"""Quickstart: train a small LM on synthetic data, checkpoint, generate.

    PYTHONPATH=src python examples/quickstart.py

Runs on a single CPU device in ~a minute.  The same code paths scale to
the production meshes via the launch layer (see examples/serve_batched.py
and src/repro/launch/{train,dryrun}.py).
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402

from repro.configs import resolve                             # noqa: E402
from repro.models import init_model, prefill, decode_step, init_cache  # noqa: E402
from repro.launch.train import main as train_main             # noqa: E402


def main():
    ckpt = "/tmp/repro_quickstart_ckpt"
    print("=== 1. train a reduced llama3.2 on synthetic tokens ===")
    # --gradsync accepts every strategy in the repro.comm train_step
    # registry ("auto" = cost-model dispatch; on a single device it
    # degrades to the native one-shot psum).  On a multi-pod mesh add
    # e.g. --gradsync lane_zero3 --pods 2 for the sharded-master FSDP
    # path — checkpoints stay restorable across chip counts either way.
    train_main(["--arch", "llama3.2-3b", "--smoke", "--steps", "60",
                "--batch", "8", "--seq", "64", "--ckpt", ckpt,
                "--log-every", "15", "--gradsync", "auto"])

    print("\n=== 2. restore + greedy generation ===")
    from repro.checkpoint import restore_checkpoint
    from repro.optim import adamw_init
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    (params, _), step = restore_checkpoint(
        ckpt, (params, adamw_init(params)))
    print(f"restored step {step}")

    prompt = jnp.asarray(np.arange(12)[None] % cfg.vocab_size, jnp.int32)
    cache = init_cache(cfg, 1, 64, dtype=jnp.float32)
    logits, state = prefill(params, cfg, prompt, cache)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    for _ in range(10):
        logits, state = decode_step(params, cfg, tok, state)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("generated token ids:", out)
    print("OK")


if __name__ == "__main__":
    main()
