"""End-to-end serving driver: continuous batching over a request stream.

    PYTHONPATH=src python examples/serve_batched.py [--arch ARCH] [--n 24]

Serves a reduced-config model with the fixed-slot continuous batcher
(vLLM-style scheduling, functional KV caches; on a pod the caches are
sequence-sharded over the "model" axis and decode uses the distributed
log-sum-exp combine — see DESIGN.md §3).
"""
import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                            # noqa: E402
import jax                                                    # noqa: E402

from repro.configs import resolve                             # noqa: E402
from repro.models import init_model                           # noqa: E402
from repro.serve import ContinuousBatcher, Request            # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--n", type=int, default=24, help="request count")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = resolve(args.arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(params, cfg, slots=args.slots, max_seq=256)

    rng = np.random.default_rng(0)
    reqs = [Request(i,
                    rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(4, 64)))
                    .astype(np.int32),
                    max_new_tokens=int(rng.integers(4, args.max_new)))
            for i in range(args.n)]

    done, stats = eng.run(reqs)
    finished = sum(1 for r in done if r.out)
    print(f"requests finished : {finished}/{len(reqs)}")
    print(f"decode steps      : {stats['steps']}")
    print(f"decode tokens     : {stats['decode_tokens']}")
    print(f"throughput        : {stats['tok_per_s']:.1f} tok/s "
          f"({args.slots} slots, CPU)")
    # batching efficiency: tokens per decode step vs slot count
    eff = stats["decode_tokens"] / max(stats["steps"], 1) / args.slots
    print(f"slot utilization  : {eff:.0%}")


if __name__ == "__main__":
    main()
