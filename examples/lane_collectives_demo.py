"""The paper's technique, standalone: full-lane collectives on 8 devices.

    PYTHONPATH=src python examples/lane_collectives_demo.py

Builds a 2-pod × (2 data × 2 model) host-device mesh, then:
  1. checks every full-lane mock-up (paper §3 Listings 1-6) against the
     one-shot native lowering,
  2. runs the self-consistent performance-guideline comparison (§4),
  3. demonstrates the §5 Proposition-1 pipelined k-lane broadcast.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import pathlib                                                 # noqa: E402
import sys                                                     # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
from jax.sharding import PartitionSpec as P, NamedSharding     # noqa: E402

from repro.core import (LaneTopology, allreduce_lane, native_allreduce,  # noqa: E402
                        allgather_lane, native_allgather,
                        pipelined_bcast_lane, check_guideline,
                        mockup_cost)


def main():
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data", "model"), lane_axis="pod")
    spec = P(("pod", "data", "model"))
    rng = np.random.default_rng(0)
    x = jax.device_put(rng.normal(size=(8 * 1024, 64)).astype(np.float32),
                       NamedSharding(mesh, spec))

    def smap(f):
        return jax.jit(jax.shard_map(f, mesh=mesh, in_specs=spec,
                                     out_specs=spec))

    print("=== 1. correctness: lane decomposition == native ===")
    lane = smap(lambda v: allreduce_lane(v, topo))(x)
    native = smap(lambda v: native_allreduce(v, topo))(x)
    # different reduction association order ⇒ fp32 ulp-level differences
    np.testing.assert_allclose(np.asarray(lane), np.asarray(native),
                               rtol=2e-3, atol=1e-4)
    print("allreduce_lane == psum one-shot  OK")

    print("\n=== 2. performance guideline (paper §4 methodology) ===")
    res = check_guideline(
        "allreduce_8k x64",
        smap(lambda v: native_allreduce(v, topo)),
        smap(lambda v: allreduce_lane(v, topo)), x)
    print(f"native {res.native_min_us:8.1f} µs | "
          f"lane mock-up {res.mockup_min_us:8.1f} µs | "
          f"ratio {res.ratio:.2f} "
          f"({'GUIDELINE VIOLATED' if res.violated else 'guideline holds'})")
    c = mockup_cost("allreduce", n=4, N=2, c=x.size)
    print(f"paper model: node vol/proc={c.vol_node:.0f} elems, "
          f"lane vol/proc={c.vol_lane:.0f} elems "
          f"(the DCN hop carries 1/n of the payload per chip)")

    print("\n=== 3. §5 pipelined k-lane broadcast (Proposition 1) ===")
    xb = jax.device_put(
        rng.normal(size=(8 * 1024, 64)).astype(np.float32),
        NamedSharding(mesh, spec))
    out = smap(lambda v: pipelined_bcast_lane(v, topo, num_blocks=8))(xb)
    print(f"pipelined bcast output shape {out.shape}; "
          f"steps = blocks + N - 1 = {8 + 2 - 1}")
    print("OK")


if __name__ == "__main__":
    main()
