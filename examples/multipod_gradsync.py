"""Gradient sync through the paper's decomposition, end to end.

    PYTHONPATH=src python examples/multipod_gradsync.py

Trains a reduced model for a few steps on a 2-pod debug mesh with each
grad-sync backend and shows (a) identical losses for native vs lane
(bitwise-equivalent reductions), (b) the int8-compressed DCN hop's loss
staying within noise, and (c) the per-strategy collective mix counted
from the lowered HLO — the dry-run methodology applied to the technique
itself.
"""
import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import pathlib                                                 # noqa: E402
import sys                                                     # noqa: E402

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

import numpy as np                                             # noqa: E402
import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
from jax.sharding import PartitionSpec as P, NamedSharding     # noqa: E402

from repro.comm import LaneComm                                # noqa: E402
from repro.configs import resolve                              # noqa: E402
from repro.core import LaneTopology                            # noqa: E402
from repro.models import init_model                            # noqa: E402
from repro.models.transformer import loss_fn                   # noqa: E402
from repro.launch.hlo_stats import analyze                     # noqa: E402


def main():
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (16, 32)).astype(np.int32)
    labels = np.roll(toks, -1, axis=1)

    bspec = P(("pod", "data"), None)
    tok_arr = jax.device_put(toks, NamedSharding(mesh, bspec))
    lab_arr = jax.device_put(labels, NamedSharding(mesh, bspec))
    pspecs = jax.tree.map(lambda _: P(), params)

    def make(strategy):
        def per_replica(p, t, l):
            loss, g = jax.value_and_grad(
                lambda pp: loss_fn(pp, cfg, t, l))(p)
            g = comm.grad_sync(g, strategy=strategy)
            if strategy == "lane_zero1":
                g = g[0]     # sharded flat bucket
            return jax.lax.pmean(loss, ("pod", "data")), g
        return jax.jit(jax.shard_map(
            per_replica, mesh=mesh, in_specs=(pspecs, bspec, bspec),
            out_specs=(P(), None if strategy == "lane_zero1" else pspecs),
            check_vma=False))

    results = {}
    for strat in ("native", "lane", "lane_pipelined", "lane_int8"):
        f = make(strat)
        lowered = f.lower(params, tok_arr, lab_arr)
        stats = analyze(lowered.compile().as_text(), pod_size=4)
        loss, grads = f(params, tok_arr, lab_arr)
        gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                for g in jax.tree.leaves(grads))))
        results[strat] = (float(loss), gn, stats)
        kinds = {k: v["count"] for k, v in stats["coll"].items()}
        print(f"{strat:10s} loss={float(loss):.4f} |grad|={gn:.5f} "
              f"colls={kinds} dcn_wire={stats['dcn_wire']/1e6:.2f}MB "
              f"ici_wire={stats['ici_wire']/1e6:.2f}MB")

    l0, g0, _ = results["native"]
    l1, g1, _ = results["lane"]
    assert abs(g0 - g1) / g0 < 1e-5, "lane must equal native"
    _, gp, _ = results["lane_pipelined"]
    assert abs(gp - g0) / g0 < 1e-5, "pipelined lane must equal native"
    _, gq, _ = results["lane_int8"]
    print(f"\nint8 DCN hop grad-norm deviation: {abs(gq-g0)/g0:.2%} "
          f"(compression error, bounded by tests)")
    dn = results["native"][2]["dcn_wire"]
    dl = results["lane"][2]["dcn_wire"]
    dq = results["lane_int8"][2]["dcn_wire"]
    print(f"DCN wire bytes  native={dn/1e6:.2f}MB  lane={dl/1e6:.2f}MB  "
          f"lane_int8={dq/1e6:.2f}MB")
    print("full-lane property: the lane strategies stripe the cross-pod "
          "payload 1/n per chip; int8 additionally halves DCN bytes 4x")


if __name__ == "__main__":
    main()
