"""Benchmark entry point: one function per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (paper tables run on an
8-device CPU mesh in a subprocess so this process keeps one device), then
the roofline table derived from the multi-pod dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run [--skip-tables] [--skip-roofline]
"""
import argparse
import os
import pathlib
import subprocess
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args(argv)
    rc = 0

    if not args.skip_tables:
        print("== paper-table benchmarks (8-device CPU mesh, subprocess) ==")
        env = dict(os.environ)
        root = pathlib.Path(__file__).resolve().parents[1]
        env["PYTHONPATH"] = f"{root / 'src'}:{root}"
        r = subprocess.run(
            [sys.executable, "-m", "benchmarks.paper_tables"],
            text=True, env=env, cwd=root, timeout=3600)
        rc |= r.returncode

    if not args.skip_roofline:
        from benchmarks import roofline
        for mesh in ("single", "multi"):
            print(f"\n== roofline ({mesh}-pod dry-run) ==")
            code = roofline.main(["--mesh", mesh])
            rc |= 0 if code in (0, 1) else code
    return rc


if __name__ == "__main__":
    sys.exit(main())
