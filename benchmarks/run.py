"""Benchmark entry point: one function per paper table + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (paper tables run on an
8-device CPU mesh in a subprocess so this process keeps one device), then
the gradient-sync trajectory (``BENCH_gradsync.json`` — native vs lane vs
lane_pipelined with the HLO overlap check), then the roofline table
derived from the multi-pod dry-run artifacts.

  PYTHONPATH=src python -m benchmarks.run [--smoke] [--skip-tables]
      [--skip-roofline] [--skip-gradsync] [--skip-recovery]
      [--skip-serve]

``--smoke`` is the CI mode: it runs only the gradsync, recovery and
serving benchmarks, at a reduced payload, which still exercises
lowering, the bucket schedule, the structural HLO verification, the
injected-fault recovery ladder and the continuous-batching serve loop
(``BENCH_serve.json``) end to end.
"""
import argparse
import os
import pathlib
import subprocess
import sys


def _sub(module_args, env, root):
    r = subprocess.run([sys.executable, "-m", *module_args],
                       text=True, env=env, cwd=root, timeout=3600)
    return r.returncode


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: gradsync bench only, small payload")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--skip-gradsync", action="store_true")
    ap.add_argument("--skip-recovery", action="store_true")
    ap.add_argument("--skip-serve", action="store_true")
    args = ap.parse_args(argv)
    rc = 0

    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{root / 'src'}:{root}"

    if args.smoke:
        args.skip_tables = args.skip_roofline = True

    if not args.skip_tables:
        print("== paper-table benchmarks (8-device CPU mesh, subprocess) ==")
        rc |= _sub(["benchmarks.paper_tables"], env, root)

    if not args.skip_gradsync:
        print("== gradient-sync trajectory (8-device CPU mesh, subprocess) ==")
        cmd = ["benchmarks.gradsync_bench"]
        if args.smoke:
            cmd.append("--smoke")
        # feed the committed timing cache (written by the tune-smoke leg /
        # repro.tuning.tune_smoke) so the auto row dispatches on measured
        # costs; gradsync_bench degrades to the closed-form model when
        # the cache is absent or stale
        if (root / "tuning_cache.json").exists():
            cmd += ["--tuning-cache", "tuning_cache.json"]
        rc |= _sub(cmd, env, root)

    if not args.skip_recovery:
        print("== recovery ladder (8-device CPU mesh, subprocess) ==")
        cmd = ["benchmarks.recovery_bench"]
        if args.smoke:
            cmd.append("--smoke")
        rc |= _sub(cmd, env, root)

    if not args.skip_serve:
        print("== serving tier (8-device CPU mesh, subprocess) ==")
        cmd = ["benchmarks.serve_bench"]
        if args.smoke:
            cmd.append("--smoke")
        rc |= _sub(cmd, env, root)

    if not args.skip_roofline:
        from benchmarks import roofline
        for mesh in ("single", "multi"):
            print(f"\n== roofline ({mesh}-pod dry-run) ==")
            code = roofline.main(["--mesh", mesh])
            rc |= 0 if code in (0, 1) else code
    return rc


if __name__ == "__main__":
    sys.exit(main())
