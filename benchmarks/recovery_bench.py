"""Recovery benchmark: what a pod loss actually costs.

Drives the REAL training driver (repro.launch.train) on the 8-device CPU
mesh through the injected-fault recovery ladder — pod 1 stops
heartbeating at step 2, the run degrades under the lane quorum, exceeds
the staleness bound, RESTARTs with an emergency checkpoint and finishes
on the elastically-shrunken mesh — and measures, against an identical
clean run:

  * steps_lost         — training steps whose work the emergency
                         checkpoint failed to capture (0 when the
                         RESTART-step save commits)
  * steps_replayed     — steps re-executed by the restarted attempt
  * time_to_recover_s  — wall-clock premium of the faulted run over the
                         clean one (detection + emergency save + replan
                         + recompile + replay, all of it)
  * quorum overhead    — grad-sync wall time of ``lane_quorum`` (the
                         degraded-mode strategy, full mask) vs ``lane``
                         on the same payload, plus the bit-identity of
                         their results (full quorum must be free of
                         numerical drift, not just cheap)

Writes ``BENCH_recovery.json`` (schema pinned by
``benchmarks/check_bench_schema.py``).  CPU caveat as everywhere in
benchmarks/: wall times validate relative behavior, not DCN physics.

  PYTHONPATH=src python -m benchmarks.recovery_bench [--smoke] [--out F]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import contextlib
import io
import json
import pathlib
import re
import sys
import tempfile
import time

import numpy as np
import jax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.comm import CommConfig, LaneComm
from repro.core import LaneTopology, time_fn

FAULT = "pod_lost@2:pod=1"


def _drive(argv) -> tuple[str, float]:
    """Run the training driver in-process; (stdout, wall seconds)."""
    from repro.launch.train import main
    buf = io.StringIO()
    t0 = time.monotonic()
    with contextlib.redirect_stdout(buf):
        rc = main(argv)
    wall = time.monotonic() - t0
    out = buf.getvalue()
    assert rc == 0, f"driver rc={rc}\n{out}"
    return out, wall


def bench_recovery(steps: int, args_base: list) -> dict:
    with tempfile.TemporaryDirectory() as td:
        clean_out, clean_wall = _drive(
            [*args_base, "--ckpt", f"{td}/clean"])
        fault_out, fault_wall = _drive(
            [*args_base, "--ckpt", f"{td}/fault",
             "--fault-plan", FAULT, "--quorum-staleness", "2"])
    m_restart = re.search(r"RESTART at step (\d+)", fault_out)
    m_resume = re.search(r"resumed from step (\d+)", fault_out)
    assert m_restart and m_resume, fault_out
    restart_step = int(m_restart.group(1))
    resume_step = int(m_resume.group(1))
    degraded = len(re.findall(r"^degraded step", fault_out, re.M))
    row = {"fault": FAULT, "steps": steps,
           "restart_step": restart_step, "resume_step": resume_step,
           "steps_lost": restart_step - resume_step,
           "steps_replayed": steps - resume_step,
           "degraded_steps": degraded,
           "clean_wall_s": round(clean_wall, 3),
           "faulted_wall_s": round(fault_wall, 3),
           "time_to_recover_s": round(fault_wall - clean_wall, 3)}
    print(f"recovery: restart@{restart_step} resumed@{resume_step} "
          f"lost={row['steps_lost']} replayed={row['steps_replayed']} "
          f"degraded={degraded} recover={row['time_to_recover_s']:.2f}s",
          flush=True)
    return row


def bench_quorum_overhead(elems: int, num_buckets: int, reps: int,
                          warmup: int) -> dict:
    """Full-quorum lane_quorum vs lane on one payload: the steady-state
    price of running with the mask plumbed in (one extra scalar psum for
    the divisor plus the per-bucket multiply) — and bit-identity."""
    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, CommConfig(buckets=num_buckets), mesh=mesh)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(elems,)).astype(np.float32)
    arr = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
    fns = {}
    for strat in ("lane", "lane_quorum"):
        fns[strat] = jax.jit(jax.shard_map(
            lambda g, s=strat: comm.grad_sync(g, strategy=s,
                                              num_buckets=num_buckets),
            mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
            check_vma=False))
    exact = bool(np.array_equal(np.asarray(fns["lane"](arr)),
                                np.asarray(fns["lane_quorum"](arr))))
    t = {s: time_fn(f, arr, reps=reps, warmup=warmup)[1]
         for s, f in fns.items()}
    row = {"payload_elems": elems, "num_buckets": num_buckets,
           "lane_min_us": round(t["lane"], 2),
           "lane_quorum_min_us": round(t["lane_quorum"], 2),
           "overhead_pct": round(
               100.0 * (t["lane_quorum"] - t["lane"]) / t["lane"], 1),
           "quorum_exact": exact}
    print(f"quorum overhead: lane={t['lane']:.1f}us "
          f"lane_quorum={t['lane_quorum']:.1f}us "
          f"(+{row['overhead_pct']:.1f}%) exact={exact}", flush=True)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small payload + few reps (CI)")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args(argv)

    steps = 8
    elems = 1 << 16 if args.smoke else 1 << 22
    reps, warmup = (5, 1) if args.smoke else (20, 3)
    args_base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                 "--seq", "32", "--log-every", "2", "--pods", "2",
                 "--gradsync", "lane_quorum", "--ckpt-every", "100",
                 "--steps", str(steps), "--seed", "7"]

    recovery = bench_recovery(steps, args_base)
    quorum = bench_quorum_overhead(elems, 4, reps, warmup)

    # acceptance: the emergency save must capture the RESTART step (no
    # work lost beyond it) and full quorum must be drift-free
    ok = recovery["steps_lost"] == 0 and quorum["quorum_exact"]
    doc = {"mesh": "2x2x2 (pod,data,model) driver / 2x4 grad-sync",
           "smoke": bool(args.smoke), "reps": reps,
           "recovery": recovery, "quorum_overhead": quorum, "ok": ok}
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(doc, indent=1))
    print(f"wrote {out_path}  (ok={ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
