import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
"""Paper-table benchmarks on an 8-device CPU mesh (2 nodes × 4 procs).

One function per paper table/figure family:
  table2_lane_pattern     — k virtual lanes moving c elements per node
  table4_multi_collective — k concurrent alltoalls over lane communicators
  table6..20_collectives  — native vs full-lane mock-up per collective
  table21_lane_vs_node    — allgather over the lane vs the node level
  prop1_pipeline          — §5 pipelined k-lane bcast vs monolithic bcast

Output CSV: name,us_per_call,derived
  us_per_call = best (min) wall time of the jitted program, paper protocol
  derived     = the cost-model quantity for that row (expected ratio /
                predicted μs / volume), stated per row in comments.

CPU caveat (stated in EXPERIMENTS.md): host "devices" share memory, so
wall times validate *relative* behavior and correctness of the guideline
methodology; absolute bandwidth effects of physical lanes appear in the
k-lane model column and in the dry-run's collective-byte accounting.
"""
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core import (LaneTopology, allreduce_lane, reduce_scatter_lane,
                        allgather_lane, bcast_lane, alltoall_lane,
                        reduce_lane, gather_lane, scatter_lane,
                        native_allreduce, native_allgather,
                        native_reduce_scatter, native_alltoall,
                        pipelined_bcast_lane, mockup_cost, klane_time,
                        time_fn)

MESH = None
TOPO = None


def _setup():
    global MESH, TOPO
    MESH = jax.make_mesh((2, 4), ("node_ax", "proc"))
    # paper roles: lanes run ACROSS nodes; procs within a node are the
    # node communicator.  lane_axis="node_ax" (N=2 nodes), node=4 procs.
    TOPO = LaneTopology(node_axes=("proc",), lane_axis="node_ax")


def _sharded(shape, spec, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    return jax.device_put(x, NamedSharding(MESH, spec))


def _smap(fn, in_spec, out_spec, check_vma=True):
    return jax.jit(jax.shard_map(fn, mesh=MESH, in_specs=in_spec,
                                 out_specs=out_spec, check_vma=check_vma))


ROWS = []


def row(name, us, derived):
    ROWS.append(f"{name},{us:.2f},{derived}")
    print(ROWS[-1], flush=True)


# ---------------------------------------------------------------------------
def table2_lane_pattern(counts=(10_000, 100_000, 1_000_000)):
    """k virtual lanes: each of the first k procs ppermutes c/k elements to
    the next node (50 reps inside the program, paper protocol)."""
    for c in counts:
        for k in (1, 2, 4):
            m = c // k

            def pattern(x):
                def body(y, _):
                    y = lax.ppermute(y, "node_ax", [(0, 1), (1, 0)])
                    return y, None
                y, _ = lax.scan(body, x, None, length=50)
                return y

            # payload: c/k elements on each of k lanes per node (global
            # (2, k·m) over (node, proc) — procs ≥ k carry no payload rows)
            spec = P("node_ax", "proc")
            x = _sharded((2, 4 * m), spec)
            f = _smap(pattern, spec, spec)
            avg, best = time_fn(f, x, reps=20, warmup=3)
            cost = mockup_cost("bcast", 4, 2, c)   # model context only
            t_model = c * 4 / k / 50e9 * 1e6       # c/k per lane, k lanes
            row(f"table2_lane_pattern_c{c}_k{k}", best / 50,
                f"{t_model:.2f}")


# ---------------------------------------------------------------------------
def table4_multi_collective(counts=(10_000, 100_000, 1_000_000)):
    """k concurrent alltoalls over disjoint lane communicators."""
    for c in counts:
        base = None
        for k in (1, 2, 4):
            m = max(c // 2, 2)

            def multi(*xs):
                outs = []
                for x in xs:
                    outs.append(lax.all_to_all(
                        x.reshape(2, -1), "node_ax", 0, 0, tiled=True)
                        .reshape(x.shape))
                return tuple(outs)

            spec = tuple(P("node_ax") for _ in range(k))
            xs = tuple(_sharded((2 * m,), P("node_ax"), seed=i)
                       for i in range(k))
            f = _smap(multi, spec, spec)
            avg, best = time_fn(f, *xs, reps=20, warmup=3)
            if k == 1:
                base = best
            row(f"table4_multi_coll_c{c}_k{k}", best,
                f"{best / base:.2f}x_vs_k1")


# ---------------------------------------------------------------------------
_COLLS = {}


def _register_collectives():
    p_rows = 8   # divisible by p=8
    topo = TOPO

    def inputs(rows, seed=0):
        return _sharded((8 * rows, 16), P(("node_ax", "proc"), None),
                        seed=seed)

    _COLLS.update({
        "allreduce": (lambda x: native_allreduce(x, topo),
                      lambda x: allreduce_lane(x, topo), 1),
        "reduce_scatter": (lambda x: native_reduce_scatter(x, topo),
                           lambda x: reduce_scatter_lane(x, topo), 8),
        "allgather": (lambda x: native_allgather(x, topo),
                      lambda x: allgather_lane(x, topo), 1),
        "alltoall": (lambda x: native_alltoall(x, topo),
                     lambda x: alltoall_lane(x, topo), 8),
        "bcast": (lambda x: native_allreduce(jnp.where(
                      topo.global_rank() == 0, x, jnp.zeros_like(x)), topo),
                  lambda x: bcast_lane(x, topo), 4),
        "reduce": (lambda x: jnp.where(
            topo.global_rank() == 0, native_allreduce(x, topo),
            jnp.zeros_like(x)), lambda x: reduce_lane(x, topo), 1),
        "gather": (lambda x: jnp.where(
            topo.global_rank() == 0,
            native_allgather(x, topo), 0.0 * native_allgather(x, topo)),
            lambda x: gather_lane(x, topo), 1),
        "scatter": (lambda x: lax.psum_scatter(
            jnp.where(topo.lane_rank() + topo.node_rank() == 0, x,
                      jnp.zeros_like(x)),
            ("node_ax", "proc"), scatter_dimension=0, tiled=True),
            lambda x: scatter_lane(x, topo), 8),
    })
    return inputs


def tables6to20_collectives(rows_list=(16, 128, 1024, 8192)):
    """Native (one-shot XLA lowering) vs full-lane mock-up, per collective.
    derived = native/mockup best-time ratio (>1 ⇒ guideline violation) +
    the k-lane model's predicted mock-up advantage on 2 physical lanes."""
    inputs = _register_collectives()
    for rows in rows_list:
        x = inputs(rows)
        c = 8 * rows * 16
        for name, (nat, mock, mult) in _COLLS.items():
            spec = P(("node_ax", "proc"), None)
            fn_n = _smap(nat, spec, spec)
            fn_m = _smap(mock, spec, spec)
            # shape checks: run once, compare shapes only (correctness is
            # covered by tests); then time
            a, bn = time_fn(fn_n, x, reps=15, warmup=3)
            a2, bm = time_fn(fn_m, x, reps=15, warmup=3)
            cost = mockup_cost(name if name not in ("bcast",) else "bcast",
                               4, 2, c)
            t_pred = klane_time(cost, k=2, elem_bytes=4,
                                alpha_node=1e-6, beta_node=1 / 400e9,
                                alpha_lane=5e-6, beta_lane=1 / 50e9) * 1e6
            row(f"table_coll_{name}_rows{rows}_native", bn, f"{bn/bm:.3f}")
            row(f"table_coll_{name}_rows{rows}_lane", bm,
                f"pred_us={t_pred:.1f}")


# ---------------------------------------------------------------------------
def table21_lane_vs_node(rows_list=(64, 1024, 8192)):
    """Allgather purely over the lane level vs purely over the node level
    (paper Table 21: the node level can be the slower one)."""
    for rows in rows_list:
        xl = _sharded((2 * rows, 16), P("node_ax", None))
        fn_l = _smap(lambda x: lax.all_gather(x, "node_ax", axis=0,
                                              tiled=True),
                     P("node_ax", None), P(None, None), check_vma=False)
        a, bl = time_fn(fn_l, xl, reps=15, warmup=3)
        xn = _sharded((4 * rows, 16), P("proc", None))
        fn_n = _smap(lambda x: lax.all_gather(x, "proc", axis=0, tiled=True),
                     P("proc", None), P(None, None), check_vma=False)
        a, bn = time_fn(fn_n, xn, reps=15, warmup=3)
        row(f"table21_allgather_lane_rows{rows}", bl, f"node_us={bn:.1f}")


# ---------------------------------------------------------------------------
def prop1_pipeline(counts=(4096, 65_536, 1_048_576)):
    """§5 construction: pipelined k-lane bcast vs monolithic full-lane
    bcast; derived = steps used (B + N - 1, Proposition 1)."""
    from repro.core import pipeline_steps
    for c in counts:
        B = 8
        rows = max(c // 16 // (B * 4) * (B * 4), B * 4)
        x = _sharded((8 * rows // 8 * 8, 16), P(("node_ax", "proc"), None))
        spec = P(("node_ax", "proc"), None)
        f_pipe = _smap(lambda x: pipelined_bcast_lane(x, TOPO, num_blocks=B),
                       spec, spec)
        f_mono = _smap(lambda x: bcast_lane(x, TOPO), spec, spec)
        a, bp = time_fn(f_pipe, x, reps=10, warmup=2)
        a, bm = time_fn(f_mono, x, reps=10, warmup=2)
        row(f"prop1_pipelined_bcast_c{c}", bp,
            f"steps={pipeline_steps(B, 2)};mono_us={bm:.1f}")


def main(argv=None):
    _setup()
    print("name,us_per_call,derived")
    table2_lane_pattern()
    table4_multi_collective()
    tables6to20_collectives()
    table21_lane_vs_node()
    prop1_pipeline()
    print(f"TOTAL_ROWS {len(ROWS)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
