"""Roofline derivation from the dry-run artifacts (§Roofline deliverable).

Per (arch × shape × mesh) cell, from the trip-count-corrected HLO stats:

  compute    = flops_per_chip / 197 TF/s            (bf16 MXU peak)
  memory     = hbm_bytes_per_chip / 819 GB/s
  collective = wire_bytes_per_chip / 50 GB/s        (per-spec formula)
               [refined column: DCN bytes at 6.25 GB/s/chip = 25 GB/s
                per host NIC / 4 chips — the multi-lane resource]

  MODEL_FLOPS = 6·N·D (train), 2·N·D (prefill), 2·N·B (decode);
                N_active for MoE.  ratio = MODEL_FLOPS / HLO_FLOPS
                exposes remat/causal-masking/capacity waste.

  bottleneck = argmax(term); roofline fraction = compute / max(terms)
               (≈ achievable MFU fraction under perfect overlap).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh single|multi]
           [--csv out.csv] [--md]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.configs import all_archs, cells, resolve, SHAPES

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"

PEAK = 197e12
HBM = 819e9
ICI = 50e9
DCN_PER_CHIP = 25e9 / 4


def model_flops(arch: str, shape_name: str) -> float:
    cfg = resolve(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: 1 tok/seq


def load_cell(arch: str, shape: str, mesh: str) -> dict | None:
    p = RUNS / mesh / f"{arch}__{shape}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def terms(r: dict) -> dict:
    st = r["hlo_stats"]
    compute = st["flops"] / PEAK
    memory = st["bytes"] / HBM
    coll = (st["ici_wire"] + st["dcn_wire"]) / ICI        # per-spec formula
    coll_refined = st["ici_wire"] / ICI + st["dcn_wire"] / DCN_PER_CHIP
    mf = model_flops(r["arch"], r["shape"])
    hlo_total = st["flops"] * r["chips"]
    out = {
        "compute_s": compute, "memory_s": memory, "collective_s": coll,
        "collective_refined_s": coll_refined,
        "model_flops": mf, "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "dcn_frac": st["dcn_wire"] / max(st["ici_wire"] + st["dcn_wire"], 1),
    }
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: out[k])
    out["bottleneck"] = dom.replace("_s", "")
    out["roofline_fraction"] = compute / max(out[dom], 1e-12)
    # MFU if the step ran exactly at the dominant term's duration
    out["model_mfu_bound"] = mf / (r["chips"] * PEAK * max(out[dom], 1e-12))
    return out


def build_table(mesh: str) -> list[dict]:
    rows = []
    for a in all_archs():
        for s in cells(a):
            r = load_cell(a, s, mesh)
            if r is None:
                continue
            t = terms(r)
            t.update(arch=a, shape=s, mesh=r["mesh"], chips=r["chips"])
            rows.append(t)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--csv", default="")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args(argv)
    rows = build_table(args.mesh)
    if not rows:
        print("no dry-run artifacts; run: python -m repro.launch.dryrun --all")
        return 1
    hdr = ["arch", "shape", "compute_s", "memory_s", "collective_s",
           "bottleneck", "roofline_fraction", "useful_ratio",
           "model_mfu_bound", "dcn_frac"]
    lines = [",".join(hdr)]
    for t in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(",".join(
            f"{t[h]:.4g}" if isinstance(t[h], float) else str(t[h])
            for h in hdr))
    out = "\n".join(lines)
    print(out)
    if args.csv:
        pathlib.Path(args.csv).write_text(out + "\n")
    if args.md:
        print("\n| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for ln in lines[1:]:
            print("| " + " | ".join(ln.split(",")) + " |")
    return 0


if __name__ == "__main__":
    sys.exit(main())
