"""Gradient-sync benchmark: the training hot path, per strategy.

Times LaneComm.grad_sync under shard_map on the 8-device CPU mesh
(2 pods × 4 chips) for EVERY strategy the repro.comm registry has
registered (the ZeRO strategies timed as their RS+AG roundtrip), plus
one ``auto`` row recording what the cost-model dispatcher picked,
sweeping the bucket count, and writes ``BENCH_gradsync.json`` — the perf
trajectory future PRs regress against (schema pinned by
``benchmarks/check_bench_schema.py``, whose required-strategy list is
derived from the same registry: a silently-unregistered impl fails the
build).  The third-parallelism-axis section (PR 10) times the EP
token-routing alltoall (``moe_route``) and the TP activation allgather
on the (2,2,2) pods×data×model mesh and pins the tentpole wire-volume
acceptance: the two 1/E-expert routing alltoalls move ≤ 2/E of the
bytes the replaced full expert-weight gather moved per layer.
Also verifies STRUCTURALLY on the optimized HLO that each
bucketed/pipelined program contains a cross-pod (DCN) collective with no
data dependence on an intra-pod (ICI) collective — the §5 overlap
precondition — and that the monolithic K=1 chain does NOT (negative
control).

CPU caveat (same as paper_tables): host devices share memory, so wall
times validate relative behavior and the schedule's structure, not
physical DCN bandwidth; the k-lane model column carries the hardware
prediction.

  PYTHONPATH=src python -m benchmarks.gradsync_bench [--smoke] [--out F]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# (must run before the jax import below; the docstring evaluates first
# either way)

import argparse
import json
import pathlib
import sys

import numpy as np
import jax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.comm import CommConfig, LaneComm, iter_impls, strategies_for
from repro.core import LaneTopology, time_fn, bucket_pipeline_time, get_hw
from repro.core.costmodel import optimal_num_buckets
from repro.optim.gradsync import resolve_num_buckets
from repro.launch import hlo_stats
from repro.tuning import Tuner, load_timing_table_or_none

POD = 4                               # chips per pod on the 2×4 bench mesh


def bench_families(mesh, topo, reps, warmup):
    """Per-family lane_zero3 rows: time ONE layer's pipelined prefetch
    gather (the zero3 hot path) for every registered block-stack family's
    smoke arch, verify the gather reproduces the master row bit-exactly,
    and structurally verify the DCN/ICI overlap of the gather pipeline
    on the optimized HLO.  The family list derives from the block-stack
    registry (check_bench_schema re-derives it: a silently-dropped
    family fails the build)."""
    from repro.configs import resolve
    from repro.launch.steps import zero3_stack_layouts
    from repro.models import init_model
    from repro.models.blockstack import (block_stack_spec,
                                         family_smoke_archs, shard_stack,
                                         split_params)
    n, N = topo.sizes(mesh)
    rows = []
    for fam, arch in family_smoke_archs().items():
        cfg = resolve(arch, smoke=True)
        params = init_model(jax.random.PRNGKey(0), cfg)
        lays = zero3_stack_layouts(cfg)
        stack, extras, _ = split_params(block_stack_spec(cfg), params)
        B = 2                         # >=2 so the gather pipeline exists
        master, _ = shard_stack(stack, n, N, B)
        comm = LaneComm(topo, mesh=mesh)
        L = master.shape[0]

        def f(m, L=L, B=B, comm=comm):
            return comm.prefetch_allgather(m.reshape(L, -1)[0],
                                           num_blocks=B)

        fn = jax.jit(jax.shard_map(
            f, mesh=mesh,
            in_specs=P(None, None, (*topo.node_axes, topo.lane_axis),
                       None),
            out_specs=P(), check_vma=False))
        arr = jax.device_put(
            np.asarray(master),
            NamedSharding(mesh, P(None, None,
                                  (*topo.node_axes, topo.lane_axis),
                                  None)))
        hlo = fn.lower(arr).compile().as_text()
        conc = hlo_stats.collective_concurrency(hlo, pod_size=POD)
        out = np.asarray(fn(arr))
        want = np.asarray(master).reshape(L, -1)[0]
        exact = bool(np.array_equal(out, want))
        avg, best = time_fn(fn, arr, reps=reps, warmup=warmup)
        row = {"family": fam, "arch": arch,
               "layer_elems": lays["blocks"].row_elems,
               "extra_elems": lays["extras"].row_elems,
               "num_layers": L, "num_blocks": B,
               "avg_us": round(avg, 2), "min_us": round(best, 2),
               "gather_exact": exact,
               "hlo_concurrent": conc["concurrent"]}
        rows.append(row)
        print(f"zero3[{fam:7s}] {arch:22s} D={row['layer_elems']:8d} "
              f"min={best:9.1f}us overlap="
              f"{'YES' if conc['concurrent'] else 'no'} exact={exact}",
              flush=True)
    return rows


def _cell_predicted_us(collective, strategy, local_bytes, n, N, tuner):
    """predicted_us for a non-grad_sync cell: timing-cache median when
    measured, else the registered impl's closed form."""
    if tuner is not None:
        m = tuner.measured_cost(collective, strategy, n, N, local_bytes)
        if m is not None:
            return round(m * 1e6, 2)
    e = next((e for e in iter_impls(collective)
              if e.strategy == strategy), None)
    if e is None or e.cost is None:
        return None
    return round(e.cost(n, N, local_bytes, CommConfig()) * 1e6, 2)


def _bench_cell(mesh, topo, collective, strategy, xs, reps, warmup, tuner):
    """One (collective, strategy) row: shard xs over the topo's joint
    axis, dispatch through the LaneComm registry cell, time it, and
    record what auto selected plus the cost model's predicted_us."""
    comm = LaneComm(topo, CommConfig(tuner=tuner), mesh=mesh)
    strat = None if strategy == "auto" else strategy

    def f(x):
        return getattr(comm, collective)(x, strategy=strat)
    spec = P((topo.lane_axis, *topo.node_axes))
    fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=spec,
                               out_specs=spec, check_vma=False))
    arr = jax.device_put(xs, NamedSharding(mesh, spec))
    out = np.asarray(fn(arr))
    avg, best = time_fn(fn, arr, reps=reps, warmup=warmup)
    n_, N_ = topo.sizes(mesh)
    local_bytes = xs.nbytes // (n_ * N_)
    if strategy == "auto":
        sel = comm.last_selection
        selected = sel.strategy
        pred = round(sel.ranking[0][0] * 1e6, 2)
    else:
        selected = strategy
        pred = _cell_predicted_us(collective, strategy, local_bytes,
                                  n_, N_, tuner)
    row = {"cell": collective, "strategy": strategy, "selected": selected,
           "payload_bytes": local_bytes, "avg_us": round(avg, 2),
           "min_us": round(best, 2), "predicted_us": pred}
    return row, out


def bench_third_axis(reps, warmup, tuner):
    """Third-parallelism-axis rows (PR 10) on the (2,2,2) pods×data×model
    mesh: the EP token-routing alltoall (``moe_route`` cells, at the MoE
    smoke arch's real (B, E, C, d) dispatch-buffer payload, over the
    batch-axes communicator) and the TP activation allgather (the
    degenerate node_axes=() model-axis communicator mlp_tp rides) — every
    registered strategy plus the auto-dispatch row, each with
    predicted_us.  Returns (rows, ep_wire): ``ep_wire`` is the tentpole
    wire-volume acceptance — the two 1/E-expert routing alltoalls move
    ≤ 2/E of the bytes the old full expert-weight gather moved per layer
    (ratio = 2·B·C / (W·f) with W FFN mats of f columns)."""
    from repro.configs import resolve
    from repro.models.moe import _capacity
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    rows = []
    rng = np.random.default_rng(7)

    # --- EP routing: moe_route over the batch axes -----------------------
    cfg = resolve("dbrx-132b", smoke=True)
    topo_ep = LaneTopology(node_axes=("data",), lane_axis="pod")
    n_, N_ = topo_ep.sizes(mesh3)
    p = n_ * N_
    E, d = cfg.num_experts, cfg.d_model
    B, T = 2, 16                       # per-chip rows at the smoke shape
    C = _capacity(cfg, T)
    xs = rng.normal(size=(p * B * E * C, d)).astype(np.float32)
    oracle = None
    for s in (*strategies_for("moe_route"), "auto"):
        row, out = _bench_cell(mesh3, topo_ep, "moe_route", s, xs,
                               reps, warmup, tuner)
        if oracle is None and s == "native":
            oracle = out
        row["max_abs_err_vs_native"] = \
            float(np.max(np.abs(out - oracle))) if oracle is not None \
            else 0.0
        rows.append(row)
        print(f"moe_route[{s:6s}] -> {row['selected']:6s} "
              f"min={row['min_us']:9.1f}us pred={row['predicted_us']}us",
              flush=True)

    # routing alltoall per layer: dispatch + combine, each the full
    # (B, E, C, d) buffer; the replaced gather: W expert FFN mats of
    # (E, d, f) — the ≤ 2/E acceptance, asserted by check_bench_schema
    W = 3 if cfg.gated_mlp else 2
    a2a = 2 * B * E * C * d * 4
    gather = W * E * d * cfg.d_ff * 4
    ep_wire = {"arch": cfg.name, "num_experts": E, "capacity": C,
               "alltoall_bytes_per_layer": a2a,
               "expert_gather_bytes_per_layer": gather,
               "ratio": round(a2a / gather, 4),
               "bound": round(2 / E, 4),
               "ok": bool(a2a / gather <= 2 / E)}
    print(f"ep_wire: alltoall/gather = {ep_wire['ratio']} "
          f"(bound 2/E = {ep_wire['bound']}) "
          f"{'OK' if ep_wire['ok'] else 'FAIL'}", flush=True)

    # --- TP activations: allgather over the degenerate model-axis comm ---
    dcfg = resolve("llama3.2-3b", smoke=True)
    topo_tp = LaneTopology(node_axes=(), lane_axis="model")
    tp = 2
    xs = rng.normal(size=(tp * B * T, dcfg.d_model)).astype(np.float32)
    oracle = None
    for s in (*strategies_for("allgather"), "auto"):
        row, out = _bench_cell(mesh3, topo_tp, "allgather", s, xs,
                               reps, warmup, tuner)
        row["cell"] = "tp_allgather"
        if oracle is None and s == "native":
            oracle = out
        row["max_abs_err_vs_native"] = \
            float(np.max(np.abs(out - oracle))) if oracle is not None \
            else 0.0
        rows.append(row)
        print(f"tp_allgather[{s:6s}] -> {row['selected']:6s} "
              f"min={row['min_us']:9.1f}us pred={row['predicted_us']}us",
              flush=True)
    return rows, ep_wire


def predicted_us(strategy, K, local_bytes, n, N, tuner):
    """The cost auto-dispatch would charge this cell, in µs: the timing
    cache's measured median when one covers it, else the §3/§5 closed
    form of the registered impl (None for cost-less registrations)."""
    if tuner is not None:
        m = tuner.measured_cost("grad_sync", strategy, n, N, local_bytes)
        if m is not None:
            return m * 1e6
    e = next((e for e in iter_impls("grad_sync")
              if e.strategy == strategy), None)
    if e is None or e.cost is None:
        return None
    return e.cost(n, N, local_bytes, CommConfig(buckets=K)) * 1e6


def build(mesh, topo, strategy, num_buckets, tuner=None):
    """(jitted fn, comm) — the comm records any auto-dispatch selection."""
    comm = LaneComm(topo, CommConfig(buckets=num_buckets, tuner=tuner),
                    mesh=mesh)

    def f(g):
        out = comm.grad_sync(g, strategy=strategy, num_buckets=num_buckets)
        if strategy in ("lane_zero1", "lane_zero3"):
            # roundtrip for a comparable full-vector result: the RS'd
            # stripe is re-gathered (training instead defers this gather
            # past the optimizer / into the next forward's per-layer
            # prefetch) — the zero3 row times RS(node)→RS(lane)→AG(lane)
            # →AG(node).  K is re-resolved with grad_sync's own cap so
            # the unshard always agrees with the shard layout, even if
            # the payload shrinks below K·shard_ways.
            from repro.optim.gradsync import (_unflatten_bucket,
                                              zero1_unshard, zero3_unshard)
            shard, spec = out
            ways = topo.n() * (topo.N() if strategy == "lane_zero3" else 1)
            k_eff = resolve_num_buckets(g.shape[0], ways, num_buckets)
            unshard = (zero3_unshard if strategy == "lane_zero3"
                       else zero1_unshard)
            out = _unflatten_bucket(unshard(shard, topo, k_eff), spec)
        return out
    fn = jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
        check_vma=False))
    return fn, comm


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small payload + few reps (CI)")
    ap.add_argument("--out", default="BENCH_gradsync.json")
    ap.add_argument("--tuning-cache", default="",
                    help="timing cache (repro.tuning) feeding the auto "
                         "row's dispatch + every row's predicted_us; "
                         "missing/corrupt = closed-form model")
    args = ap.parse_args(argv)

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    tuner = None
    if args.tuning_cache:
        table = load_timing_table_or_none(args.tuning_cache)
        if table is not None:
            tuner = Tuner(table)
            print(f"tuning cache: {args.tuning_cache} "
                  f"({len(table)} measured cells)")

    topo_n = 4                                        # chips per pod
    elems = 1 << 16 if args.smoke else 1 << 22        # fp32 elements
    reps, warmup = (5, 1) if args.smoke else (20, 3)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(elems,)).astype(np.float32)
    arr = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))

    # grad_sync runs inside shard_map, so its cost-model auto-K resolves
    # from the PER-CHIP payload (elems / 8 devices), not the global one —
    # the structure check below must use the same resolution
    auto_k = resolve_num_buckets(elems // 8, topo_n, 0)
    # the registry IS the grid: every registered grad_sync strategy gets
    # at least one row (schema-checked), plus the auto-dispatch row
    registered = strategies_for("grad_sync")
    if args.smoke:
        # below the cost-model crossover auto-K is 1; pin K=4 so CI still
        # exercises (and structurally verifies) the multi-bucket schedule
        grid = [("native", 0), ("lane", 1), ("lane", 4),
                ("lane_pipelined", 4), ("lane_quorum", 4),
                ("lane_int8", 4),
                ("lane_zero1", 4), ("lane_zero3", 4), ("auto", 0)]
    else:
        grid = [("native", 0), ("lane", 1), ("lane", auto_k),
                ("lane_pipelined", auto_k), ("lane", 4), ("lane", 16),
                ("lane_pipelined", 4), ("lane_pipelined", 16),
                ("lane_quorum", 4), ("lane_quorum", 16),
                ("lane_int8", auto_k),
                ("lane_zero1", 1), ("lane_zero1", 4),
                ("lane_zero3", 1), ("lane_zero3", 4),
                ("lane_zero3", max(auto_k, 1)), ("auto", 0)]
        # auto_k may coincide with a swept K — drop duplicate cells
        grid = list(dict.fromkeys(grid))
    missing = set(registered) - {s for s, _ in grid}
    assert not missing, f"bench grid lost registered strategies: {missing}"

    results = []
    hlo_checks = {}
    oracle = None
    for strategy, K in grid:
        fn, comm = build(mesh, topo, strategy, K, tuner)
        lowered = fn.lower(arr)
        hlo = lowered.compile().as_text()
        conc = hlo_stats.collective_concurrency(hlo, pod_size=POD)
        # what actually ran: the auto row records the dispatcher's pick
        selected = strategy
        local_bytes = elems // 8 * 4     # per-chip trace-time payload
        n_, N_ = topo.sizes(mesh)
        if strategy == "auto":
            sel = comm.last_selection
            selected = sel.strategy
            pred = round(sel.ranking[0][0] * 1e6, 2)
            print(f"auto-dispatch: {selected} [{sel.source}] "
                  f"(ranking {[(s, round(t * 1e6, 1)) for t, s in sel.ranking]})")
        else:
            pred = predicted_us(strategy, K, local_bytes, n_, N_, tuner)
            pred = None if pred is None else round(pred, 2)
        avg, best = time_fn(fn, arr, reps=reps, warmup=warmup)
        out = np.asarray(fn(arr))
        if oracle is None and strategy == "native":
            oracle = out
        max_err = float(np.max(np.abs(out - oracle))) if oracle is not None \
            else 0.0
        stripe_bytes = elems * 4 / topo_n           # full-lane DCN stripe
        pred_us = bucket_pipeline_time(stripe_bytes, max(K, 1)) * 1e6
        row = {"strategy": strategy, "selected": selected, "num_buckets": K,
               "avg_us": round(avg, 2), "min_us": round(best, 2),
               "max_abs_err_vs_native": max_err,
               "model_pred_us": round(pred_us, 2),
               "predicted_us": pred,
               "hlo_concurrent": conc["concurrent"],
               "hlo_concurrent_pairs": len(conc["pairs"])}
        results.append(row)
        hlo_checks[f"{strategy}_K{K}"] = conc["per_computation"]
        print(f"{strategy:16s} K={K:3d} min={best:9.1f}us avg={avg:9.1f}us "
              f"overlap={'YES' if conc['concurrent'] else 'no':3s} "
              f"pairs={len(conc['pairs'])}", flush=True)

    family_rows = bench_families(mesh, topo, reps, warmup)
    third_axis_rows, ep_wire = bench_third_axis(reps, warmup, tuner)

    # structural acceptance: pipelined/bucketed overlap possible, serial not
    ok = True
    for frow in family_rows:
        if not (frow["gather_exact"] and frow["hlo_concurrent"]):
            print(f"FAMILY FAIL: {frow}")
            ok = False
    # third-axis acceptance: the decomposed routing/TP cells are exact
    # permutations of the native collectives, and the two 1/E-expert
    # routing alltoalls must undercut the old expert gather by >= E/2
    for trow in third_axis_rows:
        if trow["max_abs_err_vs_native"] != 0.0:
            print(f"THIRD-AXIS NUMERICS FAIL: {trow}")
            ok = False
    if not ep_wire["ok"]:
        print(f"EP WIRE-VOLUME FAIL: {ep_wire}")
        ok = False
    for row in results:
        eff = row["selected"]
        if eff == "native":
            continue
        # a single-bucket schedule is a monolithic chain for every wave-
        # scheduled strategy (only the lax.scan pipeline keeps structural
        # concurrency at K=1 — its stages read distinct scan carries)
        k_eff = row["num_buckets"] if row["num_buckets"] else auto_k
        want = not (eff in ("lane", "lane_int8", "lane_zero1", "lane_zero3")
                    and k_eff == 1)
        if row["hlo_concurrent"] != want:
            print(f"STRUCTURE FAIL: {row['strategy']} K={row['num_buckets']} "
                  f"concurrent={row['hlo_concurrent']}, expected {want}")
            ok = False
        if row["max_abs_err_vs_native"] > \
                (0.2 if eff == "lane_int8" else 1e-3):
            print(f"NUMERICS FAIL: {row}")
            ok = False

    doc = {
        "mesh": "2x4 (pod,data)", "payload_elems": elems,
        "payload_bytes": elems * 4, "auto_num_buckets": auto_k,
        "strategies_registered": list(registered),
        "tuning_cache": args.tuning_cache if tuner is not None else None,
        "cost_model": {"alpha_dcn_s": get_hw().alpha_dcn,
                       "dcn_bw_Bps": get_hw().dcn_bw,
                       "optimal_K_model":
                           optimal_num_buckets(elems * 4 / topo_n)},
        "smoke": bool(args.smoke), "reps": reps,
        "results": results,
        "family_results": family_rows,
        "families_registered": [r["family"] for r in family_rows],
        "third_axis_results": third_axis_rows,
        "ep_wire": ep_wire,
        "hlo_per_computation": hlo_checks,
        "structure_ok": ok,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(doc, indent=1))
    print(f"wrote {out_path}  (structure_ok={ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
