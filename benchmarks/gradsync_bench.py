"""Gradient-sync benchmark: the training hot path, per strategy.

Times grad_sync under shard_map on the 8-device CPU mesh (2 pods × 4
chips) for native vs lane vs lane_pipelined (plus lane_int8 and the
ZeRO-3 lane_zero3 reduce-scatter, timed as its RS+AG roundtrip),
sweeping the bucket count, and writes ``BENCH_gradsync.json`` — the perf
trajectory future PRs regress against (schema pinned by
``benchmarks/check_bench_schema.py``).  Also verifies STRUCTURALLY on
the optimized HLO that each bucketed/pipelined program contains a
cross-pod (DCN) collective with no data dependence on an intra-pod (ICI)
collective — the §5 overlap precondition — and that the monolithic K=1
chain does NOT (negative control).

CPU caveat (same as paper_tables): host devices share memory, so wall
times validate relative behavior and the schedule's structure, not
physical DCN bandwidth; the k-lane model column carries the hardware
prediction.

  PYTHONPATH=src python -m benchmarks.gradsync_bench [--smoke] [--out F]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# (must run before the jax import below; the docstring evaluates first
# either way)

import argparse
import json
import pathlib
import sys

import numpy as np
import jax
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.core import LaneTopology, time_fn, bucket_pipeline_time, HW
from repro.core.costmodel import optimal_num_buckets
from repro.optim import grad_sync
from repro.optim.gradsync import resolve_num_buckets
from repro.launch import hlo_stats

POD = 4                               # chips per pod on the 2×4 bench mesh


def build(mesh, topo, strategy, num_buckets):
    def f(g):
        out = grad_sync(g, topo, strategy, num_buckets=num_buckets)
        if strategy == "lane_zero3":
            # roundtrip for a comparable full-vector result: the RS'd 1/p
            # stripe is re-gathered (training instead defers this gather
            # into the next forward's per-layer prefetch) — the timed row
            # is RS(node)→RS(lane)→AG(lane)→AG(node).  K is re-resolved
            # with grad_sync's own cap so the unshard always agrees with
            # the shard layout, even if the payload shrinks below K·p.
            from repro.optim.gradsync import _unflatten_bucket, zero3_unshard
            shard, spec = out
            k_eff = resolve_num_buckets(g.shape[0], topo.n() * topo.N(),
                                        num_buckets)
            out = _unflatten_bucket(zero3_unshard(shard, topo, k_eff), spec)
        return out
    return jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
        check_vma=False))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small payload + few reps (CI)")
    ap.add_argument("--out", default="BENCH_gradsync.json")
    args = ap.parse_args(argv)

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")

    topo_n = 4                                        # chips per pod
    elems = 1 << 16 if args.smoke else 1 << 22        # fp32 elements
    reps, warmup = (5, 1) if args.smoke else (20, 3)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(elems,)).astype(np.float32)
    arr = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))

    auto_k = resolve_num_buckets(elems, topo_n, 0)
    if args.smoke:
        # below the cost-model crossover auto-K is 1; pin K=4 so CI still
        # exercises (and structurally verifies) the multi-bucket schedule
        grid = [("native", 0), ("lane", 1), ("lane", 4),
                ("lane_pipelined", 4), ("lane_zero3", 4)]
    else:
        grid = [("native", 0), ("lane", 1), ("lane", auto_k),
                ("lane_pipelined", auto_k), ("lane", 4), ("lane", 16),
                ("lane_pipelined", 4), ("lane_pipelined", 16),
                ("lane_int8", auto_k),
                ("lane_zero3", 1), ("lane_zero3", 4),
                ("lane_zero3", max(auto_k, 1))]
        # auto_k may coincide with a swept K — drop duplicate cells
        grid = list(dict.fromkeys(grid))

    results = []
    hlo_checks = {}
    oracle = None
    for strategy, K in grid:
        fn = build(mesh, topo, strategy, K)
        lowered = fn.lower(arr)
        hlo = lowered.compile().as_text()
        conc = hlo_stats.collective_concurrency(hlo, pod_size=POD)
        avg, best = time_fn(fn, arr, reps=reps, warmup=warmup)
        out = np.asarray(fn(arr))
        if oracle is None and strategy == "native":
            oracle = out
        max_err = float(np.max(np.abs(out - oracle))) if oracle is not None \
            else 0.0
        stripe_bytes = elems * 4 / topo_n           # full-lane DCN stripe
        pred_us = bucket_pipeline_time(stripe_bytes, max(K, 1)) * 1e6
        row = {"strategy": strategy, "num_buckets": K,
               "avg_us": round(avg, 2), "min_us": round(best, 2),
               "max_abs_err_vs_native": max_err,
               "model_pred_us": round(pred_us, 2),
               "hlo_concurrent": conc["concurrent"],
               "hlo_concurrent_pairs": len(conc["pairs"])}
        results.append(row)
        hlo_checks[f"{strategy}_K{K}"] = conc["per_computation"]
        print(f"{strategy:16s} K={K:3d} min={best:9.1f}us avg={avg:9.1f}us "
              f"overlap={'YES' if conc['concurrent'] else 'no':3s} "
              f"pairs={len(conc['pairs'])}", flush=True)

    # structural acceptance: pipelined/bucketed overlap possible, serial not
    ok = True
    for row in results:
        if row["strategy"] == "native":
            continue
        want = not (row["strategy"] in ("lane", "lane_zero3")
                    and row["num_buckets"] == 1)
        if row["hlo_concurrent"] != want:
            print(f"STRUCTURE FAIL: {row['strategy']} K={row['num_buckets']} "
                  f"concurrent={row['hlo_concurrent']}, expected {want}")
            ok = False
        if row["max_abs_err_vs_native"] > \
                (0.2 if row["strategy"] == "lane_int8" else 1e-3):
            print(f"NUMERICS FAIL: {row}")
            ok = False

    doc = {
        "mesh": "2x4 (pod,data)", "payload_elems": elems,
        "payload_bytes": elems * 4, "auto_num_buckets": auto_k,
        "cost_model": {"alpha_dcn_s": HW.alpha_dcn,
                       "dcn_bw_Bps": HW.dcn_bw,
                       "optimal_K_model":
                           optimal_num_buckets(elems * 4 / topo_n)},
        "smoke": bool(args.smoke), "reps": reps,
        "results": results,
        "hlo_per_computation": hlo_checks,
        "structure_ok": ok,
    }
    out_path = pathlib.Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(doc, indent=1))
    print(f"wrote {out_path}  (structure_ok={ok})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
