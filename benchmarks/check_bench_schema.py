"""Schema check for BENCH_gradsync.json and BENCH_recovery.json.

The benchmarks are the perf trajectory future PRs regress against; a
refactor that silently drops a strategy from the grid (or a field from
the rows) would make the trajectory lie by omission.  This check fails
the build instead.  The required-strategy list is DERIVED from the
repro.comm registry — every registered grad_sync strategy (plus the
``auto`` dispatch row) must appear, so an impl that quietly loses its
registration, or a registration the bench never exercises, both fail CI.
The recovery document (steps lost / time-to-recover / quorum overhead,
benchmarks/recovery_bench.py) is pinned the same way.

  PYTHONPATH=src python -m benchmarks.check_bench_schema [--file F]
      [--recovery-file R]

Run after ``benchmarks.run --smoke`` (make ci does).
"""
import argparse
import json
import pathlib
import sys

TOP_KEYS = {"mesh", "payload_elems", "payload_bytes", "auto_num_buckets",
            "strategies_registered", "cost_model", "smoke", "reps",
            "results", "family_results", "families_registered",
            "hlo_per_computation", "structure_ok"}

ROW_KEYS = {"strategy", "selected", "num_buckets", "avg_us", "min_us",
            "max_abs_err_vs_native", "model_pred_us", "hlo_concurrent",
            "hlo_concurrent_pairs"}

FAMILY_ROW_KEYS = {"family", "arch", "layer_elems", "extra_elems",
                   "num_layers", "num_blocks", "avg_us", "min_us",
                   "gather_exact", "hlo_concurrent"}

RECOVERY_TOP_KEYS = {"mesh", "smoke", "reps", "recovery",
                     "quorum_overhead", "ok"}

RECOVERY_KEYS = {"fault", "steps", "restart_step", "resume_step",
                 "steps_lost", "steps_replayed", "degraded_steps",
                 "clean_wall_s", "faulted_wall_s", "time_to_recover_s"}

QUORUM_KEYS = {"payload_elems", "num_buckets", "lane_min_us",
               "lane_quorum_min_us", "overhead_pct", "quorum_exact"}


def required_strategies() -> set:
    """The registry IS the requirement (never a hard-coded tuple)."""
    from repro.comm import strategies_for
    return set(strategies_for("grad_sync")) | {"auto"}


def required_families() -> set:
    """The block-stack registry IS the family requirement: a model family
    that silently loses its lane_zero3 registration (or its benchmark
    row) fails the build here."""
    from repro.models.blockstack import block_stack_families
    return set(block_stack_families())


REQUIRED_STRATEGIES = required_strategies()
REQUIRED_FAMILIES = required_families()


def check(doc: dict) -> list[str]:
    errs = []
    missing = TOP_KEYS - set(doc)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
    rows = doc.get("results", [])
    if not isinstance(rows, list) or not rows:
        errs.append("results must be a non-empty list")
        rows = []
    for i, row in enumerate(rows):
        mk = ROW_KEYS - set(row)
        if mk:
            errs.append(f"results[{i}] missing {sorted(mk)}")
    have = {r.get("strategy") for r in rows}
    gone = REQUIRED_STRATEGIES - have
    if gone:
        errs.append(f"benchmark stopped emitting strategies: {sorted(gone)}"
                    f" (registry + auto require "
                    f"{sorted(REQUIRED_STRATEGIES)}, have {sorted(have)})")
    stale = set(doc.get("strategies_registered", [])) - \
        (REQUIRED_STRATEGIES - {"auto"})
    if stale:
        errs.append(f"bench ran against a registry that no longer matches: "
                    f"{sorted(stale)} (re-run benchmarks.run --smoke)")
    frows = doc.get("family_results", [])
    if not isinstance(frows, list):
        frows = []
    for i, row in enumerate(frows):
        mk = FAMILY_ROW_KEYS - set(row)
        if mk:
            errs.append(f"family_results[{i}] missing {sorted(mk)}")
    fhave = {r.get("family") for r in frows}
    fgone = REQUIRED_FAMILIES - fhave
    if fgone:
        errs.append(f"benchmark stopped emitting zero3 family rows: "
                    f"{sorted(fgone)} (block_stack registry requires "
                    f"{sorted(REQUIRED_FAMILIES)}, have {sorted(fhave)})")
    fstale = set(doc.get("families_registered", [])) - REQUIRED_FAMILIES
    if fstale:
        errs.append(f"bench ran against a block-stack registry that no "
                    f"longer matches: {sorted(fstale)} (re-run "
                    f"benchmarks.run --smoke)")
    if not doc.get("structure_ok", False):
        errs.append("structure_ok is false: the §5 overlap (or a negative "
                    "control) regressed — see the benchmark output")
    return errs


def check_recovery(doc: dict) -> list[str]:
    errs = []
    missing = RECOVERY_TOP_KEYS - set(doc)
    if missing:
        errs.append(f"recovery missing top-level keys: {sorted(missing)}")
    mk = RECOVERY_KEYS - set(doc.get("recovery", {}))
    if mk:
        errs.append(f"recovery.recovery missing {sorted(mk)}")
    qk = QUORUM_KEYS - set(doc.get("quorum_overhead", {}))
    if qk:
        errs.append(f"recovery.quorum_overhead missing {sorted(qk)}")
    if not doc.get("ok", False):
        errs.append("recovery ok is false: the emergency checkpoint lost "
                    "steps, or full-quorum drifted from lane — see the "
                    "benchmark output")
    return errs


def _load(path: pathlib.Path):
    if not path.exists():
        print(f"SCHEMA FAIL: {path} missing (run benchmarks.run --smoke "
              f"first)")
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError as e:
        print(f"SCHEMA FAIL: {path} is not valid JSON: {e}")
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default="BENCH_gradsync.json")
    ap.add_argument("--recovery-file", default="BENCH_recovery.json")
    args = ap.parse_args(argv)
    doc = _load(pathlib.Path(args.file))
    if doc is None:
        return 1
    errs = check(doc)
    for e in errs:
        print(f"SCHEMA FAIL: {e}")
    if not errs:
        print(f"schema ok: {args.file} ({len(doc['results'])} rows, "
              f"{len({r['strategy'] for r in doc['results']})} strategies)")
    rdoc = _load(pathlib.Path(args.recovery_file))
    if rdoc is None:
        return 1
    rerrs = check_recovery(rdoc)
    for e in rerrs:
        print(f"SCHEMA FAIL: {e}")
    if not rerrs:
        r = rdoc["recovery"]
        print(f"schema ok: {args.recovery_file} (steps_lost="
              f"{r['steps_lost']}, recover={r['time_to_recover_s']}s, "
              f"quorum +{rdoc['quorum_overhead']['overhead_pct']}%)")
    return 1 if (errs or rerrs) else 0


if __name__ == "__main__":
    sys.exit(main())
