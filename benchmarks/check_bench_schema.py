"""Schema check for BENCH_gradsync.json, BENCH_recovery.json,
BENCH_serve.json and BENCH_tuning.json.

The benchmarks are the perf trajectory future PRs regress against; a
refactor that silently drops a strategy from the grid (or a field from
the rows) would make the trajectory lie by omission.  This check fails
the build instead.  The required-strategy list is DERIVED from the
repro.comm registry — every registered grad_sync strategy (plus the
``auto`` dispatch row) must appear, so an impl that quietly loses its
registration, or a registration the bench never exercises, both fail CI.
The recovery document (steps lost / time-to-recover / quorum overhead,
benchmarks/recovery_bench.py) is pinned the same way.

  PYTHONPATH=src python -m benchmarks.check_bench_schema [--file F]
      [--recovery-file R] [--serve-file S]

Run after ``benchmarks.run --smoke`` (make ci does).
"""
import argparse
import json
import pathlib
import sys

TOP_KEYS = {"mesh", "payload_elems", "payload_bytes", "auto_num_buckets",
            "strategies_registered", "tuning_cache", "cost_model",
            "smoke", "reps", "results", "family_results",
            "families_registered", "third_axis_results", "ep_wire",
            "hlo_per_computation", "structure_ok"}

ROW_KEYS = {"strategy", "selected", "num_buckets", "avg_us", "min_us",
            "max_abs_err_vs_native", "model_pred_us", "predicted_us",
            "hlo_concurrent", "hlo_concurrent_pairs"}

THIRD_AXIS_ROW_KEYS = {"cell", "strategy", "selected", "payload_bytes",
                       "avg_us", "min_us", "predicted_us",
                       "max_abs_err_vs_native"}

EP_WIRE_KEYS = {"arch", "num_experts", "capacity",
                "alltoall_bytes_per_layer",
                "expert_gather_bytes_per_layer", "ratio", "bound", "ok"}

TUNING_TOP_KEYS = {"topology", "tolerance", "measured_cells", "cells",
                   "violations", "fit", "ok"}

TUNING_CELL_KEYS = {"collective", "topo_sig", "payload_bytes", "native_us",
                    "best_decomposed_us", "best_strategy", "ratio",
                    "beats_native", "status"}

TUNING_FIT_KEYS = {"alpha_ici_s", "alpha_dcn_s", "ici_bw_Bps", "dcn_bw_Bps",
                   "residual_rms_us", "residual_max_us", "num_cells"}

FAMILY_ROW_KEYS = {"family", "arch", "layer_elems", "extra_elems",
                   "num_layers", "num_blocks", "avg_us", "min_us",
                   "gather_exact", "hlo_concurrent"}

RECOVERY_TOP_KEYS = {"mesh", "smoke", "reps", "recovery",
                     "quorum_overhead", "ok"}

SERVE_TOP_KEYS = {"mesh", "smoke", "max_seq", "families_registered",
                  "scenarios", "results", "zero3_identity", "ok"}

SERVE_ROW_KEYS = {"family", "arch", "scenario", "requests", "slots",
                  "decode_tokens", "tok_s", "ttft_ms_p50", "ttft_ms_p99",
                  "latency_ms_p50", "latency_ms_p99"}

RECOVERY_KEYS = {"fault", "steps", "restart_step", "resume_step",
                 "steps_lost", "steps_replayed", "degraded_steps",
                 "clean_wall_s", "faulted_wall_s", "time_to_recover_s"}

QUORUM_KEYS = {"payload_elems", "num_buckets", "lane_min_us",
               "lane_quorum_min_us", "overhead_pct", "quorum_exact"}


def required_strategies() -> set:
    """The registry IS the requirement (never a hard-coded tuple)."""
    from repro.comm import strategies_for
    return set(strategies_for("grad_sync")) | {"auto"}


def auto_eligible_strategies() -> set:
    """The strategies LaneComm.select can actually pick for grad_sync —
    auto_ok registrations with a cost model.  The measured-dispatch
    check below must restrict itself to these: a ZeRO row may well
    measure fastest, but auto can never select a layout-changing
    strategy, so holding the auto row to the unrestricted argmin would
    fail CI by construction."""
    from repro.comm import iter_impls
    return {e.strategy for e in iter_impls("grad_sync")
            if e.auto_ok and e.cost is not None}


def required_third_axis() -> set:
    """(cell, strategy) rows the third-parallelism-axis section must
    emit, derived from the registry: every registered moe_route strategy
    (the EP token-routing alltoall) and every registered allgather
    strategy (the TP activation collective), each plus the auto row."""
    from repro.comm import strategies_for
    return ({("moe_route", s)
             for s in (*strategies_for("moe_route"), "auto")}
            | {("tp_allgather", s)
               for s in (*strategies_for("allgather"), "auto")})


def required_families() -> set:
    """The block-stack registry IS the family requirement: a model family
    that silently loses its lane_zero3 registration (or its benchmark
    row) fails the build here."""
    from repro.models.blockstack import block_stack_families
    return set(block_stack_families())


def required_serve_families() -> set:
    """The serve_scenario registry IS the serving-family requirement —
    importing repro.serve registers it (vlm/audio serve even though the
    training driver cannot train them)."""
    from repro.comm import strategies_for
    import repro.serve  # noqa: F401 - registers serve_scenario cells
    return set(strategies_for("serve_scenario"))


REQUIRED_STRATEGIES = required_strategies()
REQUIRED_THIRD_AXIS = required_third_axis()
AUTO_ELIGIBLE = auto_eligible_strategies()
REQUIRED_FAMILIES = required_families()
REQUIRED_SERVE_FAMILIES = required_serve_families()


def check(doc: dict) -> list[str]:
    errs = []
    missing = TOP_KEYS - set(doc)
    if missing:
        errs.append(f"missing top-level keys: {sorted(missing)}")
    rows = doc.get("results", [])
    if not isinstance(rows, list) or not rows:
        errs.append("results must be a non-empty list")
        rows = []
    for i, row in enumerate(rows):
        mk = ROW_KEYS - set(row)
        if mk:
            errs.append(f"results[{i}] missing {sorted(mk)}")
    have = {r.get("strategy") for r in rows}
    gone = REQUIRED_STRATEGIES - have
    if gone:
        errs.append(f"benchmark stopped emitting strategies: {sorted(gone)}"
                    f" (registry + auto require "
                    f"{sorted(REQUIRED_STRATEGIES)}, have {sorted(have)})")
    stale = set(doc.get("strategies_registered", [])) - \
        (REQUIRED_STRATEGIES - {"auto"})
    if stale:
        errs.append(f"bench ran against a registry that no longer matches: "
                    f"{sorted(stale)} (re-run benchmarks.run --smoke)")
    frows = doc.get("family_results", [])
    if not isinstance(frows, list):
        frows = []
    for i, row in enumerate(frows):
        mk = FAMILY_ROW_KEYS - set(row)
        if mk:
            errs.append(f"family_results[{i}] missing {sorted(mk)}")
    fhave = {r.get("family") for r in frows}
    fgone = REQUIRED_FAMILIES - fhave
    if fgone:
        errs.append(f"benchmark stopped emitting zero3 family rows: "
                    f"{sorted(fgone)} (block_stack registry requires "
                    f"{sorted(REQUIRED_FAMILIES)}, have {sorted(fhave)})")
    fstale = set(doc.get("families_registered", [])) - REQUIRED_FAMILIES
    if fstale:
        errs.append(f"bench ran against a block-stack registry that no "
                    f"longer matches: {sorted(fstale)} (re-run "
                    f"benchmarks.run --smoke)")
    trows = doc.get("third_axis_results", [])
    if not isinstance(trows, list):
        trows = []
    for i, row in enumerate(trows):
        mk = THIRD_AXIS_ROW_KEYS - set(row)
        if mk:
            errs.append(f"third_axis_results[{i}] missing {sorted(mk)}")
    thave = {(r.get("cell"), r.get("strategy")) for r in trows}
    tgone = REQUIRED_THIRD_AXIS - thave
    if tgone:
        errs.append(f"benchmark stopped emitting third-axis cells: "
                    f"{sorted(tgone)} (moe_route/allgather registries + "
                    f"auto require {sorted(REQUIRED_THIRD_AXIS)})")
    wire = doc.get("ep_wire", {})
    wk = EP_WIRE_KEYS - set(wire)
    if wk:
        errs.append(f"ep_wire missing {sorted(wk)}")
    elif not wire.get("ok", False):
        errs.append(f"ep_wire ok is false: per-layer routing-alltoall "
                    f"bytes ({wire.get('alltoall_bytes_per_layer')}) "
                    f"exceed 2/E of the replaced expert-gather bytes "
                    f"({wire.get('expert_gather_bytes_per_layer')}) — "
                    f"ratio {wire.get('ratio')} > bound "
                    f"{wire.get('bound')}")
    if not doc.get("structure_ok", False):
        errs.append("structure_ok is false: the §5 overlap (or a negative "
                    "control) regressed — see the benchmark output")
    if doc.get("tuning_cache"):
        # measured dispatch: with a timing cache the auto row must have
        # selected the argmin of the MEASURED predictions among the
        # auto-eligible rows (predicted_us carries the cache's median
        # for exactly the cells select() ranked)
        auto_rows = [r for r in rows if r.get("strategy") == "auto"]
        eligible = [r for r in rows
                    if r.get("strategy") in AUTO_ELIGIBLE
                    and r.get("predicted_us") is not None]
        if auto_rows and eligible:
            best = min(eligible, key=lambda r: r["predicted_us"])
            for r in auto_rows:
                if r.get("selected") != best["strategy"]:
                    errs.append(
                        f"tuning cache present but the auto row selected "
                        f"{r.get('selected')!r}, not the measured argmin "
                        f"{best['strategy']!r} "
                        f"({best['predicted_us']} us) — measured costs "
                        f"are not driving dispatch")
        elif auto_rows:
            errs.append("tuning cache present but no auto-eligible row "
                        "carries a predicted_us to check the auto "
                        "selection against")
    return errs


def check_tuning(doc: dict) -> list[str]:
    """BENCH_tuning.json: the probe→fit→guideline report."""
    errs = []
    missing = TUNING_TOP_KEYS - set(doc)
    if missing:
        errs.append(f"tuning missing top-level keys: {sorted(missing)}")
    cells = doc.get("cells", [])
    if not isinstance(cells, list) or not cells:
        errs.append("tuning cells must be a non-empty list")
        cells = []
    for i, c in enumerate(cells):
        mk = TUNING_CELL_KEYS - set(c)
        if mk:
            errs.append(f"tuning cells[{i}] missing {sorted(mk)}")
        if c.get("status") not in ("ok", "violation"):
            errs.append(f"tuning cells[{i}] bad status {c.get('status')!r}")
    fk = TUNING_FIT_KEYS - set(doc.get("fit", {}))
    if fk:
        errs.append(f"tuning fit missing {sorted(fk)}")
    viol = [c for c in cells if c.get("status") == "violation"]
    if len(viol) != doc.get("violations"):
        errs.append(f"tuning violations count {doc.get('violations')} "
                    f"disagrees with the cells ({len(viol)})")
    if viol or not doc.get("ok", False):
        errs.append(
            f"tuning guideline violations: {len(viol)} cell(s) where the "
            f"best decomposed time exceeds tolerance× native — see "
            f"BENCH_tuning.json")
    return errs


def check_recovery(doc: dict) -> list[str]:
    errs = []
    missing = RECOVERY_TOP_KEYS - set(doc)
    if missing:
        errs.append(f"recovery missing top-level keys: {sorted(missing)}")
    mk = RECOVERY_KEYS - set(doc.get("recovery", {}))
    if mk:
        errs.append(f"recovery.recovery missing {sorted(mk)}")
    qk = QUORUM_KEYS - set(doc.get("quorum_overhead", {}))
    if qk:
        errs.append(f"recovery.quorum_overhead missing {sorted(qk)}")
    if not doc.get("ok", False):
        errs.append("recovery ok is false: the emergency checkpoint lost "
                    "steps, or full-quorum drifted from lane — see the "
                    "benchmark output")
    return errs


def check_serve(doc: dict) -> list[str]:
    errs = []
    missing = SERVE_TOP_KEYS - set(doc)
    if missing:
        errs.append(f"serve missing top-level keys: {sorted(missing)}")
    rows = doc.get("results", [])
    if not isinstance(rows, list) or not rows:
        errs.append("serve results must be a non-empty list")
        rows = []
    for i, row in enumerate(rows):
        mk = SERVE_ROW_KEYS - set(row)
        if mk:
            errs.append(f"serve results[{i}] missing {sorted(mk)}")
    have = {r.get("family") for r in rows}
    gone = REQUIRED_SERVE_FAMILIES - have
    if gone:
        errs.append(f"serve bench stopped emitting families: "
                    f"{sorted(gone)} (serve_scenario registry requires "
                    f"{sorted(REQUIRED_SERVE_FAMILIES)}, have "
                    f"{sorted(have)})")
    stale = set(doc.get("families_registered", [])) - \
        REQUIRED_SERVE_FAMILIES
    if stale:
        errs.append(f"serve bench ran against a registry that no longer "
                    f"matches: {sorted(stale)} (re-run "
                    f"benchmarks.serve_bench --smoke)")
    if not doc.get("zero3_identity", False):
        errs.append("zero3_identity is false: zero3-hosted serving "
                    "diverged from replicated tokens — see the benchmark "
                    "output")
    if not doc.get("ok", False):
        errs.append("serve ok is false — see the benchmark output")
    return errs


def _load(path: pathlib.Path):
    if not path.exists():
        print(f"SCHEMA FAIL: {path} missing (run benchmarks.run --smoke "
              f"first)")
        return None
    try:
        return json.loads(path.read_text())
    except json.JSONDecodeError as e:
        print(f"SCHEMA FAIL: {path} is not valid JSON: {e}")
        return None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default="BENCH_gradsync.json")
    ap.add_argument("--recovery-file", default="BENCH_recovery.json")
    ap.add_argument("--serve-file", default="BENCH_serve.json")
    ap.add_argument("--tuning-file", default="BENCH_tuning.json")
    args = ap.parse_args(argv)
    doc = _load(pathlib.Path(args.file))
    if doc is None:
        return 1
    errs = check(doc)
    for e in errs:
        print(f"SCHEMA FAIL: {e}")
    if not errs:
        print(f"schema ok: {args.file} ({len(doc['results'])} rows, "
              f"{len({r['strategy'] for r in doc['results']})} strategies)")
    rdoc = _load(pathlib.Path(args.recovery_file))
    if rdoc is None:
        return 1
    rerrs = check_recovery(rdoc)
    for e in rerrs:
        print(f"SCHEMA FAIL: {e}")
    if not rerrs:
        r = rdoc["recovery"]
        print(f"schema ok: {args.recovery_file} (steps_lost="
              f"{r['steps_lost']}, recover={r['time_to_recover_s']}s, "
              f"quorum +{rdoc['quorum_overhead']['overhead_pct']}%)")
    sdoc = _load(pathlib.Path(args.serve_file))
    if sdoc is None:
        return 1
    serrs = check_serve(sdoc)
    for e in serrs:
        print(f"SCHEMA FAIL: {e}")
    if not serrs:
        fams = {r["family"] for r in sdoc["results"]}
        print(f"schema ok: {args.serve_file} "
              f"({len(sdoc['results'])} rows, {len(fams)} families, "
              f"zero3_identity={sdoc['zero3_identity']})")
    tdoc = _load(pathlib.Path(args.tuning_file))
    if tdoc is None:
        return 1
    terrs = check_tuning(tdoc)
    for e in terrs:
        print(f"SCHEMA FAIL: {e}")
    if not terrs:
        print(f"schema ok: {args.tuning_file} "
              f"({len(tdoc['cells'])} cells, "
              f"{tdoc['measured_cells']} measured, "
              f"violations={tdoc['violations']}, "
              f"fit rms={tdoc['fit']['residual_rms_us']}us)")
    return 1 if (errs or rerrs or serrs or terrs) else 0


if __name__ == "__main__":
    sys.exit(main())
