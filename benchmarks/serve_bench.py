"""Serving benchmark: throughput + latency percentiles per family.

Drives the REAL continuous-batching engine (repro.serve) over the
registry-derived scenario generator on the 8-device CPU mesh and writes
``BENCH_serve.json`` — the serving-side perf trajectory future PRs
regress against (schema pinned by ``benchmarks/check_bench_schema.py``):

  * one row per (family × scenario kind): decode tok/s, time-to-first-
    token p50/p99 and request latency p50/p99, over the engine's own
    per-request records;
  * ``zero3_identity``: the headline correctness bit — zero3-hosted
    serving (1/p gathered weights, sharded slots, kv_splice
    distribution) produced byte-identical tokens to replicated hosting
    on the same scenario.

The family list is DERIVED from the serve_scenario registry: a family
that silently loses its serving registration fails the schema check,
not just this bench.  CPU caveat as everywhere in benchmarks/: wall
times validate relative behavior, not datacenter physics.

  PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] [--out F]
"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import pathlib
import sys

import numpy as np
import jax

MAX_SEQ = 96


def _pct(vals, q):
    vals = [v for v in vals if v is not None]
    return round(float(np.percentile(vals, q)), 3) if vals else None


def _bench_family(family, arch, kinds, n, slots):
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, make_scenario
    cfg = resolve(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rows = []
    for kind in kinds:
        reqs = make_scenario(cfg, kind=kind, n=n, seed=17,
                             max_seq=MAX_SEQ)
        eng = ContinuousBatcher(params, cfg, slots=slots,
                                max_seq=MAX_SEQ)
        done, stats = eng.run(reqs)
        recs = stats["requests"]
        assert all(r.done for r in done), (family, kind)
        rows.append({
            "family": family, "arch": arch, "scenario": kind,
            "requests": len(done), "slots": slots,
            "decode_tokens": stats["decode_tokens"],
            "tok_s": round(stats["tok_per_s"], 2),
            "ttft_ms_p50": _pct([r["ttft_ms"] for r in recs], 50),
            "ttft_ms_p99": _pct([r["ttft_ms"] for r in recs], 99),
            "latency_ms_p50": _pct([r["latency_ms"] for r in recs], 50),
            "latency_ms_p99": _pct([r["latency_ms"] for r in recs], 99),
        })
        print(f"{family:8s} {kind:13s} {rows[-1]['tok_s']:8.2f} tok/s  "
              f"ttft p50 {rows[-1]['ttft_ms_p50']} ms  "
              f"latency p99 {rows[-1]['latency_ms_p99']} ms")
    return rows


def _zero3_identity(arch, n):
    """zero3-hosted tokens == replicated tokens on the same scenario."""
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, make_scenario
    cfg = resolve(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2, 2, 2),
                             ("pod", "data", "model"))
    outs = []
    for hosting, kw in (("replicated", {}),
                        ("lane_zero3", {"mesh": mesh})):
        eng = ContinuousBatcher(params, cfg, slots=8, max_seq=MAX_SEQ,
                                hosting=hosting, **kw)
        done, _ = eng.run(make_scenario(cfg, kind="short_chat", n=n,
                                        seed=17, max_seq=MAX_SEQ))
        outs.append({r.rid: r.out for r in done})
    return outs[0] == outs[1]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: one scenario kind per family")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    from repro.serve import SCENARIO_KINDS, scenario_families
    from repro.models.blockstack import family_smoke_archs
    archs = family_smoke_archs()
    families = scenario_families()
    kinds = ("short_chat",) if args.smoke else SCENARIO_KINDS
    n = 5 if args.smoke else 12
    slots = 4

    results = []
    for family in sorted(families):
        results.extend(_bench_family(family, archs[family], kinds, n,
                                     slots))
    ident = _zero3_identity(archs["dense"], n)
    print(f"zero3_identity: {ident}")

    doc = {
        "mesh": "host8(2,2,2)",
        "smoke": bool(args.smoke),
        "max_seq": MAX_SEQ,
        "families_registered": sorted(families),
        "scenarios": list(kinds),
        "results": results,
        "zero3_identity": bool(ident),
        "ok": bool(ident) and all(r["decode_tokens"] > 0
                                  for r in results),
    }
    pathlib.Path(args.out).write_text(json.dumps(doc, indent=1))
    print(f"wrote {args.out} ({len(results)} rows)")
    return 0 if doc["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
