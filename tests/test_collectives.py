"""Multi-device collective correctness: every full-lane mock-up (paper
Listings 1-6), the §5 pipelined construction, gradsync strategies, and the
straggler quorum — each vs its single-process oracle, on an 8-device CPU
mesh in a subprocess (the parent process keeps 1 device)."""
import subprocess
import sys

import pytest

from repro.testing import collective_cases


def _run_all():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.run_collective_cases"],
        capture_output=True, text=True, timeout=1200)
    results = {}
    for line in proc.stdout.splitlines():
        if line.startswith(("PASS ", "FAIL ")):
            status, rest = line.split(" ", 1)
            name = rest.split(":")[0].strip()
            results[name] = (status, line)
    return results


_RESULTS = None


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = _run_all()
    return _RESULTS


@pytest.mark.parametrize("case", sorted(collective_cases.CASES))
def test_collective_case(case):
    res = _results()
    assert case in res, f"case {case} produced no result (crash?)"
    status, line = res[case]
    assert status == "PASS", line
