"""Numerics: blocked attention vs naive, banded SWA, distributed-decode
math, SSD chunked vs sequential recurrence, MoE routing conservation."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models.attention import attention_xla, decode_attention
from repro.models.ssm import ssd_chunked, ssd_decode_step
from repro.models import moe as M
from repro.kernels import ref as kref
from repro.configs import resolve


def _mk(B, H, K, Tq, Tk, hd, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Tq, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tk, K, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tk, K, hd)), jnp.float32)
    return q, k, v


def _to_ref(x):
    return jnp.swapaxes(x, 1, 2)    # (B,T,H,hd) → (B,H,T,hd)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 32), (37, 64)])
def test_attention_xla_matches_naive(causal, blocks):
    bq, bk = blocks
    q, k, v = _mk(2, 4, 2, 128, 128, 32)
    out = attention_xla(q, k, v, causal=causal, block_q=bq, block_k=bk)
    want = kref.attention_ref(_to_ref(q), _to_ref(k), _to_ref(v),
                              causal=causal)
    np.testing.assert_allclose(np.asarray(_to_ref(out)), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [16, 48, 100])
def test_banded_swa_matches_masked(window):
    q, k, v = _mk(1, 4, 2, 128, 128, 32)
    out = attention_xla(q, k, v, causal=True, window=window, block_q=32)
    want = kref.attention_ref(_to_ref(q), _to_ref(k), _to_ref(v),
                              causal=True, window=window)
    np.testing.assert_allclose(np.asarray(_to_ref(out)), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_matches_full_attention():
    """decode_attention(q1, cache) == last row of full causal attention."""
    B, H, K, T, hd = 2, 4, 2, 64, 32
    q, k, v = _mk(B, H, K, T, T, hd)
    full = attention_xla(q, k, v, causal=True, block_q=32)
    out = decode_attention(q[:, -1:], k, v,
                           jnp.full((B,), T, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_respects_length_mask():
    B, H, K, T, hd = 1, 2, 2, 32, 16
    q, k, v = _mk(B, H, K, T, T, hd)
    short = decode_attention(q[:, -1:], k, v, jnp.full((B,), 10, jnp.int32))
    trunc = decode_attention(q[:, -1:], k[:, :10], v[:, :10],
                             jnp.full((B,), 10, jnp.int32))
    np.testing.assert_allclose(np.asarray(short), np.asarray(trunc),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,chunk", [(64, 16), (64, 64), (96, 32), (50, 16)])
def test_ssd_chunked_matches_recurrence(T, chunk):
    rng = np.random.default_rng(2)
    b, H, P, S, G = 2, 4, 16, 24, 1
    x = jnp.asarray(rng.normal(size=(b, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, T, G, S)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, T, G, S)), jnp.float32)
    y, state = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    want = kref.ssd_ref(jnp.swapaxes(x, 1, 2),
                        jnp.moveaxis(dt, 1, 2), A, Bm[:, :, 0], Cm[:, :, 0])
    np.testing.assert_allclose(np.asarray(jnp.swapaxes(y, 1, 2)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_prefill():
    """prefill(T) state + decode(1) == prefill(T+1) last output."""
    rng = np.random.default_rng(3)
    b, T, H, P, S, G = 1, 32, 2, 8, 16, 1
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    x = mk(b, T + 1, H, P)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, T + 1, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.2, 2.0, size=(H,)), jnp.float32)
    Bm, Cm = mk(b, T + 1, G, S), mk(b, T + 1, G, S)
    y_all, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=16)
    _, state = ssd_chunked(x[:, :T], dt[:, :T], A, Bm[:, :T], Cm[:, :T],
                           chunk=16)
    y1, _ = ssd_decode_step(state, x[:, T], dt[:, T], A, Bm[:, T], Cm[:, T])
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_all[:, T]),
                               rtol=2e-4, atol=2e-4)


def test_moe_routing_weights_sum():
    """Kept tokens' routing weights renormalize to ≤1 and the layer output
    is a convex combination of expert outputs (capacity drops reduce it)."""
    cfg = resolve("granite-moe-3b-a800m", smoke=True)
    rng = np.random.default_rng(4)
    params = M.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    out, aux = M.moe_block(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) >= 0.99   # Switch aux loss ≥ 1 at uniform routing


def test_moe_capacity_overflow_drops_gracefully():
    import dataclasses
    cfg = dataclasses.replace(resolve("dbrx-132b", smoke=True),
                              moe_capacity_factor=0.25)
    params = M.init_moe(jax.random.PRNGKey(1), cfg)
    x = jnp.ones((1, 32, cfg.d_model), jnp.float32)   # all tokens identical
    out, _ = M.moe_block(params, x, cfg)              # severe overflow
    assert np.isfinite(np.asarray(out)).all()
