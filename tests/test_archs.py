"""Per-arch smoke: reduced config of the same family, one forward + one
train-grad + prefill/decode on CPU; output shapes + finiteness + decode↔
forward consistency (teacher forcing)."""
import pytest

from repro.configs import all_archs, resolve, cells, SHAPES
from repro.testing.model_smoke import smoke_arch


@pytest.mark.parametrize("arch", all_archs())
def test_arch_smoke(arch):
    info = smoke_arch(arch)
    assert info["params"] > 0


def test_ten_archs_assigned():
    assert len(all_archs()) == 10


@pytest.mark.parametrize("arch", all_archs())
def test_cells_assignment(arch):
    cfg = resolve(arch)
    cs = cells(arch)
    assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cs)
    if cfg.subquadratic:
        assert "long_500k" in cs
    else:
        assert "long_500k" not in cs


def test_exact_published_configs():
    q = resolve("qwen1.5-110b")
    assert (q.num_layers, q.d_model, q.num_heads, q.num_kv_heads,
            q.d_ff, q.vocab_size) == (80, 8192, 64, 8, 49152, 152064)
    assert q.qkv_bias
    d = resolve("dbrx-132b")
    assert (d.num_experts, d.experts_per_token) == (16, 4)
    g = resolve("granite-moe-3b-a800m")
    assert (g.num_experts, g.experts_per_token) == (40, 8)
    m = resolve("mamba2-780m")
    assert (m.ssm_state, m.num_layers, m.d_model) == (128, 48, 1536)
    z = resolve("zamba2-7b")
    assert (z.hybrid_attn_every, z.ssm_state) == (6, 64)
    gr = resolve("granite-34b")
    assert (gr.num_kv_heads, gr.num_layers) == (1, 88)
    w = resolve("whisper-large-v3")
    assert (w.encoder_layers, w.encoder_seq, w.vocab_size) == (32, 1500, 51866)
    h = resolve("h2o-danube-3-4b")
    assert h.sliding_window > 0 and h.d_model == 3840
    lv = resolve("llava-next-mistral-7b")
    assert lv.vision_tokens == 576
    ll = resolve("llama3.2-3b")
    assert ll.tie_embeddings and ll.vocab_size == 128256
