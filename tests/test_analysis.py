"""Tier-1 coverage of lanelint (repro.analysis).

Everything here is device-free: the HLO-layer tests run the rule
machinery over hand-written adversarial HLO fixtures (the full
registry-cell sweep lowers on 8 host devices and runs under ``make
lint``, not tier-1), and the AST layer is pure stdlib ``ast``.  Under
test:

  * the footprint classifier (node/lane/global/mixed) and its wire-byte
    conventions, trip correction included;
  * scan-carried concurrency: the carry-position/GTE-index disjointness
    proof, positive AND negative;
  * R1/R2/R4 on fixtures built to violate them — including the R4
    negative-control contract (a concurrent "blocking" cell is itself a
    finding);
  * A1–A4 on synthetic modules, plus the real repo staying AST-clean;
  * the baseline suppression file: round-trip, reason enforcement,
    stale detection;
  * the CLI exit-code contract: 0 clean / 1 findings / 2 internal error.
"""
import json

import pytest

from repro.analysis import (
    ERROR, Finding, apply_baseline, comm_footprint, format_findings,
    load_baseline, parse_hlo, save_baseline, scan_carried_concurrency,
)
from repro.analysis.footprint import analyze, classify_group, \
    collective_concurrency
from repro.analysis.rules import (
    CellCase, R2_ABS_TOL, check_r1, check_r2, check_r4,
)


# ---------------------------------------------------------------------------
# HLO fixtures (n=4 pods of 4, p=8 unless noted)
# ---------------------------------------------------------------------------

EMPTY_HLO = "HloModule empty\n"

# one op per level under n=4: global (covers all 8), node (one pod),
# lane (one member per pod), mixed (straddles without covering)
LEVELS_HLO = """HloModule levels

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
  %agn = f32[1024]{0} all-gather(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %agl = f32[1024]{0} all-gather(%agn), replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
  ROOT %agm = f32[1024]{0} all-gather(%agl), replica_groups={{0,1,4,5},{2,3,6,7}}, dimensions={0}
}
"""

# the R1 scalar exemption: a tiny mixed op (16B) next to a big one
SMALL_MIXED_HLO = """HloModule small

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  ROOT %ar = f32[4]{0} all-reduce(%p0), replica_groups={{0,1,4,5},{2,3,6,7}}, to_apply=%add
}
"""

# a 5-trip scan whose body holds one node reduce-scatter (ICI) feeding
# one cross-pod collective-permute (DCN); the DCN hop lands in carry
# position 1 while the ICI op reads only carry position 0 — the §5
# scan-carried shape (serial WITHIN the body, concurrent ACROSS steps)
CARRIED_HLO = """HloModule pipe

%body (p: (f32[16], f32[16])) -> (f32[16], f32[16]) {
  %p = (f32[16]{0}, f32[16]{0}) parameter(0)
  %gte0 = f32[16]{0} get-tuple-element(%p), index=0
  %gte1 = f32[16]{0} get-tuple-element(%p), index=1
  %rs = f32[16]{0} reduce-scatter(%gte0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[16]{0} collective-permute(%rs), source_target_pairs={{0,4},{1,5},{2,6},{3,7},{4,0},{5,1},{6,2},{7,3}}
  ROOT %t = (f32[16]{0}, f32[16]{0}) tuple(%gte0, %cp)
}

ENTRY %main (a: (f32[16], f32[16])) -> (f32[16], f32[16]) {
  %a = (f32[16]{0}, f32[16]{0}) parameter(0)
  ROOT %w = (f32[16]{0}, f32[16]{0}) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""

# same body, but the DCN hop feeds the SAME carry position the ICI op
# reads (position 0): iteration t+1's ICI phase needs iteration t's DCN
# result — strictly serial, no scan-carried pair may be claimed
SERIAL_HLO = """HloModule serial

%body (p: (f32[16], f32[16])) -> (f32[16], f32[16]) {
  %p = (f32[16]{0}, f32[16]{0}) parameter(0)
  %gte0 = f32[16]{0} get-tuple-element(%p), index=0
  %gte1 = f32[16]{0} get-tuple-element(%p), index=1
  %rs = f32[16]{0} reduce-scatter(%gte0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[16]{0} collective-permute(%rs), source_target_pairs={{0,4},{1,5},{2,6},{3,7},{4,0},{5,1},{6,2},{7,3}}
  ROOT %t = (f32[16]{0}, f32[16]{0}) tuple(%cp, %gte1)
}

ENTRY %main (a: (f32[16], f32[16])) -> (f32[16], f32[16]) {
  %a = (f32[16]{0}, f32[16]{0}) parameter(0)
  ROOT %w = (f32[16]{0}, f32[16]{0}) while(%a), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
}
"""

# within-computation independence: DCN and ICI ops with no def-use edge
WITHIN_HLO = """HloModule within

ENTRY %main (p0: f32[64], p1: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %p1 = f32[64]{0} parameter(1)
  %agn = f32[64]{0} all-gather(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %agl = f32[64]{0} all-gather(%p1), replica_groups={{0,4},{1,5},{2,6},{3,7}}, dimensions={0}
  ROOT %s = f32[64]{0} add(%agn, %agl)
}
"""

# a lowering that moves HALF the volume the closed form says (R2 bait):
# native allreduce of c=4096B must move 2*(p-1)/p*c = 7168B globally
HALF_VOLUME_HLO = """HloModule half

ENTRY %main (p0: f32[512]) -> f32[512] {
  %p0 = f32[512]{0} parameter(0)
  ROOT %ar = f32[512]{0} all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""

FULL_VOLUME_HLO = """HloModule full

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %ar = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add
}
"""


# ---------------------------------------------------------------------------
# footprint: parsing, classification, wire conventions, trip correction
# ---------------------------------------------------------------------------

def test_empty_module_raises():
    comps = parse_hlo(EMPTY_HLO)
    assert comps["__entry__"] is None
    with pytest.raises(ValueError, match="no ENTRY"):
        comm_footprint(EMPTY_HLO, n=4)
    with pytest.raises(ValueError, match="no ENTRY"):
        analyze(EMPTY_HLO, pod_size=4)


def test_classify_group():
    n = 4
    assert classify_group((0, 1, 2, 3), n=n, num_devices=8) == "node"
    assert classify_group((4,), n=n, num_devices=8) == "node"
    assert classify_group((0, 4), n=n, num_devices=8) == "lane"
    assert classify_group(range(8), n=n, num_devices=8) == "global"
    assert classify_group((0, 1, 4, 5), n=n, num_devices=8) == "mixed"
    assert classify_group((), n=n, num_devices=8) == "global"


def test_footprint_levels_and_wire_conventions():
    foot = comm_footprint(LEVELS_HLO, n=4, num_devices=8)
    assert len(foot) == 4
    by = {o.name: o for o in foot.ops}
    assert by["ar"].level == "global"
    assert by["agn"].level == "node"
    assert by["agl"].level == "lane"
    assert by["agm"].level == "mixed"
    # all-reduce 2(g-1)/g * result; all-gather (g-1)/g * result
    assert by["ar"].wire_bytes == pytest.approx(2 * 7 / 8 * 4096)
    assert by["agn"].wire_bytes == pytest.approx(3 / 4 * 4096)
    assert by["agl"].wire_bytes == pytest.approx(1 / 2 * 4096)
    lv = foot.by_level()
    assert lv["global"] == pytest.approx(7168)
    assert foot.mixed() == (by["agm"],)
    assert set(foot.levels()) == {"node", "lane", "global", "mixed"}


def test_footprint_trip_correction():
    foot = comm_footprint(CARRIED_HLO, n=4, num_devices=8)
    by = {o.name: o for o in foot.ops}
    # both body collectives execute known_trip_count = 5 times
    assert by["rs"].count == 5 and by["cp"].count == 5
    # reduce-scatter: (g-1) * SHARD bytes; permute: one hop, whole buf
    assert by["rs"].wire_bytes == pytest.approx(3 * 64)
    assert by["cp"].wire_bytes == pytest.approx(64)
    assert foot.by_level()["node"] == pytest.approx(5 * 3 * 64)
    assert foot.by_level()["lane"] == pytest.approx(5 * 64)


def test_footprint_infers_num_devices():
    foot = comm_footprint(LEVELS_HLO, n=4)      # p inferred from groups
    assert foot.num_devices == 8
    assert {o.level for o in foot.ops} == \
        {"node", "lane", "global", "mixed"}


# ---------------------------------------------------------------------------
# concurrency proofs: within-body and scan-carried
# ---------------------------------------------------------------------------

def test_scan_carried_positive():
    res = scan_carried_concurrency(CARRIED_HLO, pod_size=4)
    assert res["concurrent"]
    (body, dcn, dkind, ici, ikind), = res["pairs"]
    assert body == "body" and dcn == "cp" and ici == "rs"
    assert dkind == "collective-permute" and ikind == "reduce-scatter"


def test_scan_carried_negative_serial():
    # the DCN hop feeds the carry element the ICI op reads: no pair
    assert not scan_carried_concurrency(SERIAL_HLO, pod_size=4)["concurrent"]
    # and within the body the permute consumes the scatter: no pair there
    assert not collective_concurrency(SERIAL_HLO, pod_size=4)["concurrent"]
    # a module with no while loop at all can never be scan-carried
    assert not scan_carried_concurrency(WITHIN_HLO, pod_size=4)["concurrent"]


def test_within_body_independence():
    res = collective_concurrency(WITHIN_HLO, pod_size=4)
    assert res["concurrent"]
    assert any(d == "agl" and i == "agn" or d == "agl" and i == "agn"
               for _, d, _, i, _ in res["pairs"])


# ---------------------------------------------------------------------------
# the rules on adversarial fixtures
# ---------------------------------------------------------------------------

def test_r1_flags_mixed_and_decomposed_global():
    foot = comm_footprint(LEVELS_HLO, n=4, num_devices=8)
    out = check_r1("cell@n4xN2", foot, decomposed=False)
    assert [f.rule for f in out] == ["R1"]
    assert "straddles" in out[0].message
    # a decomposed strategy additionally may not lower global collectives
    out = check_r1("cell@n4xN2", foot, decomposed=True)
    assert len(out) == 2
    assert any("global" in f.message for f in out)


def test_r1_scalar_exemption():
    foot = comm_footprint(SMALL_MIXED_HLO, n=4, num_devices=8)
    assert foot.mixed()                     # the op IS mixed ...
    assert check_r1("cell", foot, decomposed=True) == []   # ... but tiny


def test_r2_payload_conservation():
    case = CellCase("allreduce", "native", 4, 2, 4096)
    good = comm_footprint(FULL_VOLUME_HLO, n=4, num_devices=8)
    assert check_r2(case, good) == []
    bad = comm_footprint(HALF_VOLUME_HLO, n=4, num_devices=8)
    out = check_r2(case, bad)
    assert [f.rule for f in out] == ["R2"]
    assert "3584" in out[0].message and "7168" in out[0].message
    assert R2_ABS_TOL < 7168 - 3584        # the gap is a real finding


def test_r4_pipelined_and_negative_control():
    pipe = CellCase("bcast", "lane_pipelined", 4, 2, 4096)
    ctrl = CellCase("prefetch_allgather", "blocking", 4, 2, 4096)
    # pipelined cell with the carried shape: clean
    assert check_r4(pipe, CARRIED_HLO, expect_overlap=True) == []
    # pipelined cell gone serial: finding
    out = check_r4(pipe, SERIAL_HLO, expect_overlap=True)
    assert [f.rule for f in out] == ["R4"]
    assert "NO concurrent" in out[0].message
    # the blocking control staying serial: clean
    assert check_r4(ctrl, SERIAL_HLO, expect_overlap=False) == []
    # a CONCURRENT control is a finding against the rule itself
    out = check_r4(ctrl, CARRIED_HLO, expect_overlap=False)
    assert [f.rule for f in out] == ["R4"]
    assert "vacuous" in out[0].message


def test_closed_form_volumes_match_dump_verified_values():
    """lowered_wire_volumes pins the dump-verified (n=4, N=2, c=4096)
    per-level algebra R2 compares against."""
    from repro.comm.costs import assumed_volumes, lowered_wire_volumes
    kw = dict(n=4, N=2, payload_bytes=4096)
    assert lowered_wire_volumes("allreduce", "native", **kw) == \
        {"global": pytest.approx(7168)}
    assert lowered_wire_volumes("allreduce", "lane", **kw) == \
        {"node": pytest.approx(6144), "lane": pytest.approx(1024)}
    v = lowered_wire_volumes("reduce_scatter", "lane", **kw)
    assert v["node"] == pytest.approx(3 / 4 * 4096)
    assert v["lane"] == pytest.approx(4096 / 8)
    # cells without a cost model opt out of R3 entirely
    assert assumed_volumes("bcast", "lane_pipelined", num_blocks=4,
                           **kw) is None
    got = assumed_volumes("allreduce", "lane", **kw)
    assert got is not None
    vols, bound = got
    assert bound >= 1.0 and set(vols) <= {"node", "lane", "total"}


# ---------------------------------------------------------------------------
# diagnostics + baseline
# ---------------------------------------------------------------------------

def test_finding_key_and_format():
    a = Finding("R2", "allreduce/lane@n4xN2", "volume off", ERROR)
    b = Finding("A2", "src/repro/x.py#assert", "bare assert",
                severity="warning")
    assert a.key == "R2:allreduce/lane@n4xN2"
    txt = format_findings([b, a])
    lines = txt.splitlines()
    assert lines[0].startswith("ERROR R2")     # errors first
    assert lines[1].startswith("WARNING A2")


def test_baseline_roundtrip_and_stale(tmp_path):
    path = str(tmp_path / "baseline.json")
    f1 = Finding("R2", "cell/a", "m1")
    f2 = Finding("A1", "src/x.py#psum", "m2")
    save_baseline([f1, f2], path)
    base = load_baseline(path)
    assert set(base) == {f1.key, f2.key}
    # suppression + stale detection
    unsup, stale = apply_baseline([f1], base)
    assert unsup == [] and stale == [f2.key]
    f3 = Finding("R3", "cell/b", "m3")
    unsup, _ = apply_baseline([f1, f3], base)
    assert unsup == [f3]
    # re-save preserves hand-edited reasons at surviving keys
    doc = json.loads(open(path).read())
    doc["entries"][1]["reason"] = "because physics"
    open(path, "w").write(json.dumps(doc))
    save_baseline([f1, f2], path)
    assert load_baseline(path)[f1.key]["reason"] == "because physics"


def test_baseline_missing_file_and_reason_enforcement(tmp_path):
    assert load_baseline(str(tmp_path / "absent.json")) == {}
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({
        "version": 1,
        "entries": [{"rule": "R1", "target": "x", "reason": "  "}]}))
    with pytest.raises(ValueError, match="justified"):
        load_baseline(str(path))
    path.write_text(json.dumps({"version": 99, "entries": []}))
    with pytest.raises(ValueError, match="unsupported format"):
        load_baseline(str(path))


# ---------------------------------------------------------------------------
# AST rules on synthetic modules
# ---------------------------------------------------------------------------

def _lint_src(tmp_path, rel, src):
    from repro.analysis.astlint import lint_file
    p = tmp_path / rel.replace("/", "__")
    p.write_text(src)
    return lint_file(str(p), rel, src_prefix="src/repro/")


def test_a1_raw_collectives(tmp_path):
    src = ("import jax.lax as lax\n"
           "from jax.lax import psum\n"
           "def f(x):\n"
           "    return lax.ppermute(psum(x, 'd'), 'd', [(0, 1)])\n")
    out = _lint_src(tmp_path, "models/foo.py", src)
    assert sorted(f.target for f in out) == [
        "src/repro/models/foo.py#ppermute",
        "src/repro/models/foo.py#psum"]
    assert all(f.rule == "A1" for f in out)
    # the same source inside the comm layer is fine
    assert _lint_src(tmp_path, "comm/foo.py", src) == []


def test_a2_bare_assert(tmp_path):
    src = "def f(x):\n    assert x > 0, 'bad'\n    return x\n"
    out = _lint_src(tmp_path, "serve/foo.py", src)
    assert [f.rule for f in out] == ["A2"]
    assert _lint_src(tmp_path, "testing/foo.py", src) == []


def test_a3_determinism_scope(tmp_path):
    src = ("import time, numpy as np\n"
           "import jax\n"
           "def f():\n"
           "    t = time.time()\n"
           "    a = np.random.normal()\n"
           "    b = np.random.default_rng()\n"
           "    ok1 = np.random.default_rng(0)\n"
           "    ok2 = jax.random.PRNGKey(0)\n"
           "    return t, a, b, ok1, ok2\n")
    out = _lint_src(tmp_path, "data/foo.py", src)
    names = sorted(f.target.split("#")[1] for f in out)
    assert names == ["np.random.default_rng()", "np.random.normal",
                     "time.time"]
    assert all(f.rule == "A3" for f in out)
    # outside the seeded-determinism scope A3 does not apply
    assert _lint_src(tmp_path, "models/foo.py", src) == []


def test_a4_unpriced_cell(tmp_path):
    src = ("from repro.comm.registry import register_impl\n"
           "@register_impl('allreduce', 'mystery')\n"
           "def f(comm, x): return x\n"
           "@register_impl('allreduce', 'priced', cost=lambda *a: 1.0)\n"
           "def g(comm, x): return x\n"
           "@register_impl('allreduce', 'opted', auto_ok=False)\n"
           "def h(comm, x): return x\n")
    out = _lint_src(tmp_path, "comm/foo.py", src)
    assert [f.rule for f in out] == ["A4"]
    assert "allreduce/mystery" in out[0].target


def test_a0_unparseable(tmp_path):
    out = _lint_src(tmp_path, "models/foo.py", "def f(:\n")
    assert [f.rule for f in out] == ["A0"]


def test_repo_is_ast_clean():
    """The shipped package passes A1-A4 with zero findings (the AST half
    of the ISSUE's zero-unsuppressed acceptance, without the 8-device
    lowering sweep tier-1 cannot afford)."""
    from repro.analysis.astlint import run_ast_rules
    assert run_ast_rules() == []


# ---------------------------------------------------------------------------
# CLI exit-code contract (0 / 1 / 2)
# ---------------------------------------------------------------------------

def _main(monkeypatch, findings, argv):
    import repro.analysis.lint as lint
    if isinstance(findings, Exception):
        def collect(args):
            raise findings
    else:
        def collect(args):
            return list(findings)
    monkeypatch.setattr(lint, "_collect", collect)
    return lint.main(argv)


def test_cli_exit_codes(monkeypatch, tmp_path, capsys):
    f = Finding("R2", "cell/a", "volume off")
    assert _main(monkeypatch, [], ["--ast-only", "--no-baseline"]) == 0
    assert _main(monkeypatch, [f], ["--ast-only", "--no-baseline"]) == 1
    out = capsys.readouterr().out
    assert "ERROR R2 cell/a" in out and "1 finding(s)" in out
    assert _main(monkeypatch, RuntimeError("lowering crashed"),
                 ["--ast-only"]) == 2
    assert "internal error" in capsys.readouterr().err


def test_cli_baseline_lifecycle(monkeypatch, tmp_path, capsys):
    f = Finding("R2", "cell/a", "volume off")
    base = str(tmp_path / "baseline.json")
    # 1. a finding with no baseline: exit 1
    assert _main(monkeypatch, [f], ["--ast-only", "--baseline", base]) == 1
    # 2. --update-baseline writes the suppression and exits 0
    assert _main(monkeypatch, [f], ["--ast-only", "--baseline", base,
                                    "--update-baseline"]) == 0
    assert load_baseline(base)[f.key]["rule"] == "R2"
    # 3. same finding now suppressed: exit 0
    capsys.readouterr()
    assert _main(monkeypatch, [f], ["--ast-only", "--baseline", base]) == 0
    assert "1 suppressed" in capsys.readouterr().out
    # 4. finding fixed: stale suppression warns but stays exit 0
    assert _main(monkeypatch, [], ["--ast-only", "--baseline", base]) == 0
    assert "stale baseline entry R2:cell/a" in capsys.readouterr().out
    # 5. an unauditable baseline is an internal error: exit 2
    (tmp_path / "baseline.json").write_text("{'not json'}")
    assert _main(monkeypatch, [], ["--ast-only", "--baseline", base]) == 2


def test_cli_real_ast_layer_is_clean():
    """End-to-end: the shipped CLI's AST leg over the real repo, real
    baseline path, exits 0."""
    from repro.analysis.lint import main
    assert main(["--ast-only"]) == 0
