"""End-to-end behaviour: dry-run artifacts are complete and healthy, the
roofline inputs exist, and the production mesh constructors behave."""
import json
import pathlib

import pytest

from repro.configs import all_archs, resolve, cells

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"

HBM_BYTES = 16e9          # TPU v5e per chip


def _cells(mesh):
    out = []
    for a in all_archs():
        for s in cells(a):
            out.append((a, s, mesh))
    return out


def _load(arch, shape, mesh):
    p = RUNS / mesh / f"{arch}__{shape}.json"
    if not p.exists():
        pytest.skip(f"dry-run artifact missing: {p} (run dryrun --all)")
    return json.loads(p.read_text())


@pytest.mark.parametrize("arch,shape,mesh",
                         _cells("single") + _cells("multi"))
def test_dryrun_cell_compiled(arch, shape, mesh):
    r = _load(arch, shape, mesh)
    assert r["chips"] == (512 if mesh == "multi" else 256)
    assert "error" not in r["memory_analysis"], r["memory_analysis"]
    assert r["hlo_stats"]["flops"] > 0
    assert r["collectives"]["total_wire_bytes"] > 0


@pytest.mark.parametrize("arch,shape,mesh",
                         _cells("single") + _cells("multi"))
def test_dryrun_cell_fits_hbm(arch, shape, mesh):
    r = _load(arch, shape, mesh)
    m = r["memory_analysis"]
    live = m["argument_size_in_bytes"] + m["temp_size_in_bytes"]
    # TPU-adjusted: XLA:CPU keeps fp32 mirrors of large bf16 buffers for
    # its dot lowering (quantified per cell by the dry-run); the TPU MXU
    # consumes bf16 directly so those buffers don't exist there.
    live -= r.get("f32_mirror_bytes", 0)
    # 10% tolerance: CPU buffer assignment takes no donation-alias credit
    assert live <= HBM_BYTES * 1.10, f"{live/1e9:.1f} GB adj"


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_complete(mesh):
    if not (RUNS / mesh).exists():
        # same degradation as _load: artifacts are produced by the (hours-
        # long) dryrun --all sweep, not shipped with the repo
        pytest.skip(f"no dry-run artifacts at {RUNS / mesh} "
                    f"(run dryrun --all)")
    want = {(a, s) for a in all_archs() for s in cells(a)}
    have = {tuple(p.stem.split("__")) for p in (RUNS / mesh).glob("*.json")}
    missing = want - have
    assert not missing, f"missing {mesh} cells: {sorted(missing)[:5]}"


def test_multi_pod_cells_cross_dcn():
    """The pod axis must actually be exercised: multi-pod train cells
    show nonzero DCN wire bytes (the cross-pod gradient reduction)."""
    for a in all_archs():
        r = _load(a, "train_4k", "multi")
        assert r["hlo_stats"]["dcn_wire"] > 0, a


def test_long500k_skips_documented():
    for a in all_archs():
        cfg = resolve(a)
        if not cfg.subquadratic:
            assert "long_500k" not in cells(a)
    # and the ones that run, ran
    for a in ("mamba2-780m", "zamba2-7b", "h2o-danube-3-4b"):
        _load(a, "long_500k", "single")


def test_production_mesh_requires_512_devices():
    import jax
    from repro.launch.mesh import make_production_mesh
    if len(jax.devices()) >= 512:
        m = make_production_mesh(multi_pod=True)
        assert m.devices.shape == (2, 16, 16)
    else:
        with pytest.raises(Exception):
            make_production_mesh(multi_pod=True)
