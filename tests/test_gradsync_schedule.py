"""Single-device tests for the bucketed gradient-sync machinery:
cost-model bucket sizing, the bucket schedule's partition/skew algebra,
and the HLO structural-concurrency checker (on handcrafted HLO — the
compiled-program version runs in the multi-device subprocess cases)."""
import numpy as np
import pytest

from repro.core.costmodel import (bucket_pipeline_time, optimal_num_buckets,
                                  HW)
from repro.core.pipeline import allreduce_pipeline_steps, ALLREDUCE_STAGES
from repro.optim.gradsync import resolve_num_buckets
from repro.launch.hlo_stats import collective_concurrency


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_optimal_buckets_crossover():
    # far below the latency/bandwidth crossover: one bucket (don't pipeline
    # pure latency)
    assert optimal_num_buckets(1024) == 1
    # far above: many buckets, clamped
    assert optimal_num_buckets(10e9) == 64
    # monotone non-decreasing in payload
    ks = [optimal_num_buckets(c) for c in (1e3, 1e5, 1e7, 1e9)]
    assert ks == sorted(ks)


def test_optimal_buckets_minimizes_model():
    c = 256e6                      # 256 MB stripe
    k_star = optimal_num_buckets(c)
    t_star = bucket_pipeline_time(c, k_star)
    for k in (max(1, k_star // 2), k_star * 2):
        assert t_star <= bucket_pipeline_time(c, k) * 1.05
    with pytest.raises(ValueError):
        bucket_pipeline_time(c, 0)


def test_resolve_num_buckets():
    # override wins
    assert resolve_num_buckets(10_000, 4, 7) == 7
    # auto is deterministic and >= 1
    assert resolve_num_buckets(0, 4, 0) == 1
    assert resolve_num_buckets(10_000, 4, 0) == \
        resolve_num_buckets(10_000, 4, 0)
    # capped: each bucket keeps >= 1 row per chip after the node RS
    assert resolve_num_buckets(8, 4, 100) == 2
    # big payloads hit the cost-model choice
    big = resolve_num_buckets(1 << 30, 4, 0)
    assert big == optimal_num_buckets((1 << 30) * 4 / 4)


def test_pipeline_step_count():
    assert ALLREDUCE_STAGES == 3
    assert allreduce_pipeline_steps(1) == 3
    assert allreduce_pipeline_steps(8) == 10


# ---------------------------------------------------------------------------
# bucket schedule (pure-jax, runs on one device with trivial stages)
# ---------------------------------------------------------------------------

def test_bucket_schedule_partitions_exactly():
    import jax.numpy as jnp
    from repro.optim.gradsync import bucket_schedule
    x = jnp.arange(24, dtype=jnp.float32)
    calls = []
    parts = bucket_schedule(
        x, 4, (lambda v: (calls.append("s0"), v * 2)[1],
               lambda v: (calls.append("s1"), v + 1)[1]))
    got = np.concatenate([np.asarray(p) for p in parts])
    np.testing.assert_allclose(got, np.arange(24) * 2 + 1)
    # every bucket saw every stage exactly once
    assert len(calls) == 8
    with pytest.raises(ValueError):
        bucket_schedule(x, 5, (lambda v: v,))


def test_bucket_schedule_skewed_emission_order():
    import jax.numpy as jnp
    from repro.optim.gradsync import bucket_schedule
    order = []
    x = jnp.arange(6, dtype=jnp.float32)
    bucket_schedule(x, 3, (lambda v: (order.append(("s0", float(v[0]))), v)[1],
                           lambda v: (order.append(("s1", float(v[0]))), v)[1]))
    # wave order: b0s0 | b1s0, b0s1 | b2s0, b1s1 | b2s1 (skew: bucket b's
    # stage 1 is emitted in the same wave as bucket b+1's stage 0)
    stages = [s for s, _ in order]
    assert stages == ["s0", "s0", "s1", "s0", "s1", "s1"]


# ---------------------------------------------------------------------------
# HLO structural concurrency checker
# ---------------------------------------------------------------------------

_HLO_CONCURRENT = """\
HloModule m

ENTRY %main (p0: f32[8]) -> (f32[4], f32[8]) {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,4},{4,0},{1,5},{5,1}}
  ROOT %t = (f32[4], f32[8]) tuple(f32[4]{0} %rs, f32[8]{0} %cp)
}
"""

_HLO_SERIAL = """\
HloModule m

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %fus = f32[4]{0} fusion(f32[4]{0} %rs), kind=kLoop, calls=%fc
  %cp = f32[4]{0} collective-permute(f32[4]{0} %fus), source_target_pairs={{0,4},{4,0}}
  ROOT %ag = f32[8]{0} all-gather(f32[4]{0} %cp), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
}
"""


def test_concurrency_checker_positive():
    res = collective_concurrency(_HLO_CONCURRENT, pod_size=4)
    assert res["concurrent"]
    assert len(res["pairs"]) == 1
    (_, dcn_name, dcn_kind, ici_name, ici_kind) = res["pairs"][0]
    assert (dcn_kind, ici_kind) == ("collective-permute", "reduce-scatter")


_HLO_PERMUTE_LATE_CROSS = """\
HloModule m

ENTRY %main (p0: f32[8]) -> (f32[4], f32[8]) {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,1},{1,4},{4,5},{5,0}}
  ROOT %t = (f32[4], f32[8]) tuple(f32[4]{0} %rs, f32[8]{0} %cp)
}
"""


def test_permute_dcn_classification_checks_all_pairs():
    # first listed pair (0,1) is intra-pod; pairs (1,4)/(5,0) cross pods —
    # the permute must still classify as DCN
    res = collective_concurrency(_HLO_PERMUTE_LATE_CROSS, pod_size=4)
    assert res["per_computation"]["main"] == \
        {"dcn": 1, "ici": 1, "pairs": 1}


def test_concurrency_checker_negative():
    # rs → fusion → cp → ag is a strict chain (deps flow THROUGH the
    # non-collective fusion), so nothing may be reported concurrent
    res = collective_concurrency(_HLO_SERIAL, pod_size=4)
    assert not res["concurrent"]
    assert res["per_computation"]["main"]["dcn"] == 1
    assert res["per_computation"]["main"]["ici"] == 2


def test_hw_alpha_defaults_present():
    assert HW.alpha_dcn > HW.alpha_ici > 0
