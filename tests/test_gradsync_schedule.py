"""Single-device tests for the bucketed gradient-sync machinery:
cost-model bucket/prefetch sizing, the bucket schedule's partition/skew
algebra, the int8 payload/scale fuse, and the HLO structural-concurrency
checkers (on handcrafted HLO — the compiled-program versions run in the
multi-device subprocess cases)."""
import numpy as np
import pytest

from repro.core.costmodel import (bucket_pipeline_time, optimal_num_buckets,
                                  optimal_prefetch_blocks, HW)
from repro.core.pipeline import (allreduce_pipeline_steps, ALLREDUCE_STAGES,
                                 allgather_pipeline_steps, ALLGATHER_STAGES)
from repro.optim.gradsync import resolve_num_buckets
from repro.launch.hlo_stats import (collective_concurrency,
                                    collective_compute_concurrency)


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------

def test_optimal_buckets_crossover():
    # far below the latency/bandwidth crossover: one bucket (don't pipeline
    # pure latency)
    assert optimal_num_buckets(1024) == 1
    # far above: many buckets, clamped
    assert optimal_num_buckets(10e9) == 64
    # monotone non-decreasing in payload
    ks = [optimal_num_buckets(c) for c in (1e3, 1e5, 1e7, 1e9)]
    assert ks == sorted(ks)


def test_optimal_buckets_minimizes_model():
    c = 256e6                      # 256 MB stripe
    k_star = optimal_num_buckets(c)
    t_star = bucket_pipeline_time(c, k_star)
    for k in (max(1, k_star // 2), k_star * 2):
        assert t_star <= bucket_pipeline_time(c, k) * 1.05
    with pytest.raises(ValueError):
        bucket_pipeline_time(c, 0)


def test_resolve_num_buckets():
    # override wins
    assert resolve_num_buckets(10_000, 4, 7) == 7
    # auto is deterministic and >= 1
    assert resolve_num_buckets(0, 4, 0) == 1
    assert resolve_num_buckets(10_000, 4, 0) == \
        resolve_num_buckets(10_000, 4, 0)
    # capped: each bucket keeps >= 1 row per chip after the node RS
    assert resolve_num_buckets(8, 4, 100) == 2
    # big payloads hit the cost-model choice
    big = resolve_num_buckets(1 << 30, 4, 0)
    assert big == optimal_num_buckets((1 << 30) * 4 / 4)


def test_pipeline_step_count():
    assert ALLREDUCE_STAGES == 3
    assert allreduce_pipeline_steps(1) == 3
    assert allreduce_pipeline_steps(8) == 10


def test_allgather_pipeline_step_count():
    assert ALLGATHER_STAGES == 2
    assert allgather_pipeline_steps(1) == 2
    assert allgather_pipeline_steps(8) == 9


def test_optimal_prefetch_blocks():
    # tiny layer stripes don't split (latency would eat the window)
    assert optimal_prefetch_blocks(256) == 1
    # huge stripes clamp at the prefetch cap, below the gradient cap
    assert optimal_prefetch_blocks(10e9) == 16
    ks = [optimal_prefetch_blocks(c) for c in (1e3, 1e6, 1e9)]
    assert ks == sorted(ks)


def test_zero3_rejects_single_batch_axis():
    """lane_zero3 shards over the (lane × node) product: a single-batch-
    axis mesh has no distinct levels and must be rejected up front (the
    other strategies degrade to native instead)."""
    import jax
    from repro.configs import resolve
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch.steps import build_train_step_lane
    from repro.optim import AdamWConfig
    cfg = resolve("llama3.2-3b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    gradsync="lane_zero3")
    with pytest.raises(ValueError, match="distinct lane and node"):
        build_train_step_lane(cfg, run, AdamWConfig(), mesh, None)


def test_resolve_prefetch_blocks():
    from repro.launch.steps import resolve_prefetch_blocks
    # override wins; -1 (blocking negative control) degenerates to 1
    assert resolve_prefetch_blocks(10_000, 2, 2, 5) == 5
    assert resolve_prefetch_blocks(10_000, 2, 2, -1) == 1
    # deterministic auto, capped at >= 1 row per chip per block
    assert resolve_prefetch_blocks(10_000, 2, 2, 0) == \
        resolve_prefetch_blocks(10_000, 2, 2, 0)
    assert resolve_prefetch_blocks(8, 4, 2, 100) == 1
    assert resolve_prefetch_blocks(0, 1, 1, 0) == 1


# ---------------------------------------------------------------------------
# bucket schedule (pure-jax, runs on one device with trivial stages)
# ---------------------------------------------------------------------------

def test_bucket_schedule_partitions_exactly():
    import jax.numpy as jnp
    from repro.optim.gradsync import bucket_schedule
    x = jnp.arange(24, dtype=jnp.float32)
    calls = []
    parts = bucket_schedule(
        x, 4, (lambda v: (calls.append("s0"), v * 2)[1],
               lambda v: (calls.append("s1"), v + 1)[1]))
    got = np.concatenate([np.asarray(p) for p in parts])
    np.testing.assert_allclose(got, np.arange(24) * 2 + 1)
    # every bucket saw every stage exactly once
    assert len(calls) == 8
    with pytest.raises(ValueError):
        bucket_schedule(x, 5, (lambda v: v,))


def test_bucket_schedule_skewed_emission_order():
    import jax.numpy as jnp
    from repro.optim.gradsync import bucket_schedule
    order = []
    x = jnp.arange(6, dtype=jnp.float32)
    bucket_schedule(x, 3, (lambda v: (order.append(("s0", float(v[0]))), v)[1],
                           lambda v: (order.append(("s1", float(v[0]))), v)[1]))
    # wave order: b0s0 | b1s0, b0s1 | b2s0, b1s1 | b2s1 (skew: bucket b's
    # stage 1 is emitted in the same wave as bucket b+1's stage 0)
    stages = [s for s, _ in order]
    assert stages == ["s0", "s0", "s1", "s0", "s1", "s1"]


# ---------------------------------------------------------------------------
# HLO structural concurrency checker
# ---------------------------------------------------------------------------

_HLO_CONCURRENT = """\
HloModule m

ENTRY %main (p0: f32[8]) -> (f32[4], f32[8]) {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,4},{4,0},{1,5},{5,1}}
  ROOT %t = (f32[4], f32[8]) tuple(f32[4]{0} %rs, f32[8]{0} %cp)
}
"""

_HLO_SERIAL = """\
HloModule m

ENTRY %main (p0: f32[8]) -> f32[8] {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %fus = f32[4]{0} fusion(f32[4]{0} %rs), kind=kLoop, calls=%fc
  %cp = f32[4]{0} collective-permute(f32[4]{0} %fus), source_target_pairs={{0,4},{4,0}}
  ROOT %ag = f32[8]{0} all-gather(f32[4]{0} %cp), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
}
"""


def test_concurrency_checker_positive():
    res = collective_concurrency(_HLO_CONCURRENT, pod_size=4)
    assert res["concurrent"]
    assert len(res["pairs"]) == 1
    (_, dcn_name, dcn_kind, ici_name, ici_kind) = res["pairs"][0]
    assert (dcn_kind, ici_kind) == ("collective-permute", "reduce-scatter")


_HLO_PERMUTE_LATE_CROSS = """\
HloModule m

ENTRY %main (p0: f32[8]) -> (f32[4], f32[8]) {
  %p0 = f32[8]{0} parameter(0)
  %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,1},{1,4},{4,5},{5,0}}
  ROOT %t = (f32[4], f32[8]) tuple(f32[4]{0} %rs, f32[8]{0} %cp)
}
"""


def test_permute_dcn_classification_checks_all_pairs():
    # first listed pair (0,1) is intra-pod; pairs (1,4)/(5,0) cross pods —
    # the permute must still classify as DCN
    res = collective_concurrency(_HLO_PERMUTE_LATE_CROSS, pod_size=4)
    assert res["per_computation"]["main"] == \
        {"dcn": 1, "ici": 1, "pairs": 1}


def test_concurrency_checker_negative():
    # rs → fusion → cp → ag is a strict chain (deps flow THROUGH the
    # non-collective fusion), so nothing may be reported concurrent
    res = collective_concurrency(_HLO_SERIAL, pod_size=4)
    assert not res["concurrent"]
    assert res["per_computation"]["main"]["dcn"] == 1
    assert res["per_computation"]["main"]["ici"] == 2


def test_hw_alpha_defaults_present():
    assert HW.alpha_dcn > HW.alpha_ici > 0


# ---------------------------------------------------------------------------
# HLO checker edge cases (satellite: untested false-negative paths)
# ---------------------------------------------------------------------------

def test_concurrency_checker_empty_hlo():
    for text in ("", "HloModule m\n"):
        res = collective_concurrency(text, pod_size=4)
        assert res == {"concurrent": False, "pairs": [],
                       "per_computation": {}}
        res = collective_compute_concurrency(text, pod_size=4)
        assert res == {"concurrent": False, "pairs": [],
                       "per_computation": {}}


_HLO_SINGLE_COLLECTIVE = """\
HloModule m

ENTRY %main (p0: f32[8]) -> f32[4] {
  %p0 = f32[8]{0} parameter(0)
  ROOT %rs = f32[4]{0} reduce-scatter(f32[8]{0} %p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
}
"""


def test_concurrency_checker_single_collective():
    # one collective can never overlap with itself
    res = collective_concurrency(_HLO_SINGLE_COLLECTIVE, pod_size=4)
    assert not res["concurrent"]
    assert res["per_computation"]["main"] == {"dcn": 0, "ici": 1, "pairs": 0}


_HLO_TUPLE_CHAIN = """\
HloModule m

ENTRY %main (p0: f32[8]) -> f32[4] {
  %p0 = f32[8]{0} parameter(0)
  %cp = f32[8]{0} collective-permute(f32[8]{0} %p0), source_target_pairs={{0,4},{4,0}}
  %t = (f32[8], f32[8]) tuple(f32[8]{0} %cp, f32[8]{0} %p0)
  %gte = f32[8]{0} get-tuple-element((f32[8], f32[8]) %t), index=0
  ROOT %rs = f32[4]{0} reduce-scatter(f32[8]{0} %gte), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
}
"""


def test_concurrency_checker_tuple_gte_dependence():
    """A DCN permute feeding an ICI reduce-scatter THROUGH a
    tuple/get-tuple-element chain is a real dependence — the checker must
    not report the pair concurrent just because the edge is plumbing."""
    res = collective_concurrency(_HLO_TUPLE_CHAIN, pod_size=4)
    assert not res["concurrent"]
    assert res["per_computation"]["main"] == {"dcn": 1, "ici": 1, "pairs": 0}


# ---------------------------------------------------------------------------
# prefetch-AG vs compute checker (tentpole acceptance, handcrafted HLO;
# the compiled lane_zero3 version runs in collective_cases)
# ---------------------------------------------------------------------------

_HLO_PREFETCH = """\
HloModule m

ENTRY %main (shard: f32[2], w: f32[4], h: f32[2,2]) -> (f32[4], f32[2,2]) {
  %shard = f32[2]{0} parameter(0)
  %w = f32[4]{0} parameter(1)
  %h = f32[2,2]{1,0} parameter(2)
  %ag = f32[4]{0} all-gather(f32[2]{0} %shard), replica_groups={{0,1}}, dimensions={0}
  %wr = f32[2,2]{1,0} reshape(f32[4]{0} %w)
  %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %h, f32[2,2]{1,0} %wr), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (f32[4], f32[2,2]) tuple(f32[4]{0} %ag, f32[2,2]{1,0} %dot)
}
"""

_HLO_BLOCKING = """\
HloModule m

ENTRY %main (shard: f32[2], h: f32[2,2]) -> f32[2,2] {
  %shard = f32[2]{0} parameter(0)
  %h = f32[2,2]{1,0} parameter(1)
  %ag = f32[4]{0} all-gather(f32[2]{0} %shard), replica_groups={{0,1}}, dimensions={0}
  %wr = f32[2,2]{1,0} reshape(f32[4]{0} %ag)
  ROOT %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %h, f32[2,2]{1,0} %wr), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_compute_concurrency_prefetch_positive():
    """Layer i+1's gather reads the shard; layer i's dot reads the
    already-gathered carry — no ancestor relation, overlap possible."""
    res = collective_compute_concurrency(_HLO_PREFETCH, pod_size=4)
    assert res["concurrent"]
    (_, ag, kind, dot, op) = res["pairs"][0]
    assert (kind, op) == ("all-gather", "dot")


def test_compute_concurrency_blocking_negative():
    """BLOCKING all-gather: the dot consumes the gather's output, so the
    checker must find no independent pair (the fsdp_prefetch=-1 control)."""
    res = collective_compute_concurrency(_HLO_BLOCKING, pod_size=4)
    assert not res["concurrent"]
    assert res["per_computation"]["main"] == \
        {"colls": 1, "compute": 1, "pairs": 0}


_HLO_WHILE_CARRIER = """\
HloModule m

%gcond (cp: (f32[2], f32[4])) -> pred[] {
  %cp = (f32[2], f32[4]) parameter(0)
  ROOT %lt = pred[] constant(false)
}

%gbody (gp: (f32[2], f32[4])) -> (f32[2], f32[4]) {
  %gp = (f32[2], f32[4]) parameter(0)
  %gs = f32[2]{0} get-tuple-element((f32[2], f32[4]) %gp), index=0
  %gag = f32[4]{0} all-gather(f32[2]{0} %gs), replica_groups={{0,1}}, dimensions={0}
  ROOT %gt = (f32[2], f32[4]) tuple(f32[2]{0} %gs, f32[4]{0} %gag)
}

ENTRY %main (shard: f32[2], z: f32[4], w: f32[2,2], h: f32[2,2]) -> ((f32[2], f32[4]), f32[2,2]) {
  %shard = f32[2]{0} parameter(0)
  %z = f32[4]{0} parameter(1)
  %w = f32[2,2]{1,0} parameter(2)
  %h = f32[2,2]{1,0} parameter(3)
  %init = (f32[2], f32[4]) tuple(f32[2]{0} %shard, f32[4]{0} %z)
  %wl = (f32[2], f32[4]) while((f32[2], f32[4]) %init), condition=%gcond, body=%gbody
  %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %h, f32[2,2]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = ((f32[2], f32[4]), f32[2,2]) tuple((f32[2], f32[4]) %wl, f32[2,2]{1,0} %dot)
}
"""


def test_compute_concurrency_while_carries_collective():
    """The pipelined per-layer gather lowers to an inner while loop: the
    while INSTRUCTION must count as carrying its body's all-gather, so it
    can pair with a dot beside it (this is exactly how the lane_zero3
    layer scan body looks after XLA)."""
    res = collective_compute_concurrency(_HLO_WHILE_CARRIER, pod_size=4)
    assert res["concurrent"]
    pair_comps = {p[0] for p in res["pairs"]}
    assert "main" in pair_comps
    main_pairs = [p for p in res["pairs"] if p[0] == "main"]
    assert any(p[1] == "wl" and p[3] == "dot" for p in main_pairs)


_HLO_BRANCH_CARRIER = """\
HloModule m

%br0 (bp: f32[2]) -> f32[4] {
  %bp = f32[2]{0} parameter(0)
  ROOT %bag = f32[4]{0} all-gather(f32[2]{0} %bp), replica_groups={{0,1}}, dimensions={0}
}

%br1 (cp: f32[2]) -> f32[4] {
  %cp = f32[2]{0} parameter(0)
  ROOT %pad = f32[4]{0} pad(f32[2]{0} %cp), padding=0_2
}

ENTRY %main (idx: s32[], shard: f32[2], h: f32[2,2], w: f32[2,2]) -> (f32[4], f32[2,2]) {
  %idx = s32[] parameter(0)
  %shard = f32[2]{0} parameter(1)
  %h = f32[2,2]{1,0} parameter(2)
  %w = f32[2,2]{1,0} parameter(3)
  %sel = f32[4]{0} conditional(s32[] %idx, f32[2]{0} %shard, f32[2]{0} %shard), branch_computations={%br0, %br1}
  %dot = f32[2,2]{1,0} dot(f32[2,2]{1,0} %h, f32[2,2]{1,0} %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (f32[4], f32[2,2]) tuple(f32[4]{0} %sel, f32[2,2]{1,0} %dot)
}
"""


def test_compute_concurrency_conditional_carries_collective():
    """A collective living inside a conditional BRANCH computation must
    count against the conditional instruction (branch_computations= is a
    different attribute syntax than body=/calls=)."""
    res = collective_compute_concurrency(_HLO_BRANCH_CARRIER, pod_size=4)
    assert res["concurrent"]
    assert any(p[0] == "main" and p[1] == "sel" and p[3] == "dot"
               for p in res["pairs"])


def test_compute_concurrency_kind_filter():
    # nothing matches when the prefetch kind is excluded
    res = collective_compute_concurrency(
        _HLO_PREFETCH, pod_size=4, coll_kinds=("reduce-scatter",))
    assert not res["concurrent"] and res["per_computation"] == {}


# ---------------------------------------------------------------------------
# int8 payload/scale fuse (satellite: one DCN collective per bucket)
# ---------------------------------------------------------------------------

def test_int8_pack_unpack_roundtrip_exact():
    import jax.numpy as jnp
    from repro.optim.gradsync import (compress_int8, pack_int8_payload,
                                      unpack_int8_payload)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2500,)) * 3.0,
                    jnp.float32)
    q, s, n = compress_int8(x)
    buf = pack_int8_payload(q, s)
    assert buf.dtype == jnp.int8
    assert buf.shape[0] == q.size + 4 * s.size      # scales ride as 4 bytes
    q2, s2 = unpack_int8_payload(buf, q.shape[0])
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
    # bitcast, not convert: the fp32 scales survive BIT-EXACTLY
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))


def test_int8_fused_error_bound_unchanged():
    """The fuse moves bytes, not values: summing dequantized payloads
    through pack→unpack equals the unfused two-gather result exactly,
    and stays within the half-step quantization bound."""
    import jax.numpy as jnp
    from repro.optim.gradsync import (compress_int8, decompress_int8,
                                      pack_int8_payload, unpack_int8_payload)
    rng = np.random.default_rng(1)
    ranks = [jnp.asarray(rng.normal(size=(1500,)), jnp.float32)
             for _ in range(4)]
    fused = np.zeros(1500, np.float32)
    unfused = np.zeros(1500, np.float32)
    for x in ranks:
        q, s, n = compress_int8(x)
        qf, sf = unpack_int8_payload(pack_int8_payload(q, s), q.shape[0])
        fused += np.asarray(decompress_int8(qf, sf, n))
        unfused += np.asarray(decompress_int8(q, s, n))
    np.testing.assert_array_equal(fused, unfused)
    total = np.sum([np.asarray(x) for x in ranks], axis=0)
    # per-rank half-step bound, accumulated over ranks
    bound = sum(float(np.abs(np.asarray(x)).max()) / 127.0 * 0.5 + 1e-6
                for x in ranks)
    assert np.abs(fused - total).max() <= bound


# ---------------------------------------------------------------------------
# BENCH_gradsync.json schema check (satellite: CI guards the trajectory)
# ---------------------------------------------------------------------------

def test_bench_schema_flags_missing_strategy():
    import sys, pathlib
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
    from benchmarks.check_bench_schema import (check, REQUIRED_STRATEGIES,
                                               REQUIRED_FAMILIES,
                                               REQUIRED_THIRD_AXIS)
    from repro.comm import strategies_for
    from repro.models.blockstack import block_stack_families
    # the requirements are DERIVED from the registries (satellite
    # contract): a silently-unregistered impl/family shrinks neither
    # list unnoticed
    assert REQUIRED_STRATEGIES == set(strategies_for("grad_sync")) | {"auto"}
    assert REQUIRED_FAMILIES == set(block_stack_families())
    assert REQUIRED_THIRD_AXIS == (
        {("moe_route", s)
         for s in (*strategies_for("moe_route"), "auto")}
        | {("tp_allgather", s)
           for s in (*strategies_for("allgather"), "auto")})
    row = {"strategy": "native", "selected": "native", "num_buckets": 0,
           "avg_us": 1.0, "min_us": 1.0, "max_abs_err_vs_native": 0.0,
           "model_pred_us": 1.0, "predicted_us": None,
           "hlo_concurrent": False, "hlo_concurrent_pairs": 0}
    frow = {"family": "dense", "arch": "a", "layer_elems": 1,
            "extra_elems": 1, "num_layers": 1, "num_blocks": 1,
            "avg_us": 1.0, "min_us": 1.0, "gather_exact": True,
            "hlo_concurrent": True}
    trow = {"payload_bytes": 4, "avg_us": 1.0, "min_us": 1.0,
            "predicted_us": 1.0, "max_abs_err_vs_native": 0.0}
    wire = {"arch": "a", "num_experts": 8, "capacity": 8,
            "alltoall_bytes_per_layer": 1,
            "expert_gather_bytes_per_layer": 9, "ratio": 0.111,
            "bound": 0.25, "ok": True}
    doc = {"mesh": "2x4", "payload_elems": 1, "payload_bytes": 4,
           "auto_num_buckets": 1, "cost_model": {}, "smoke": True,
           "reps": 1, "hlo_per_computation": {}, "structure_ok": True,
           "tuning_cache": None,
           "strategies_registered": sorted(REQUIRED_STRATEGIES - {"auto"}),
           "results": [dict(row, strategy=s) for s in REQUIRED_STRATEGIES],
           "families_registered": sorted(REQUIRED_FAMILIES),
           "family_results": [dict(frow, family=f)
                              for f in REQUIRED_FAMILIES],
           "third_axis_results": [dict(trow, cell=c, strategy=s,
                                       selected=s)
                                  for c, s in REQUIRED_THIRD_AXIS],
           "ep_wire": wire}
    assert check(doc) == []
    # dropping any third-axis (cell, strategy) row fails the build, and
    # so does a wire-volume regression past the 2/E bound
    for c, s in REQUIRED_THIRD_AXIS:
        bad = dict(doc, third_axis_results=[
            r for r in doc["third_axis_results"]
            if (r["cell"], r["strategy"]) != (c, s)])
        errs = check(bad)
        assert errs and "third-axis" in errs[0], ((c, s), errs)
    assert any("ep_wire ok is false" in e
               for e in check(dict(doc, ep_wire=dict(wire, ok=False))))
    assert any("ep_wire missing" in e
               for e in check(dict(doc, ep_wire={})))
    # dropping any required strategy (incl. the auto row) fails the build
    for s in REQUIRED_STRATEGIES:
        bad = dict(doc, results=[r for r in doc["results"]
                                 if r["strategy"] != s])
        errs = check(bad)
        assert errs and "stopped emitting" in errs[0], (s, errs)
    # dropping any block-stack family's zero3 row fails the build too
    for f in REQUIRED_FAMILIES:
        bad = dict(doc, family_results=[r for r in doc["family_results"]
                                        if r["family"] != f])
        errs = check(bad)
        assert errs and any("family" in e for e in errs), (f, errs)
    # a regressed structural check fails too
    assert check(dict(doc, structure_ok=False))
    # a bench emitted against a stale (now-unregistered) strategy/family
    # is caught
    assert any("no longer matches" in e for e in check(
        dict(doc, strategies_registered=["lane_future"])))
    assert any("no longer matches" in e for e in check(
        dict(doc, families_registered=["family_future"])))
    # and a row losing a field is caught (both row kinds)
    broken = dict(doc, results=doc["results"][:1]
                  + [dict(doc["results"][1])])
    del broken["results"][1]["min_us"]
    assert any("missing" in e for e in check(broken))
    broken_f = dict(doc, family_results=[dict(doc["family_results"][0])])
    del broken_f["family_results"][0]["gather_exact"]
    assert any("family_results[0] missing" in e for e in check(broken_f))
    # with a tuning cache present, the auto row's selected strategy must
    # equal the argmin of the MEASURED (predicted_us) auto-eligible rows
    from benchmarks.check_bench_schema import AUTO_ELIGIBLE
    assert set(AUTO_ELIGIBLE) == {"native", "lane", "lane_pipelined"}
    pred = {"native": 2.0, "lane": 1.0, "lane_pipelined": 3.0}
    tuned = dict(doc, tuning_cache="tuning_cache.json",
                 results=[dict(r, predicted_us=pred.get(r["strategy"]),
                               selected=("lane" if r["strategy"] == "auto"
                                         else r["selected"]))
                          for r in doc["results"]])
    assert check(tuned) == []
    mis = dict(tuned, results=[dict(r, selected="native")
                               if r["strategy"] == "auto" else r
                               for r in tuned["results"]])
    assert any("argmin" in e for e in check(mis))
    nopred = dict(tuned, results=[dict(r, predicted_us=None)
                                  for r in tuned["results"]])
    assert any("predicted_us" in e for e in check(nopred))
