"""Unit tier for the fault-tolerant lane runtime (tentpole PR 6).

Single-device pieces of the failure story: the deterministic fault plan
(runtime/faults.py), the progress watchdog and health-state ladder
(runtime/watchdog.py, runtime/health.py), the quorum collectives on a
degenerate lane, checkpoint integrity (crc32 verify, verified fallback,
the ``.old`` overwrite swap, stray-name hardening, bounded retry), and
the (seed, step)-keyed microbatch replay contract of the data pipeline.

The multi-pod halves — the DEGRADED→RESTART driver ladder, quorum
bit-identity against a skipped microbatch, restart-vs-fresh-launch
bit-identity — need 8 devices and live in testing/driver_cases.py
(``fault_*`` cases), executed per-case by test_checkpoint_runtime.py in
a subprocess.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import (CheckpointCorruptError, committed_steps,
                              keep_last_k, latest_step, latest_verified_step,
                              restore_checkpoint, save_checkpoint,
                              verify_checkpoint)
from repro.runtime import (DEGRADED, HEALTHY, RESTART, FaultPlan,
                           HealthMonitor, Watchdog, corrupt_leaf_file,
                           parse_fault_plan, quorum_mean, quorum_stage)


# ---------------------------------------------------------------------------
# fault plan: grammar, determinism, queries
# ---------------------------------------------------------------------------

def test_fault_plan_parse_grammar():
    plan = parse_fault_plan(
        "pod_slow@2-4:pod=1;pod_lost@6:pod=0;ckpt_io@3:count=2;"
        "corrupt_leaf@8:leaf=5")
    kinds = [f.kind for f in plan.faults]
    assert kinds == ["pod_slow", "pod_lost", "ckpt_io", "corrupt_leaf"]
    slow = plan.faults[0]
    assert (slow.step, slow.until, slow.pod) == (2, 4, 1)
    assert plan.faults[2].count == 2
    assert plan.faults[3].leaf == 5
    assert bool(plan) and not bool(FaultPlan())
    assert parse_fault_plan("").faults == ()


def test_fault_plan_parse_rejects_garbage():
    with pytest.raises(ValueError):
        parse_fault_plan("meteor@3")              # unknown kind
    with pytest.raises(ValueError):
        parse_fault_plan("pod_slow@5-2")          # inverted window
    with pytest.raises(ValueError):
        parse_fault_plan("pod_slow@2:mass=1")     # unknown key


def test_fault_plan_generate_deterministic():
    a = FaultPlan.generate(seed=3, steps=20, num_pods=4)
    b = FaultPlan.generate(seed=3, steps=20, num_pods=4)
    assert a == b
    assert a != FaultPlan.generate(seed=4, steps=20, num_pods=4)
    for f in a.faults:                            # all in-range
        assert 0 <= f.step < 20
        assert 0 <= f.pod < 4


def test_pods_down_windows_and_shrink():
    plan = parse_fault_plan("pod_slow@2-4:pod=1;pod_lost@6:pod=2")
    assert plan.pods_down(1, 4) == ()
    assert plan.pods_down(2, 4) == (1,)
    assert plan.pods_down(4, 4) == (1,)           # window inclusive
    assert plan.pods_down(5, 4) == ()
    assert plan.pods_down(7, 4) == (2,)           # lost = forever
    assert plan.lost_pods(7, 4) == (2,)
    assert plan.lost_pods(4, 4) == ()
    # after an elastic shrink the surviving mesh has fewer pods: faults
    # aimed at amputated pods must go inert, not crash or re-fire
    assert plan.pods_down(7, 2) == ()


def test_ckpt_attempt_hook_transient_and_corrupt_at():
    plan = parse_fault_plan("ckpt_io@3:count=2;corrupt_leaf@5:leaf=1")
    assert plan.ckpt_attempt_hook(2) is None
    hook = plan.ckpt_attempt_hook(3)
    with pytest.raises(OSError):
        hook(0)
    with pytest.raises(OSError):
        hook(1)
    hook(2)                                       # third attempt passes
    assert plan.corrupt_at(5) == 1
    assert plan.corrupt_at(4) is None


# ---------------------------------------------------------------------------
# watchdog + health ladder
# ---------------------------------------------------------------------------

def test_watchdog_mask_and_monotone_heartbeat():
    w = Watchdog(num_pods=3, deadline_steps=1)
    for p in range(3):
        w.heartbeat(p, 0)
    assert w.mask(0).tolist() == [1.0, 1.0, 1.0]
    w.heartbeat(0, 2)
    w.heartbeat(1, 2)
    w.heartbeat(2, 0)                             # stale echo: no rewind
    assert w.mask(2).tolist() == [1.0, 1.0, 0.0]
    assert w.live(2) == (0, 1) and w.stale(2) == (2,)
    w.heartbeat(2, 2)
    assert w.mask(2).tolist() == [1.0, 1.0, 1.0]


def test_health_ladder_degraded_then_restart():
    events = []
    h = HealthMonitor(num_pods=2, staleness_limit=2,
                      log=lambda m: events.append(m))
    ones, hole = np.ones(2, np.float32), np.array([1.0, 0.0], np.float32)
    assert h.observe(0, ones) == HEALTHY
    assert h.observe(1, hole) == DEGRADED          # streak 1
    assert h.observe(2, hole) == DEGRADED          # streak 2 == K
    assert h.observe(3, hole) == RESTART           # streak 3 > K
    assert h.observe(4, ones) == RESTART           # terminal per attempt
    assert h.restart_pods() == (1,)
    assert any("HEALTHY -> DEGRADED" in m for m in events)
    assert any("DEGRADED -> RESTART" in m for m in events)


def test_health_recovers_and_no_degrade_mode():
    h = HealthMonitor(num_pods=2, staleness_limit=2, log=lambda m: None)
    hole = np.array([1.0, 0.0], np.float32)
    assert h.observe(0, hole) == DEGRADED
    assert h.observe(1, np.ones(2, np.float32)) == HEALTHY  # streak resets
    # a strategy without a quorum mask cannot run degraded: any masked
    # pod goes straight to RESTART
    h2 = HealthMonitor(num_pods=2, staleness_limit=2, can_degrade=False,
                       log=lambda m: None)
    assert h2.observe(0, hole) == RESTART


# ---------------------------------------------------------------------------
# quorum collectives on a degenerate (single-pod) lane
# ---------------------------------------------------------------------------

def _lane_run(f, x):
    mesh = jax.make_mesh((1,), ("pod",))
    sm = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    return np.asarray(jax.jit(sm)(x))


def test_quorum_mean_and_stage_identity_and_zero_quorum():
    x = jnp.arange(4, dtype=jnp.float32) + 1.0
    one, zero = jnp.ones((), jnp.float32), jnp.zeros((), jnp.float32)
    # full quorum on a 1-pod lane is the identity, bitwise
    np.testing.assert_array_equal(
        _lane_run(lambda v: quorum_mean(v, "pod", one), x), np.asarray(x))
    np.testing.assert_array_equal(
        _lane_run(lambda v: quorum_stage("pod", one)(v), x), np.asarray(x))
    # zero quorum: contribution zeroed, divisor clamped to 1 (no NaN)
    np.testing.assert_array_equal(
        _lane_run(lambda v: quorum_mean(v, "pod", zero), x), np.zeros(4))
    np.testing.assert_array_equal(
        _lane_run(lambda v: quorum_stage("pod", zero)(v), x), np.zeros(4))


def test_lane_quorum_full_mask_matches_lane_single_device():
    from repro.comm import CommConfig, LaneComm
    from repro.core import LaneTopology
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, CommConfig(strategy="lane_quorum"), mesh=mesh)
    g = {"w": jnp.arange(6, dtype=jnp.float32), "b": jnp.ones((3,))}

    def run(f):
        sm = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        return jax.jit(sm)(g)

    got = run(lambda t: comm.grad_sync(t))
    ref = run(lambda t: comm.grad_sync(t, strategy="lane"))
    for k in g:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(ref[k]))


# ---------------------------------------------------------------------------
# checkpoint integrity: crc32, verified fallback, .old swap, retry
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(4, 3),
            "b": jnp.ones((2,), jnp.int32)}


def _np_tree():
    return {k: np.asarray(v) for k, v in _tree().items()}


def test_crc_verify_detects_single_bit_rot(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    man = verify_checkpoint(ck, 2)
    assert all("crc32" in l for l in man["leaves"])
    corrupt_leaf_file(ck, 2, 0)
    # the array still LOADS fine — only the checksum catches the rot
    np.load(tmp_path / "step_2" / "arr_0.npy")
    with pytest.raises(CheckpointCorruptError, match="crc32 mismatch"):
        verify_checkpoint(ck, 2)


def test_restore_falls_back_to_newest_verified(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    save_checkpoint(ck, 4, _tree())
    corrupt_leaf_file(ck, 4, 1)
    assert latest_step(ck) == 4
    assert latest_verified_step(ck) == 2
    _, step = restore_checkpoint(ck, _np_tree())
    assert step == 2
    # an EXPLICIT step never silently falls back
    with pytest.raises(CheckpointCorruptError):
        restore_checkpoint(ck, _np_tree(), step=4)
    # unverified escape hatch still reads the rotten bytes
    _, step = restore_checkpoint(ck, _np_tree(), step=4, verify=False)
    assert step == 4


def test_restore_all_corrupt_raises_not_loops(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    corrupt_leaf_file(ck, 2, 0)
    with pytest.raises(CheckpointCorruptError, match="no verifiable"):
        restore_checkpoint(ck, _np_tree())


def test_pre_crc_manifest_passes_vacuously(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    d = tmp_path / "step_2"
    man = json.loads((d / "manifest.json").read_text())
    for leaf in man["leaves"]:
        del leaf["crc32"]                      # checkpoint from an old build
    (d / "manifest.json").write_text(json.dumps(man))
    verify_checkpoint(ck, 2)
    _, step = restore_checkpoint(ck, _np_tree())
    assert step == 2


def test_scanner_ignores_stray_step_names(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    (tmp_path / "step_backup").mkdir()         # operator's manual copy
    (tmp_path / "step_").mkdir()
    (tmp_path / "step_9.tmp").mkdir()          # in-flight write
    (tmp_path / "step_3").mkdir()              # dir without manifest
    assert committed_steps(ck) == [2]
    assert latest_step(ck) == 2


def test_overwrite_swap_and_old_only_commit(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    save_checkpoint(ck, 2, _tree())            # overwrite via .old swap
    assert committed_steps(ck) == [2]
    assert not (tmp_path / "step_2.old").exists()   # dropped post-commit
    # crash window: committed copy parked at .old, final half-written
    (tmp_path / "step_2").rename(tmp_path / "step_2.old")
    (tmp_path / "step_2").mkdir()
    (tmp_path / "step_2" / "arr_0.npy").write_bytes(b"partial")
    assert committed_steps(ck) == [2]          # lone .old counts
    _, step = restore_checkpoint(ck, _np_tree())
    assert step == 2
    # keep_last_k prunes BOTH spellings
    save_checkpoint(ck, 4, _tree())
    keep_last_k(ck, 1)
    assert committed_steps(ck) == [4]
    assert not (tmp_path / "step_2.old").exists()


def test_save_retries_transient_and_gives_up(tmp_path):
    calls = []

    def flaky(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise OSError("transient")

    save_checkpoint(str(tmp_path), 2, _tree(), attempt_hook=flaky,
                    backoff_s=0.001)
    assert calls == [0, 1, 2]
    verify_checkpoint(str(tmp_path), 2)

    def always(attempt):
        raise OSError("disk on fire")

    with pytest.raises(OSError, match="disk on fire"):
        save_checkpoint(str(tmp_path), 4, _tree(), attempt_hook=always,
                        backoff_s=0.001)
    assert latest_step(str(tmp_path)) == 2     # failed save commits nothing


def test_corrupt_leaf_file_targets_one_leaf(tmp_path):
    ck = str(tmp_path)
    save_checkpoint(ck, 2, _tree())
    corrupt_leaf_file(ck, 2, 1)
    man = json.loads((tmp_path / "step_2" / "manifest.json").read_text())
    from repro.checkpoint.store import _crc32
    assert _crc32(np.load(tmp_path / "step_2" / "arr_0.npy")) == \
        man["leaves"][0]["crc32"]
    assert _crc32(np.load(tmp_path / "step_2" / "arr_1.npy")) != \
        man["leaves"][1]["crc32"]


# ---------------------------------------------------------------------------
# microbatch replay: dropped rows are a pure function of (seed, step, range)
# ---------------------------------------------------------------------------

def test_batch_slice_replays_dropped_rows():
    from repro.data.pipeline import make_loader
    from repro.configs import resolve
    cfg = resolve("llama3.2-3b", smoke=True)
    ld = make_loader(cfg, seq_len=16, global_batch=8, seed=7)
    toks, labs = ld.batch_at(step=3)
    # pod 1 of 2 owns rows [4, 8): a replay from the SAME (seed, step)
    # must regenerate exactly those rows — on any host
    rt, rl = ld.batch_slice(3, 4, 4)
    np.testing.assert_array_equal(toks[4:8], rt)
    np.testing.assert_array_equal(labs[4:8], rl)
    other = make_loader(cfg, seq_len=16, global_batch=8, seed=7,
                        host_index=0, num_hosts=1)
    np.testing.assert_array_equal(other.batch_slice(3, 4, 4)[0], rt)
    # ...and different (seed, step) keys yield different rows
    assert not np.array_equal(ld.batch_slice(3, 0, 4)[0], rt)
    assert not np.array_equal(ld.batch_slice(4, 4, 4)[0], rt)
