"""Cluster launch planning: per-host env/argv, elastic renumbering,
manifest rendering (the 1000+-node runnability layer, unit-testable)."""
import json

from repro.launch.cluster import (plan_cluster, surviving_plans,
                                  render_ssh_script, render_gke_jobset)


def test_plan_shape_and_ids():
    plans = plan_cluster(num_pods=2, hosts_per_pod=64)
    assert len(plans) == 128
    assert [p.process_id for p in plans] == list(range(128))
    assert plans[64].pod_index == 1                  # pod-major numbering
    assert plans[0].env["JAX_NUM_PROCESSES"] == "128"
    assert plans[77].env["REPRO_HOST_INDEX"] == "77"


def test_elastic_pod_loss_renumbers():
    plans = plan_cluster(num_pods=2, hosts_per_pod=64)
    left = surviving_plans(plans, lost_pods=[0])
    assert len(left) == 64
    assert [p.process_id for p in left] == list(range(64))
    assert all(p.pod_index == 1 for p in left)
    assert left[0].env["JAX_NUM_PROCESSES"] == "64"


def test_renders():
    plans = plan_cluster(num_pods=2, hosts_per_pod=4)
    sh = render_ssh_script(plans)
    assert sh.count("ssh ") == 8 and sh.strip().endswith("wait")
    js = json.loads(render_gke_jobset(plans, image="repro:latest"))
    rj = js["spec"]["replicatedJobs"][0]
    assert rj["replicas"] == 2
    assert rj["template"]["spec"]["parallelism"] == 4
    tpl = rj["template"]["spec"]["template"]["spec"]
    assert tpl["terminationGracePeriodSeconds"] == 120   # SIGTERM ckpt window
