"""Checkpoint atomicity/resume, async writer, shard-layout round-trips,
train-driver integration and the restart matrix (resume-at-completion,
crash/SIGTERM step accounting, zero3 elastic-shrink restore via the
multi-device subprocess cases), serving engine, hlo_stats counter."""
import json
import os
import pathlib
import shutil
import signal
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer,
                              Zero1CheckpointLayout, Zero3CheckpointLayout)
from repro.checkpoint.store import keep_last_k
from repro.testing import driver_cases


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    got, step = restore_checkpoint(tmp_path, jax.tree.map(np.asarray, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_latest_ignores_tmp(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    (pathlib.Path(tmp_path) / "step_9.tmp").mkdir()   # simulated crash
    assert latest_step(tmp_path) == 3


def test_keep_last_k(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, t)
    keep_last_k(tmp_path, 2)
    assert latest_step(tmp_path) == 4
    assert not (pathlib.Path(tmp_path) / "step_1").exists()


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, _tree(s))
    ck.wait()
    assert latest_step(tmp_path) == 30
    got, _ = restore_checkpoint(tmp_path, jax.tree.map(np.asarray, _tree(30)))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 _tree(30), got)


def test_train_driver_and_resume(tmp_path):
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    rc = main(["--arch", "llama3.2-3b", "--smoke", "--steps", "12",
               "--batch", "4", "--seq", "64", "--ckpt", ck,
               "--ckpt-every", "6", "--log-every", "4"])
    assert rc == 0
    assert latest_step(ck) == 12
    # resume and continue
    rc = main(["--arch", "llama3.2-3b", "--smoke", "--steps", "16",
               "--batch", "4", "--seq", "64", "--ckpt", ck,
               "--log-every", "4"])
    assert rc == 0
    assert latest_step(ck) == 16


def _canon_tree(layout, tree):
    return jax.tree_util.tree_map_with_path(layout.to_canonical, tree)


def _master_tree(layout, tree):
    return jax.tree_util.tree_map_with_path(layout.from_canonical, tree)


def test_zero3_layout_elastic_roundtrip_bit_identical():
    """The elastic-shrink acceptance at the layout level: canonical →
    (L, B, p, s) master at p=4 → canonical → master at p′=2 (different
    B′, s′, padding) → canonical, every hop bit-exact for params-like
    AND moment-like leaves."""
    rng = np.random.default_rng(0)
    canon = {"blocks": rng.normal(size=(3, 100)).astype(np.float32)}
    a = Zero3CheckpointLayout(num_layers=3, layer_elems=100,
                              num_blocks=2, num_shards=4)
    b = Zero3CheckpointLayout(num_layers=3, layer_elems=100,
                              num_blocks=3, num_shards=2)
    master_a = _master_tree(a, canon)
    assert master_a["blocks"].shape == a.master_shape
    np.testing.assert_array_equal(
        _canon_tree(a, master_a)["blocks"], canon["blocks"])
    master_b = _master_tree(b, _canon_tree(a, master_a))
    assert master_b["blocks"].shape == b.master_shape \
        != a.master_shape
    np.testing.assert_array_equal(
        _canon_tree(b, master_b)["blocks"], canon["blocks"])
    # non-master leaves (scalars, rest params) pass through untouched
    assert b.from_canonical((), np.float32(3.5)) == np.float32(3.5)


def test_zero1_layout_elastic_roundtrip_bit_identical():
    rng = np.random.default_rng(1)
    canon = {"m": rng.normal(size=(53,)).astype(np.float32),
             "count": np.zeros((), np.int32)}
    a = Zero1CheckpointLayout(total_elems=53, num_buckets=3, n=2)
    b = Zero1CheckpointLayout(total_elems=53, num_buckets=2, n=4)
    ma = _master_tree(a, canon)
    assert ma["m"].shape == (a.padded,) and ma["count"].shape == ()
    np.testing.assert_array_equal(_canon_tree(a, ma)["m"], canon["m"])
    mb = _master_tree(b, _canon_tree(a, ma))
    assert mb["m"].shape == (b.padded,) != ma["m"].shape
    np.testing.assert_array_equal(_canon_tree(b, mb)["m"], canon["m"])


def test_restore_layout_kind_mismatch_raises(tmp_path):
    save_checkpoint(tmp_path, 1, _tree())
    with pytest.raises(ValueError, match="layout mismatch"):
        restore_checkpoint(
            tmp_path, jax.tree.map(np.asarray, _tree()),
            layout=Zero3CheckpointLayout(1, 8, 1, 2))


def test_restore_shape_mismatch_raises_valueerror(tmp_path):
    """A bare assert would vanish under ``python -O`` — the mismatch must
    be a ValueError naming the leaf and both shapes."""
    save_checkpoint(tmp_path, 1, _tree())
    bad = jax.tree.map(np.asarray, _tree())
    bad["a"] = np.zeros((4, 5), np.float32)
    with pytest.raises(ValueError, match=r"leaf 0.*\(4, 5\)") as ei:
        restore_checkpoint(tmp_path, bad)
    assert "(4, 3)" in str(ei.value)


def test_async_checkpointer_worker_error_propagates(tmp_path):
    target = tmp_path / "not_a_dir"
    target.write_text("occupied")          # mkdir in the worker will fail
    ck = AsyncCheckpointer(str(target))
    ck.save(1, _tree())
    with pytest.raises(FileExistsError):
        ck.wait()
    assert ck.error is None                # error consumed by the raise


# ---------------------------------------------------------------------------
# driver restart matrix (single-device legs; the multi-pod zero3/elastic
# legs run through the 8-device subprocess cases below)
# ---------------------------------------------------------------------------

_DRIVER_ARGS = ["--arch", "llama3.2-3b", "--smoke", "--batch", "4",
                "--seq", "32", "--log-every", "2"]


class _HookedLoader:
    """Wraps the real loader; fires ``hook(step)`` before each batch."""

    def __init__(self, inner, hook):
        self._inner, self._hook = inner, hook

    def batch_at(self, step):
        self._hook(step)
        return self._inner.batch_at(step)


def _hook_loader(monkeypatch, hook):
    import repro.launch.train as T
    real = T.make_loader

    def make(*a, **kw):
        return _HookedLoader(real(*a, **kw), hook)

    monkeypatch.setattr(T, "make_loader", make)


def test_resume_at_completion_is_noop(tmp_path, capsys):
    """start_step >= --steps: the loop never runs — no losses[0]
    IndexError, and the finally block must NOT write a spurious
    step_{start+1} checkpoint."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    assert main([*_DRIVER_ARGS, "--steps", "4", "--ckpt", ck,
                 "--ckpt-every", "2"]) == 0
    assert latest_step(ck) == 4
    assert main([*_DRIVER_ARGS, "--steps", "4", "--ckpt", ck]) == 0
    out = capsys.readouterr().out
    assert "nothing to do" in out
    assert latest_step(ck) == 4
    assert not (tmp_path / "ck" / "step_5").exists()


def test_crash_saves_last_completed_step(tmp_path, monkeypatch):
    """A raise inside step k must checkpoint step k (k steps completed),
    never k+1 — saving k+1 would make resume SKIP the failed step."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")

    def hook(step):
        if step == 2:
            raise RuntimeError("injected data failure")

    _hook_loader(monkeypatch, hook)
    with pytest.raises(RuntimeError, match="injected"):
        main([*_DRIVER_ARGS, "--steps", "6", "--ckpt", ck])
    assert latest_step(ck) == 2            # steps 0 and 1 completed


def test_sigterm_emergency_checkpoint(tmp_path, monkeypatch, capsys):
    """Preemption: SIGTERM mid-run → finish the in-flight step, commit an
    emergency checkpoint for the COMPLETED count, exit cleanly."""
    from repro.launch.train import main
    ck = str(tmp_path / "ck")

    def hook(step):
        if step == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    _hook_loader(monkeypatch, hook)
    assert main([*_DRIVER_ARGS, "--steps", "20", "--ckpt", ck]) == 0
    assert "SIGTERM: emergency checkpoint" in capsys.readouterr().out
    assert latest_step(ck) == 3            # step 2 completed, then broke


def test_sigterm_emergency_surfaces_worker_error(tmp_path, monkeypatch,
                                                 capsys):
    """An AsyncCheckpointer worker failure on the emergency path must be
    REPORTED and re-raised, not die silently with the daemon thread."""
    import repro.checkpoint.store as store
    from repro.launch.train import main
    ck = str(tmp_path / "ck")

    def boom(*a, **kw):
        raise RuntimeError("disk full")

    monkeypatch.setattr(store, "save_checkpoint", boom)

    def hook(step):
        if step == 1:
            os.kill(os.getpid(), signal.SIGTERM)

    _hook_loader(monkeypatch, hook)
    with pytest.raises(RuntimeError, match="disk full"):
        main([*_DRIVER_ARGS, "--steps", "20", "--ckpt", ck])
    assert "CHECKPOINT ERROR" in capsys.readouterr().err


def _driver_results():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.run_driver_cases"],
        capture_output=True, text=True, timeout=2400)
    results = {"__stderr__": (f"rc={proc.returncode}\n"
                              + "\n".join(proc.stderr.splitlines()[-15:]))}
    for line in proc.stdout.splitlines():
        if line.startswith(("PASS ", "FAIL ")):
            status, rest = line.split(" ", 1)
            results[rest.split(":")[0].strip()] = (status, line)
    return results


_DRIVER_RESULTS = None


@pytest.mark.parametrize("case", sorted(driver_cases.CASES))
def test_driver_restart_case(case):
    global _DRIVER_RESULTS
    if _DRIVER_RESULTS is None:
        _DRIVER_RESULTS = _driver_results()
    assert case in _DRIVER_RESULTS, \
        f"case {case} produced no result (subprocess crash?):\n" \
        f"{_DRIVER_RESULTS['__stderr__']}"
    status, line = _DRIVER_RESULTS[case]
    assert status == "PASS", line


def test_serving_engine_completes():
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, Request
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(params, cfg, slots=2, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 255, size=int(rng.integers(4, 30)))
                    .astype(np.int32), max_new_tokens=6) for i in range(5)]
    done, stats = eng.run(reqs)
    assert all(len(r.out) >= 1 for r in done)
    assert stats["decode_tokens"] > 0


def test_serving_matches_unbatched_decode():
    """Continuous batching must not change greedy outputs: compare one
    request served alone vs alongside others."""
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, Request
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(2), cfg)
    prompt = np.arange(10, dtype=np.int32) % cfg.vocab_size

    def run(nreq):
        eng = ContinuousBatcher(params, cfg, slots=2, max_seq=96)
        rng = np.random.default_rng(1)
        reqs = [Request(0, prompt.copy(), max_new_tokens=5)]
        for i in range(1, nreq):
            reqs.append(Request(i, rng.integers(0, 255, size=8)
                                .astype(np.int32), max_new_tokens=5))
        eng.run(reqs)
        return reqs[0].out

    assert run(1) == run(4)


def test_hlo_stats_counts_loops():
    from repro.launch.hlo_stats import analyze
    from jax import lax

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = analyze(c.as_text())
    assert st["flops"] == 7 * 2 * 64 * 32 * 32
