"""Checkpoint atomicity/resume, async writer, train-driver integration
(loss decreases; restart continues), serving engine, hlo_stats counter."""
import json
import pathlib
import shutil

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (save_checkpoint, restore_checkpoint,
                              latest_step, AsyncCheckpointer)
from repro.checkpoint.store import keep_last_k


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "b": {"c": jnp.asarray(rng.integers(0, 9, (2,)), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 7, t)
    got, step = restore_checkpoint(tmp_path, jax.tree.map(np.asarray, t))
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b), t, got)


def test_latest_ignores_tmp(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, 3, t)
    (pathlib.Path(tmp_path) / "step_9.tmp").mkdir()   # simulated crash
    assert latest_step(tmp_path) == 3


def test_keep_last_k(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save_checkpoint(tmp_path, s, t)
    keep_last_k(tmp_path, 2)
    assert latest_step(tmp_path) == 4
    assert not (pathlib.Path(tmp_path) / "step_1").exists()


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.save(s, _tree(s))
    ck.wait()
    assert latest_step(tmp_path) == 30
    got, _ = restore_checkpoint(tmp_path, jax.tree.map(np.asarray, _tree(30)))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 _tree(30), got)


def test_train_driver_and_resume(tmp_path):
    from repro.launch.train import main
    ck = str(tmp_path / "ck")
    rc = main(["--arch", "llama3.2-3b", "--smoke", "--steps", "12",
               "--batch", "4", "--seq", "64", "--ckpt", ck,
               "--ckpt-every", "6", "--log-every", "4"])
    assert rc == 0
    assert latest_step(ck) == 12
    # resume and continue
    rc = main(["--arch", "llama3.2-3b", "--smoke", "--steps", "16",
               "--batch", "4", "--seq", "64", "--ckpt", ck,
               "--log-every", "4"])
    assert rc == 0
    assert latest_step(ck) == 16


def test_serving_engine_completes():
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, Request
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    eng = ContinuousBatcher(params, cfg, slots=2, max_seq=96)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, 255, size=int(rng.integers(4, 30)))
                    .astype(np.int32), max_new_tokens=6) for i in range(5)]
    done, stats = eng.run(reqs)
    assert all(len(r.out) >= 1 for r in done)
    assert stats["decode_tokens"] > 0


def test_serving_matches_unbatched_decode():
    """Continuous batching must not change greedy outputs: compare one
    request served alone vs alongside others."""
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import ContinuousBatcher, Request
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(2), cfg)
    prompt = np.arange(10, dtype=np.int32) % cfg.vocab_size

    def run(nreq):
        eng = ContinuousBatcher(params, cfg, slots=2, max_seq=96)
        rng = np.random.default_rng(1)
        reqs = [Request(0, prompt.copy(), max_new_tokens=5)]
        for i in range(1, nreq):
            reqs.append(Request(i, rng.integers(0, 255, size=8)
                                .astype(np.int32), max_new_tokens=5))
        eng.run(reqs)
        return reqs[0].out

    assert run(1) == run(4)


def test_hlo_stats_counts_loops():
    from repro.launch.hlo_stats import analyze
    from jax import lax

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jax.jit(f).lower(x, w).compile()
    st = analyze(c.as_text())
    assert st["flops"] == 7 * 2 * 64 * 32 * 32
