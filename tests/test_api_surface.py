"""Public-surface lock for ``repro.comm`` (satellite: CI/tooling).

Snapshot of the exported names AND their signatures: any addition,
removal, or signature change to the communicator API must edit this file
deliberately — the point of an API redesign is that the surface stops
drifting by accident.  Wired into ``make ci`` (tier1 plus its own
``api-surface`` leg).

The snapshot strings are ``str(inspect.signature(...))`` with
``from __future__ import annotations``-style quoting, exactly as the
modules produce them.
"""
import inspect

import pytest

import repro.comm as comm

EXPECTED_EXPORTS = {
    "LaneComm":
        "(topo: 'LaneTopology', cfg: 'Optional[CommConfig]' = None, *, "
        "mesh=None)",
    "CommConfig":
        "(strategy: 'str' = 'auto', buckets: 'int' = 0, prefetch_blocks: "
        "'int' = 0, compression: 'str' = 'none', record_selections: 'bool' "
        "= True, tuner: 'Optional[Tuner]' = None) -> None",
    "Selection":
        "(collective: 'str', strategy: 'str', payload_bytes: 'int', "
        "ranking: 'tuple', source: 'str' = 'model') -> None",
    "ImplEntry":
        "(collective: 'str', strategy: 'str', fn: 'Callable', cost: "
        "'Optional[Callable]' = None, auto_ok: 'bool' = True, feasible: "
        "'Optional[Callable]' = None, probe_ok: 'Optional[bool]' = None) "
        "-> None",
    "register_impl":
        "(collective: 'str', strategy: 'str', *, cost: 'Optional[Callable]'"
        " = None, auto_ok: 'bool' = True, feasible: 'Optional[Callable]' = "
        "None, probe_ok: 'Optional[bool]' = None, override: 'bool' = False)"
        " -> 'Callable'",
    "get_impl": "(collective: 'str', strategy: 'str') -> 'ImplEntry'",
    "has_impl": "(collective: 'str', strategy: 'str') -> 'bool'",
    "iter_impls": "(collective: 'str') -> 'tuple[ImplEntry, ...]'",
    "strategies_for": "(collective: 'str') -> 'tuple[str, ...]'",
    "registered_collectives": "() -> 'tuple[str, ...]'",
    "register_param_layout": "(strategy: 'str', kind: 'str') -> 'None'",
    "param_layout_kind": "(strategy: 'str') -> 'str'",
}

EXPECTED_LANECOMM_METHODS = {
    "__init__":
        "(self, topo: 'LaneTopology', cfg: 'Optional[CommConfig]' = None, "
        "*, mesh=None)",
    "sizes": "(self) -> 'tuple[int, int]'",
    "select":
        "(self, collective: 'str', payload_bytes: 'int', *, n: "
        "'Optional[int]' = None, N: 'Optional[int]' = None, lead: "
        "'Optional[int]' = None) -> 'tuple[str, tuple]'",
    "allreduce": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "reduce_scatter": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "allgather": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "bcast": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "alltoall": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "moe_route": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "reduce": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "gather": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "scatter": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "scan": "(self, x, *, strategy: 'Optional[str]' = None, **kw)",
    "grad_sync":
        "(self, grads, *, strategy: 'Optional[str]' = None, num_buckets: "
        "'Optional[int]' = None, **kw)",
    "prefetch_allgather":
        "(self, shard, *, strategy: 'Optional[str]' = None, num_blocks: "
        "'Optional[int]' = None)",
    "kv_splice":
        "(self, big, *, small, slot, batch_axis: 'int' = 1, strategy: "
        "'Optional[str]' = None, **kw)",
    "param_layout": "(self, strategy: 'Optional[str]' = None) -> 'str'",
}

# the registered strategy tables are surface too: a lost registration is
# an API break for every consumer that names the strategy
EXPECTED_STRATEGIES = {
    "allreduce": ("native", "lane", "lane_pipelined"),
    "reduce_scatter": ("native", "lane"),
    "allgather": ("native", "lane"),
    "alltoall": ("native", "lane"),
    "moe_route": ("native", "lane"),
    "scan": ("native", "lane"),
    "bcast": ("native", "lane", "lane_pipelined"),
    "reduce": ("native", "lane", "lane_pipelined"),
    "gather": ("native", "lane"),
    "scatter": ("native", "lane"),
    "grad_sync": ("native", "lane", "lane_pipelined", "lane_quorum",
                  "lane_int8", "lane_zero1", "lane_zero3"),
    "prefetch_allgather": ("lane_pipelined", "blocking"),
    "kv_splice": ("native", "lane"),
}


def test_exported_names_locked():
    assert set(comm.__all__) == set(EXPECTED_EXPORTS)
    # everything in __all__ resolves, nothing extra leaks a signature drift
    for name, sig in EXPECTED_EXPORTS.items():
        assert str(inspect.signature(getattr(comm, name))) == sig, name


def test_lanecomm_method_surface_locked():
    public = {n for n in vars(comm.LaneComm)
              if not n.startswith("_") or n == "__init__"}
    public.discard("last_selection")            # property, checked below
    assert public == set(EXPECTED_LANECOMM_METHODS)
    for name, sig in EXPECTED_LANECOMM_METHODS.items():
        got = str(inspect.signature(getattr(comm.LaneComm, name)))
        assert got == sig, (name, got)
    assert isinstance(inspect.getattr_static(comm.LaneComm,
                                             "last_selection"), property)


def test_registered_strategy_tables_locked():
    import repro.launch.steps  # noqa: F401 - registers train_step flavors
    import repro.models.transformer  # noqa: F401 - registers block_stack
    import repro.serve  # noqa: F401 - registers serve_step/serve_scenario
    for coll, strategies in EXPECTED_STRATEGIES.items():
        assert comm.strategies_for(coll) == strategies, coll
    assert comm.strategies_for("train_step") == (
        "native", "lane", "lane_pipelined", "lane_int8", "auto",
        "lane_quorum", "lane_zero1", "lane_zero3")
    # the lane-capable model families are registry surface too: the
    # zero3 runtime, the train-smoke sweep and the bench schema all
    # enumerate this table (models/blockstack.py)
    assert set(comm.strategies_for("block_stack")) == \
        {"dense", "vlm", "audio", "moe", "ssm", "hybrid"}
    # serving is a registry consumer with its own two tables: the
    # hosting flavors (serve/steps.py) and the family scenario
    # generators the benches/smoke enumerate (serve/scenarios.py)
    assert comm.strategies_for("serve_step") == ("replicated", "lane_zero3")
    assert set(comm.strategies_for("serve_scenario")) == \
        {"dense", "vlm", "audio", "moe", "ssm", "hybrid"}
    assert set(comm.registered_collectives()) == \
        set(EXPECTED_STRATEGIES) | {"train_step", "block_stack",
                                    "serve_step", "serve_scenario"}


def test_param_layout_table_locked():
    """Every registered train-step strategy declares its master layout;
    the ZeRO flavors are the only non-replicated ones (checkpoints ever
    written depend on these answers — see repro.checkpoint.layouts)."""
    import repro.launch.steps  # noqa: F401 - registers layouts
    expected = {"native": "replicated", "lane": "replicated",
                "lane_pipelined": "replicated", "lane_int8": "replicated",
                "auto": "replicated", "lane_quorum": "replicated",
                "lane_zero1": "zero1", "lane_zero3": "zero3"}
    for strategy, kind in expected.items():
        assert comm.param_layout_kind(strategy) == kind, strategy
    with pytest.raises(ValueError, match="no param layout"):
        comm.param_layout_kind("nope")


EXPECTED_TUNING_EXPORTS = {
    # table / tuner
    "TimingEntry", "TimingTable", "Tuner", "payload_bucket",
    "topology_signature", "parse_topology_signature",
    # store
    "TuningCacheError", "save_timing_table", "load_timing_table",
    "load_timing_table_or_none", "load_misses", "DEFAULT_CACHE_NAME",
    # probe
    "probe_cells", "probe_worklist", "probeable_collectives",
    "DEFAULT_LADDER", "SMOKE_LADDER",
    # fit
    "FitResult", "fit_hw", "design_row", "predicted_us",
    # report
    "build_report", "DEFAULT_TOLERANCE",
    # backend
    "apply_backend_setup", "xla_flags_for", "merge_xla_flags",
    "GPU_XLA_FLAGS", "HOST_DEVICE_COUNT_FLAG",
}

EXPECTED_TUNING_SIGNATURES = {
    "Tuner":
        "(table: 'TimingTable', *, platform: 'Optional[str]' = None, "
        "device_kind: 'Optional[str]' = None)",
    "save_timing_table":
        "(path: 'Union[str, pathlib.Path]', table: 'TimingTable', "
        "misses=None) -> 'pathlib.Path'",
    "load_misses":
        "(path: 'Union[str, pathlib.Path]') -> 'list'",
    "probe_worklist":
        "(mesh, topo, misses, *, table: 'TimingTable', reps: 'int' = 5, "
        "warmup: 'int' = 2, verbose: 'bool' = True) -> 'int'",
    "load_timing_table":
        "(path: 'Union[str, pathlib.Path]') -> 'TimingTable'",
    "load_timing_table_or_none":
        "(path: 'Union[str, pathlib.Path]') -> 'Optional[TimingTable]'",
    "fit_hw":
        "(table: 'TimingTable', *, topo_sig: 'str' = None, alpha_floor: "
        "'float' = 1e-09, beta_floor: 'float' = 1e-13) -> 'FitResult'",
    "apply_backend_setup":
        "(platform: 'str', *, host_device_count: 'Optional[int]' = None, "
        "env: 'Optional[MutableMapping]' = None) -> 'str'",
}


def test_tuning_surface_locked():
    """The measured-cost tuning subsystem is public surface: the
    CommConfig.tuner hook's provider (Tuner.measured_cost), the cache
    the driver persists beside checkpoints, and the fit/backend entry
    points are all named by drivers, benches and CI legs."""
    import repro.tuning as tuning
    assert set(tuning.__all__) == EXPECTED_TUNING_EXPORTS
    for name in EXPECTED_TUNING_EXPORTS:
        assert hasattr(tuning, name), name
    for name, sig in EXPECTED_TUNING_SIGNATURES.items():
        got = str(inspect.signature(getattr(tuning, name)))
        assert got == sig, (name, got)
    # the hook contract select() relies on: seconds-or-None per cell
    assert str(inspect.signature(tuning.Tuner.measured_cost)) == \
        "(self, collective: 'str', strategy: 'str', n: 'int', N: 'int', " \
        "payload_bytes: 'int') -> 'Optional[float]'"


def test_auto_eligibility_locked():
    """Lossy / layout-changing impls must never become auto-selectable
    without a deliberate edit here."""
    entries = {e.strategy: e for e in comm.iter_impls("grad_sync")}
    assert {s for s, e in entries.items() if e.auto_ok and e.cost} == \
        {"native", "lane", "lane_pipelined"}
    assert not entries["lane_quorum"].auto_ok   # degraded-mode only
    assert not entries["lane_int8"].auto_ok
    assert not entries["lane_zero1"].auto_ok
    assert not entries["lane_zero3"].auto_ok
    # probe eligibility is a distinct axis: the blocking prefetch control
    # is probed (probe_ok=True) while staying auto-ineligible
    pf = {e.strategy: e for e in comm.iter_impls("prefetch_allgather")}
    assert pf["blocking"].probe_ok is True and not pf["blocking"].auto_ok
    assert pf["lane_pipelined"].probe_eligible


EXPECTED_ANALYSIS_EXPORTS = {
    # diagnostics / baseline spine
    "Finding", "ERROR", "WARNING", "format_findings",
    "load_baseline", "save_baseline", "apply_baseline",
    "default_baseline_path",
    # footprint layer
    "CollOp", "CommFootprint", "comm_footprint", "analyze",
    "collective_kind_counts", "collective_concurrency",
    "collective_compute_concurrency", "scan_carried_concurrency",
    "group_info", "parse_hlo", "replica_groups", "permute_edges",
}


def test_analysis_surface_locked():
    """lanelint is public surface: the CLI, `make lint`, the hlo_stats
    back-compat shim and the benches all name these.  The package root
    must stay importable WITHOUT jax (the AST leg runs anywhere)."""
    import repro.analysis as analysis
    assert set(analysis.__all__) == EXPECTED_ANALYSIS_EXPORTS
    for name in EXPECTED_ANALYSIS_EXPORTS:
        assert hasattr(analysis, name), name
    assert str(inspect.signature(analysis.comm_footprint)) == \
        "(text: 'str', *, n: 'int', num_devices: 'Optional[int]' = None)" \
        " -> 'CommFootprint'"
    assert str(inspect.signature(analysis.scan_carried_concurrency)) == \
        "(text: 'str', *, pod_size: 'int' = 256) -> 'dict'"
    assert str(inspect.signature(analysis.Finding)) == \
        "(rule: 'str', target: 'str', message: 'str', severity: 'str' = " \
        "'error') -> None"
    # the launch/hlo_stats shim keeps re-exporting the moved core
    import repro.launch.hlo_stats as shim
    for name in ("parse_hlo", "analyze", "collective_concurrency",
                 "collective_compute_concurrency",
                 "collective_kind_counts"):
        assert getattr(shim, name) is getattr(analysis, name), name
