"""Single-device tests for the repro.comm communicator API: registry
contract, cost-model auto-dispatch ranking, CommConfig bridging, the
deprecation shims' exactly-once warning + bit-identity, and the sharded
AdamW parity fix (satellites).  Multi-device behavior runs in the
subprocess suites (collective/conformance cases)."""
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro._deprecation import reset_warned
from repro.comm import (CommConfig, LaneComm, get_impl, has_impl,
                        iter_impls, register_impl, strategies_for)
from repro.core import LaneTopology


# ---------------------------------------------------------------------------
# registry contract
# ---------------------------------------------------------------------------

def test_unknown_strategy_error_derives_from_registry():
    with pytest.raises(ValueError) as ei:
        get_impl("grad_sync", "lane_future")
    msg = str(ei.value)
    assert "registered strategies" in msg
    for s in strategies_for("grad_sync"):
        assert s in msg


def test_unknown_collective_lists_collectives():
    with pytest.raises(ValueError, match="registered collectives"):
        get_impl("allfuture", "lane")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):
        register_impl("grad_sync", "native")(lambda comm, x: x)
    # override is the deliberate escape hatch
    orig = get_impl("grad_sync", "native")
    try:
        register_impl("grad_sync", "native", override=True)(
            lambda comm, x: x)
        assert get_impl("grad_sync", "native").fn is not orig.fn
    finally:
        register_impl("grad_sync", "native", cost=orig.cost,
                      auto_ok=orig.auto_ok, feasible=orig.feasible,
                      override=True)(orig.fn)


def test_strategies_tuple_is_lazy_registry_view():
    from repro.optim import gradsync
    assert gradsync.STRATEGIES == strategies_for("grad_sync")
    with pytest.raises(AttributeError):
        gradsync.NOPE


def test_runconfig_docstring_derives_strategy_list():
    from repro.configs.base import RunConfig
    for s in strategies_for("grad_sync"):
        assert s in RunConfig.__doc__
    assert "auto" in RunConfig.__doc__


# ---------------------------------------------------------------------------
# cost-model auto-dispatch (pure ranking — no devices needed)
# ---------------------------------------------------------------------------

def _comm(**cfg):
    return LaneComm(LaneTopology(node_axes=("data",), lane_axis="pod"),
                    CommConfig(**cfg))


def test_select_small_payload_prefers_unpipelined_lane():
    # below the §5 crossover pipelining pays pure latency
    best, ranking = _comm().select("allreduce", 16 << 10, n=2, N=2)
    assert best == "lane"
    assert [t for t, _ in ranking] == sorted(t for t, _ in ranking)


def test_select_large_payload_prefers_pipelined():
    best, _ = _comm().select("allreduce", 32 << 20, n=2, N=2)
    assert best == "lane_pipelined"
    best, _ = _comm().select("grad_sync", 32 << 20, n=2, N=2)
    assert best == "lane_pipelined"


def test_select_excludes_lossy_and_layout_changing():
    _, ranking = _comm().select("grad_sync", 1 << 20, n=2, N=2)
    names = {s for _, s in ranking}
    assert names == {"native", "lane", "lane_pipelined"}


def test_select_respects_feasibility():
    # lead not divisible by n: the lane decompositions are skipped
    best, ranking = _comm().select("allreduce", 12, n=2, N=2, lead=3)
    assert best == "native" and {s for _, s in ranking} == {"native"}


def test_select_single_node_native_beats_lane():
    # N=1: the lane phase is a phantom (2 DCN alphas for nothing)
    _, ranking = _comm().select("allreduce", 1 << 20, n=8, N=1)
    cost = {s: t for t, s in ranking}
    assert cost["native"] < cost["lane"]


def test_select_deterministic():
    a = _comm().select("grad_sync", 123456, n=4, N=2)
    b = _comm().select("grad_sync", 123456, n=4, N=2)
    assert a == b


def test_bucket_override_enters_pipelined_cost():
    # a forced giant K makes the pipelined model pay K alphas
    loose = _comm(buckets=64).select("allreduce", 16 << 10, n=2, N=2)[1]
    tight = _comm().select("allreduce", 16 << 10, n=2, N=2)[1]
    assert {s: t for t, s in loose}["lane_pipelined"] > \
        {s: t for t, s in tight}["lane_pipelined"]


# ---------------------------------------------------------------------------
# CommConfig bridging
# ---------------------------------------------------------------------------

def test_commconfig_from_run():
    from repro.configs import resolve
    from repro.configs.base import RunConfig, SHAPES
    run = RunConfig(model=resolve("llama3.2-3b", smoke=True),
                    shape=SHAPES["train_4k"], gradsync="lane_int8",
                    gradsync_buckets=7, fsdp_prefetch=-1)
    cfg = CommConfig.from_run(run)
    assert cfg.strategy == "lane_int8" and cfg.buckets == 7
    assert cfg.prefetch_blocks == -1 and cfg.compression == "int8"


def test_commconfig_rejects_unknown_compression():
    with pytest.raises(ValueError, match="compression"):
        CommConfig(compression="fp4")


def test_commconfig_rejects_typod_strategy():
    # a typo'd default strategy must fail at CONSTRUCTION, not silently
    # fall back to auto at dispatch time
    with pytest.raises(ValueError, match="not registered"):
        CommConfig(strategy="lane_pipelinde")
    # any name registered for SOME collective is a valid default
    CommConfig(strategy="blocking")
    CommConfig(strategy="lane_zero3")


def test_runconfig_rejects_unknown_gradsync():
    """RunConfig validates gradsync against the registry at construction
    — dryrun used to smuggle PLAN names ("default"/"tp0") through this
    field, which silently bypassed every downstream strategy check; plans
    now ride the separate ``plan`` field."""
    from repro.configs import resolve
    from repro.configs.base import RunConfig, SHAPES
    cfg = resolve("llama3.2-3b", smoke=True)
    for bad in ("tp0", "default", "lane_pipelinde"):
        with pytest.raises(ValueError, match="unknown gradsync"):
            RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync=bad)
    # plan names are legal on the plan field, with a real strategy riding
    # gradsync (what dryrun.plan() does now)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync="auto",
                    plan="tp0")
    assert run.plan == "tp0" and run.gradsync == "auto"


def test_prefetch_explicit_num_blocks_is_strict():
    """An explicit num_blocks names a committed shard layout: an
    indivisible value must raise (silent shrinking would reassemble a
    permuted weight vector), unlike the auto path which may clamp."""
    mesh, topo = _tiny_mesh()
    comm = LaneComm(topo, mesh=mesh)
    x = jnp.arange(8, dtype=jnp.float32)
    sm = jax.shard_map(lambda s: comm.prefetch_allgather(s, num_blocks=3),
                       mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    with pytest.raises(ValueError, match="not divisible"):
        jax.jit(sm)(x)
    # the auto path on the same shard resolves a feasible B instead
    sm_auto = jax.shard_map(lambda s: comm.prefetch_allgather(s),
                            mesh=mesh, in_specs=P(), out_specs=P(),
                            check_vma=False)
    np.testing.assert_array_equal(np.asarray(jax.jit(sm_auto)(x)),
                                  np.arange(8, dtype=np.float32))


def test_prefetch_default_strategy_follows_blocking_knob():
    assert _comm(prefetch_blocks=-1)._default_strategy(
        "prefetch_allgather") == "blocking"
    assert _comm()._default_strategy("prefetch_allgather") == \
        "lane_pipelined"
    # a cfg strategy not registered for a collective falls back to auto
    assert _comm(strategy="lane_zero3")._default_strategy("allreduce") == \
        "auto"
    assert has_impl("grad_sync", "lane_zero3")
    assert _comm(strategy="lane_zero3")._default_strategy("grad_sync") == \
        "lane_zero3"


# ---------------------------------------------------------------------------
# deprecation shims: exactly-once warning + bit-identity (satellite)
# ---------------------------------------------------------------------------

def _tiny_mesh():
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    return mesh, topo


def test_grad_sync_shim_warns_exactly_once_and_matches_comm():
    from repro.optim import grad_sync
    mesh, topo = _tiny_mesh()
    comm = LaneComm(topo, mesh=mesh)
    x = jnp.arange(8, dtype=jnp.float32)

    def legacy(g):
        return grad_sync(g, topo, "lane")

    def modern(g):
        return comm.grad_sync(g, strategy="lane")

    def run(f, tag):
        sm = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        return np.asarray(jax.jit(sm)(x))

    reset_warned()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out1 = run(legacy, "a")
        out2 = run(legacy, "b")       # second trace: latch must hold
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)
                and "grad_sync" in str(x.message)]
    assert len(deps) == 1, [str(d.message) for d in deps]
    assert "repro.comm.LaneComm" in str(deps[0].message)
    np.testing.assert_array_equal(out1, out2)
    np.testing.assert_array_equal(out1, run(modern, "c"))  # bit-identical


def test_pipelined_allreduce_shim_warns_exactly_once_and_matches_comm():
    from repro.core.pipeline import pipelined_allreduce_lane
    mesh, topo = _tiny_mesh()
    comm = LaneComm(topo, mesh=mesh)
    x = jnp.arange(6, dtype=jnp.float32)

    def run(f):
        sm = jax.shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        return np.asarray(jax.jit(sm)(x))

    reset_warned()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out1 = run(lambda g: pipelined_allreduce_lane(g, topo,
                                                      num_blocks=2))
        out2 = run(lambda g: pipelined_allreduce_lane(g, topo,
                                                      num_blocks=3))
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)
                and "pipelined_allreduce_lane" in str(x.message)]
    assert len(deps) == 1, [str(d.message) for d in deps]
    np.testing.assert_array_equal(
        out1, run(lambda g: comm.allreduce(g, strategy="lane_pipelined",
                                           num_blocks=2)))
    np.testing.assert_array_equal(
        out2, run(lambda g: comm.allreduce(g, strategy="lane_pipelined",
                                           num_blocks=3)))


def test_auto_dispatch_records_selection_at_trace_time():
    mesh, topo = _tiny_mesh()
    comm = LaneComm(topo, CommConfig(strategy="auto"), mesh=mesh)
    x = jnp.arange(8, dtype=jnp.float32)
    sm = jax.shard_map(lambda g: comm.grad_sync(g), mesh=mesh,
                       in_specs=P(), out_specs=P(), check_vma=False)
    out = np.asarray(jax.jit(sm)(x))
    sel = comm.last_selection
    assert sel is not None and sel.collective == "grad_sync"
    assert sel.payload_bytes == 32
    # n=N=1: whatever wins must still BE the recorded ranking argmin
    assert sel.strategy == sel.ranking[0][1]
    np.testing.assert_allclose(out, np.arange(8, dtype=np.float32))


# ---------------------------------------------------------------------------
# sharded-AdamW parity (satellite: _adamw_flat fix, single-device algebra)
# ---------------------------------------------------------------------------

def test_adamw_flat_matches_tree_update_with_clipping_and_decay():
    """With the true global-norm scale and the decay mask, the flat
    sharded AdamW reproduces adamw_update element-for-element — clipping
    ACTIVE and matrices-only weight decay."""
    from repro.launch.steps import _adamw_flat
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.optim.adamw import global_norm
    from repro.optim.gradsync import (_flatten_bucket, _unflatten_bucket,
                                      decay_mask_flat)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32),
              "g": jnp.asarray(rng.normal(size=(9,)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(6, 4)) * 3, jnp.float32),
             "g": jnp.asarray(rng.normal(size=(9,)) * 3, jnp.float32)}
    opt = AdamWConfig(clip_norm=0.5, weight_decay=0.1)   # clipping ACTIVE
    want, _ = adamw_update(opt, grads, adamw_init(params), params)

    gflat, spec = _flatten_bucket(grads, pad_to=7)       # padding exercised
    pflat, pspec = _flatten_bucket(params, pad_to=7)
    mask = decay_mask_flat(params, pad_to=7)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, opt.clip_norm / jnp.maximum(gnorm, 1e-9))
    state = {"m": jnp.zeros_like(gflat), "v": jnp.zeros_like(gflat),
             "count": jnp.zeros((), jnp.int32)}
    newp, nst = _adamw_flat(opt, gflat, state, pflat, scale=scale,
                            decay_mask=mask)
    got = _unflatten_bucket(newp, pspec)
    for k in params:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(want[k]), rtol=1e-6,
                                   atol=1e-7, err_msg=k)
    # the moments pin the clip SCALE (params alone are ~scale-invariant
    # through m/√v): m must equal the clipped-gradient first moment
    _, wantst = adamw_update(opt, grads, adamw_init(params), params)
    mflatN, _ = _flatten_bucket(wantst["m"], pad_to=7)
    np.testing.assert_allclose(np.asarray(nst["m"]), np.asarray(mflatN),
                               rtol=1e-6, atol=1e-8)
    # without the mask the 1-D leaf would be (wrongly) decayed — guard
    # that the mask is actually doing work in this fixture (the warmup
    # lr at step 1 is ~3e-6, so the spurious decay is lr·wd·|p| ~ 1e-7)
    newp_nomask, _ = _adamw_flat(opt, gflat, state, pflat, scale=scale)
    got_nomask = _unflatten_bucket(newp_nomask, pspec)
    assert np.abs(np.asarray(got_nomask["g"])
                  - np.asarray(want["g"])).max() > 1e-8


def test_adamw_update_accepts_external_grad_norm():
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.optim.adamw import global_norm
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    grads = {"w": jnp.asarray(rng.normal(size=(4, 4)) * 5, jnp.float32)}
    opt = AdamWConfig(clip_norm=0.3)
    a, sa = adamw_update(opt, grads, adamw_init(params), params)
    b, sb = adamw_update(opt, grads, adamw_init(params), params,
                         grad_norm=global_norm(grads))
    np.testing.assert_array_equal(np.asarray(a["w"]), np.asarray(b["w"]))
    # a DIFFERENT norm must change the clip scale — visible in the
    # moments (the step-1 param delta is scale-invariant: m/√v cancels)
    _, sc = adamw_update(opt, grads, adamw_init(params), params,
                         grad_norm=global_norm(grads) * 10)
    np.testing.assert_array_equal(np.asarray(sa["m"]["w"]),
                                  np.asarray(sb["m"]["w"]))
    assert np.abs(np.asarray(sa["m"]["w"])
                  - np.asarray(sc["m"]["w"])).max() > 0


def test_decay_mask_flat_layout():
    from repro.optim.gradsync import decay_mask_flat
    tree = {"a": jnp.zeros((2, 3)), "b": jnp.zeros((4,)),
            "c": jnp.zeros((1, 1, 2))}
    m = np.asarray(decay_mask_flat(tree, pad_to=5))
    # _flatten_bucket order is jax.tree.flatten order: a, b, c; pad to 15
    want = np.concatenate([np.ones(6), np.zeros(4), np.ones(2),
                           np.zeros(3)])
    np.testing.assert_array_equal(m, want)


def test_impl_entries_have_feasibility_where_divisibility_matters():
    feas = {e.strategy: e.feasible for e in iter_impls("allreduce")}
    assert feas["native"] is None
    assert feas["lane"] is not None and feas["lane"](2, 2, 3) is False
    assert feas["lane"](2, 2, 4) is True
