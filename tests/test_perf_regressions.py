"""Hillclimb results as regression tests: the §Perf wins must not rot.

These read the tagged dry-run variants produced by
``dryrun --tag ...`` (EXPERIMENTS.md §Perf); skipped if absent.
"""
import json
import pathlib

import pytest

RUNS = pathlib.Path(__file__).resolve().parents[1] / "runs" / "dryrun"
PEAK, HBM, ICI = 197e12, 819e9, 50e9


def _load(stem):
    p = RUNS / "single" / f"{stem}.json"
    if not p.exists():
        pytest.skip(f"variant artifact missing: {p}")
    return json.loads(p.read_text())


def _terms(r):
    st = r["hlo_stats"]
    return (st["flops"] / PEAK, st["bytes"] / HBM,
            (st["ici_wire"] + st["dcn_wire"]) / ICI)


def test_tp0_beats_tp_for_small_moe():
    """EXPERIMENTS.md §Perf C1: pure-FSDP plan cuts granite-moe's
    collective term by >10× and memory by >3×."""
    base = _terms(_load("granite-moe-3b-a800m__train_4k"))
    tp0 = _terms(_load("granite-moe-3b-a800m__train_4k__tp0"))
    assert tp0[2] < base[2] / 10, (base, tp0)
    assert tp0[1] < base[1] / 3
    assert tp0[0] <= base[0] * 1.05     # no compute regression


def test_microbatch_reduction_cuts_collectives():
    """§Perf A1/B1: µ16→4 lowers the collective term 15-30% (and the
    finding that it is NOT ~4× is itself pinned here)."""
    for arch in ("qwen1.5-110b", "dbrx-132b"):
        base = _terms(_load(f"{arch}__train_4k"))
        mb4 = _terms(_load(f"{arch}__train_4k__mb4"))
        assert mb4[2] < base[2] * 0.85, arch          # it helps…
        assert mb4[2] > base[2] * 0.5, arch           # …but is second-order
        assert mb4[0] == pytest.approx(base[0], rel=1e-3)  # flops invariant


def test_bf16_accum_saves_accumulator_bytes():
    """§Perf B4: the saving equals the fp32→bf16 accumulator delta."""
    fp32 = _load("dbrx-132b__train_4k__mb4")
    bf16 = _load("dbrx-132b__train_4k__mb4bf16")
    d = (fp32["memory_analysis"]["temp_size_in_bytes"]
         - bf16["memory_analysis"]["temp_size_in_bytes"])
    expect = fp32["params"] * 2 / 256            # half of fp32 grads, FSDP
    assert d == pytest.approx(expect, rel=0.25), (d, expect)
