"""Single-device tests for the family-agnostic sharded block stack
(repro.models.blockstack): StackLayout flatten/unflatten algebra, the
BlockSpec registry, scan_stack mode equivalence (prefetch / blocking /
backward re-gather), the extras-aware Zero3CheckpointLayout, the
canonical flat-order primitives, cross-layout state conversion, and the
lane microbatch accumulator.  (The multi-device gather/step versions run
in the subprocess collective/conformance cases.)"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.checkpoint import (Zero1CheckpointLayout, Zero3CheckpointLayout,
                              concat_flat_order, split_flat_order)
from repro.configs import resolve
from repro.models.blockstack import (
    BlockSpec, ShardedStack, block_stack_families, block_stack_spec,
    resolve_prefetch_blocks, scan_stack, shard_stack, split_params,
    stack_layout,
)


# ---------------------------------------------------------------------------
# StackLayout
# ---------------------------------------------------------------------------

def _stacked_tree(L=3):
    return {"w": jnp.arange(L * 4 * 2, dtype=jnp.float32).reshape(L, 4, 2),
            "b": jnp.arange(L * 5, dtype=jnp.bfloat16).reshape(L, 5)}


def test_stack_layout_stacked():
    t = _stacked_tree()
    lay = stack_layout(t, stacked=True)
    assert lay.length == 3 and lay.row_elems == 13
    # Zero3LayerSpec-compat names
    assert lay.num_layers == 3 and lay.layer_elems == 13
    # decay mirrors adamw_update's ndim>=2 rule on the ORIGINAL leaves:
    # the replicated optimizer sees the STACKED (L, 5) array (ndim 2), so
    # per-layer vectors are decayed there — parity means decaying them in
    # the flat path too (only true per-element vectors, e.g. the
    # unstacked final-norm scale below, escape decay)
    by_meta = dict(zip(sorted(t), lay.decay))
    assert by_meta == {"b": True, "w": True}
    mat = lay.flatten(t, pad_to=8)
    assert mat.shape == (3, 16) and mat.dtype == jnp.float32
    back = lay.unflatten(np.asarray(mat))
    assert back["w"].dtype == jnp.float32 and back["b"].dtype == jnp.bfloat16
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a, np.float32), np.asarray(b, np.float32)), t, back)
    # dtype override (moment trees stay fp32)
    back32 = lay.unflatten(np.asarray(mat), dtype=np.float32)
    assert back32["b"].dtype == np.float32
    # per-row unflatten
    row0 = lay.unflatten_row(mat[0])
    np.testing.assert_array_equal(np.asarray(row0["w"]),
                                  np.asarray(t["w"][0]))


def test_stack_layout_unstacked():
    t = {"embed": {"w": jnp.ones((7, 2), jnp.float32)},
         "norm": jnp.ones((2,), jnp.float32)}
    lay = stack_layout(t, stacked=False)
    assert lay.length == 1 and lay.row_elems == 16
    assert dict(zip(["embed/w", "norm"],
                    lay.decay)) == {"embed/w": True, "norm": False}
    mat = lay.flatten(t, pad_to=5)
    assert mat.shape == (1, 20)
    back = lay.unflatten(np.asarray(mat))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, back)
    mask = np.asarray(lay.decay_mask(20))
    assert mask.shape == (20,)
    assert mask[:14].all() and not mask[14:].any()   # embed yes, norm+pad no


def test_stack_layout_errors():
    with pytest.raises(ValueError, match="empty"):
        stack_layout({}, stacked=True)
    with pytest.raises(ValueError, match="stack length"):
        stack_layout({"a": jnp.zeros((2, 3)), "b": jnp.zeros((4, 3))},
                     stacked=True)


def test_shard_stack_geometry():
    t = _stacked_tree(L=2)
    master, B = shard_stack(t, n=2, N=2, fsdp_prefetch=3)
    assert B == 3
    L, Bm, p, s = master.shape
    assert (L, Bm, p) == (2, 3, 4)
    assert Bm * p * s >= 13
    assert B == resolve_prefetch_blocks(13, 2, 2, 3)


# ---------------------------------------------------------------------------
# BlockSpec registry
# ---------------------------------------------------------------------------

def test_block_stack_registry_families():
    fams = set(block_stack_families())
    assert {"dense", "moe", "ssm", "hybrid", "vlm", "audio"} <= fams
    for arch, fam, repl in (("llama3.2-3b", "dense", ()),
                            ("mamba2-780m", "ssm", ()),
                            ("granite-moe-3b-a800m", "moe", ()),
                            ("zamba2-7b", "hybrid", ("shared_attn",))):
        spec = block_stack_spec(resolve(arch, smoke=True))
        assert isinstance(spec, BlockSpec)
        assert spec.family == fam
        assert spec.replicated_keys == repl


def test_block_stack_spec_unknown_family():
    import dataclasses
    cfg = dataclasses.replace(resolve("llama3.2-3b", smoke=True),
                              family="holographic")
    with pytest.raises(ValueError, match="no registered block_stack"):
        block_stack_spec(cfg)


def test_split_params_hybrid():
    cfg = resolve("zamba2-7b", smoke=True)
    from repro.models import init_model
    params = init_model(jax.random.PRNGKey(0), cfg)
    spec = block_stack_spec(cfg)
    stack, extras, repl = split_params(spec, params)
    assert set(repl) == {"shared_attn"}
    assert "blocks" not in extras and "shared_attn" not in extras
    assert set(extras) | {"blocks", "shared_attn"} == set(params)
    with pytest.raises(ValueError, match="no 'blocks'"):
        split_params(spec, {"embed": jnp.zeros(3)})


# ---------------------------------------------------------------------------
# scan_stack: the three modes agree in value AND gradient
# ---------------------------------------------------------------------------

def _toy_stack(L=4, D=6):
    rng = np.random.default_rng(0)
    shards = jnp.asarray(rng.normal(size=(L, D)), jnp.float32)
    gather = lambda x: {"w": x * 2.0}         # stand-in for the collective

    def body(h, lp, i):
        # index-dependent body exercises the idx plumbing (hybrid)
        scale = jnp.where(i % 2 == 0, 1.0, 0.5)
        h = h + scale * jnp.sum(lp["w"]) * h
        return h, jnp.sum(lp["w"]) * 0.1
    return shards, gather, body


@pytest.mark.parametrize("mode", ["prefetch", "blocking", "regather"])
def test_scan_stack_modes_agree(mode):
    shards, gather, body = _toy_stack()

    def loss(sh):
        stack = ShardedStack(sh, gather,
                             prefetch=(mode != "blocking"),
                             regather=(mode == "regather"))
        h, aux = scan_stack(stack, jnp.ones((3,), jnp.float32), body)
        assert aux.shape == (sh.shape[0],)
        return jnp.sum(h) + jnp.sum(aux)

    # reference: a plain python loop over the same math
    def ref(sh):
        h = jnp.ones((3,), jnp.float32)
        aux = 0.0
        for i in range(sh.shape[0]):
            h, a = body(h, gather(sh[i]), jnp.asarray(i))
            aux = aux + a
        return jnp.sum(h) + aux

    v, g = jax.value_and_grad(loss)(shards)
    vr, gr = jax.value_and_grad(ref)(shards)
    np.testing.assert_allclose(float(v), float(vr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), rtol=1e-5)


def test_regather_blocking_mutually_exclusive():
    # ShardedStack level: the blocking negative control must not be
    # silently replaced by the regather scan
    with pytest.raises(ValueError, match="blocking negative control"):
        ShardedStack(jnp.zeros((2, 4)), lambda x: x, prefetch=False,
                     regather=True)
    # step-builder level: the flag combination errors with flag names
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch.steps import build_train_step_lane
    from repro.optim import AdamWConfig
    cfg = resolve("llama3.2-3b", smoke=True)
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                    gradsync="lane_zero3", fsdp_prefetch=-1,
                    fsdp_regather=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError):
        build_train_step_lane(cfg, run, AdamWConfig(), mesh, None)


def test_family_smoke_archs_derived():
    from repro.models.blockstack import family_smoke_archs
    full = family_smoke_archs()
    assert set(full) == set(block_stack_families())
    trainable = family_smoke_archs(driver_trainable_only=True)
    # vlm/audio declare needs_extra_embeds and drop out of driver sweeps
    assert set(trainable) == set(full) - {"vlm", "audio"}
    assert {"dense", "ssm", "hybrid", "moe"} <= set(trainable)
    for fam, arch in full.items():
        assert resolve(arch, smoke=True).family == fam


def test_scan_stack_single_layer():
    shards, gather, body = _toy_stack(L=1)
    stack = ShardedStack(shards, gather)
    h, aux = scan_stack(stack, jnp.ones((3,), jnp.float32), body)
    assert aux.shape == (1,)


# ---------------------------------------------------------------------------
# Zero3CheckpointLayout with the extras pseudo-layer
# ---------------------------------------------------------------------------

def test_zero3_layout_extras_roundtrip():
    lay = Zero3CheckpointLayout(num_layers=2, layer_elems=13, num_blocks=2,
                                num_shards=4, extra_elems=9, extra_blocks=3)
    assert lay.master_shape == (2, 2, 4, 2)
    assert lay.extra_master_shape == (1, 3, 4, 1)
    rng = np.random.default_rng(3)
    cb = rng.normal(size=(2, 13)).astype(np.float32)
    ce = rng.normal(size=(1, 9)).astype(np.float32)
    pb = ("blocks",)
    pe = ("extras",)
    to_path = lambda keys: tuple(jtu.DictKey(k) for k in keys)
    mb = lay.from_canonical(to_path(pb), cb)
    me = lay.from_canonical(to_path(pe), ce)
    assert mb.shape == lay.master_shape and me.shape == lay.extra_master_shape
    np.testing.assert_array_equal(lay.to_canonical(to_path(pb), mb), cb)
    np.testing.assert_array_equal(lay.to_canonical(to_path(pe), me), ce)
    # manifest records + validates the extras geometry
    entry = lay.manifest_entry()
    assert entry["extra_elems"] == 9 and entry["extra_blocks"] == 3
    lay.check_manifest(entry)
    with pytest.raises(ValueError, match="extra_elems"):
        lay.check_manifest(dict(entry, extra_elems=11))
    # a layout without extras still round-trips blocks (old behavior)
    plain = Zero3CheckpointLayout(2, 13, 2, 4)
    assert plain.extra_master_shape is None
    np.testing.assert_array_equal(
        plain.to_canonical(to_path(pb),
                           plain.from_canonical(to_path(pb), cb)), cb)
    with pytest.raises(ValueError):
        Zero3CheckpointLayout(2, 13, 2, 4, extra_elems=9)  # blocks unset


# ---------------------------------------------------------------------------
# canonical flat order primitives
# ---------------------------------------------------------------------------

def test_flat_order_roundtrip():
    leaves = [np.arange(6, dtype=np.float64).reshape(2, 3),
              np.arange(4, dtype=np.int32)]
    flat = concat_flat_order(leaves)
    assert flat.dtype == np.float32 and flat.shape == (10,)
    back = split_flat_order(flat, [(2, 3), (4,)],
                            dtypes=[np.float64, np.int32])
    assert back[0].dtype == np.float64 and back[1].dtype == np.int32
    np.testing.assert_array_equal(back[0], leaves[0])
    np.testing.assert_array_equal(back[1], leaves[1])
    assert concat_flat_order([]).shape == (0,)
    with pytest.raises(ValueError, match="different model"):
        split_flat_order(flat, [(3, 3)])


# ---------------------------------------------------------------------------
# cross-layout conversion (host-side, mesh-free): replicated -> kind ->
# canonical -> replicated is bit-exact for fp32 smoke models
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,arch", [
    ("zero1", "llama3.2-3b"),
    ("zero3", "llama3.2-3b"),
    ("zero3", "zamba2-7b"),         # hybrid: replicated leftovers active
    ("zero3", "granite-moe-3b-a800m"),
])
def test_cross_layout_roundtrip_bitexact(kind, arch):
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch.steps import (replicated_to_state,
                                    state_to_replicated,
                                    zero1_checkpoint_layout,
                                    zero3_checkpoint_layout)
    from repro.models import init_model
    from repro.optim import adamw_init
    cfg = resolve(arch, smoke=True)
    gradsync = "lane_zero1" if kind == "zero1" else "lane_zero3"
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync=gradsync)
    params = init_model(jax.random.PRNGKey(0), cfg)
    # non-trivial moments so the layout transposes are actually exercised
    opt = adamw_init(params)
    opt = {"m": jax.tree.map(lambda p: jnp.asarray(
               np.random.default_rng(1).normal(size=p.shape), jnp.float32),
               params),
           "v": opt["v"], "count": jnp.asarray(5, jnp.int32)}
    n, N = 2, 2
    p_host, o_host = replicated_to_state(cfg, run, n, N, params, opt,
                                         kind=kind)
    layout = zero1_checkpoint_layout(params, n) if kind == "zero1" else \
        zero3_checkpoint_layout(cfg, n, N)
    canon_p = jtu.tree_map_with_path(
        lambda pth, l: layout.to_canonical(pth, np.asarray(l)), p_host)
    canon_o = jtu.tree_map_with_path(
        lambda pth, l: layout.to_canonical(pth, np.asarray(l)), o_host)
    entry = layout.manifest_entry()
    back_p, back_o = state_to_replicated(cfg, entry, (canon_p, canon_o))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, back_p)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), opt, back_o)


# ---------------------------------------------------------------------------
# microbatch accumulator
# ---------------------------------------------------------------------------

def test_microbatched_parity_fp32():
    from repro.launch.steps import _microbatched
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(4,)), jnp.float32)
    toks = jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)
    labs = jnp.asarray(rng.normal(size=(8,)), jnp.float32)

    def vg(w, t, l, e):
        def f(w):
            return jnp.mean((t @ w - l) ** 2)
        return jax.value_and_grad(f)(w)

    l0, g0 = vg(w, toks, labs, None)
    l2, g2 = jax.jit(lambda *a: _microbatched(vg, 4, jnp.float32)(*a))(
        w, toks, labs, None)
    np.testing.assert_allclose(float(l2), float(l0), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g2), np.asarray(g0), rtol=1e-5,
                               atol=1e-7)


def test_microbatched_rejects_indivisible():
    from repro.launch.steps import _microbatched
    vg = lambda w, t, l, e: (jnp.sum(t), w)
    with pytest.raises(ValueError, match="not divisible"):
        _microbatched(vg, 3, jnp.float32)(jnp.zeros(2), jnp.zeros((8, 2)),
                                          jnp.zeros(8), None)


def test_microbatched_passthrough():
    from repro.launch.steps import _microbatched
    vg = lambda *a: a
    assert _microbatched(vg, 0, jnp.float32) is vg
    assert _microbatched(vg, 1, jnp.float32) is vg


def test_run_config_validates_accum_dtype():
    from repro.configs.base import RunConfig, SHAPES
    cfg = resolve("llama3.2-3b", smoke=True)
    with pytest.raises(ValueError, match="accum_dtype"):
        RunConfig(model=cfg, shape=SHAPES["train_4k"], accum_dtype="fp8")
