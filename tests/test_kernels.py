"""Pallas kernel allclose sweeps (interpret=True on CPU) vs ref.py oracles:
shapes × dtypes × mask modes for flash attention; shapes × chunkings for
the SSD kernel."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.ssd import ssd_tpu
from repro.kernels import ref


def _qkv(B, H, K, Tq, Tk, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, H, Tq, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, K, Tk, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, K, Tk, hd)), dtype)
    return q, k, v


ATT_SHAPES = [
    # B, H, K, Tq, Tk, hd, bq, bk
    (1, 2, 2, 128, 128, 64, 64, 64),
    (2, 4, 2, 256, 256, 64, 128, 128),
    (1, 8, 2, 256, 512, 32, 128, 128),    # GQA G=4, cross lengths
    (1, 2, 1, 512, 512, 128, 256, 128),   # MQA
]


@pytest.mark.parametrize("shape", ATT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["causal", "full", "window"])
def test_flash_attention_allclose(shape, dtype, mode):
    B, H, K, Tq, Tk, hd, bq, bk = shape
    if mode == "causal" and Tq != Tk:
        pytest.skip("causal requires square here")
    causal = mode == "causal"
    window = 96 if mode == "window" else 0
    q, k, v = _qkv(B, H, K, Tq, Tk, hd, dtype)
    out = flash_attention_tpu(q, k, v, causal=causal, window=window,
                              block_q=bq, block_k=bk, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


SSD_SHAPES = [
    # b, H, T, P, S, chunk, hb
    (1, 4, 64, 32, 32, 16, 4),
    (2, 8, 128, 32, 64, 32, 4),
    (1, 8, 128, 64, 128, 64, 8),
    (2, 4, 96, 16, 16, 32, 2),
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_allclose(shape, dtype):
    b, H, T, P, S, chunk, hb = shape
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(b, H, T, P)), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(b, H, T)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, T, S)), dtype)
    Cm = jnp.asarray(rng.normal(size=(b, T, S)), dtype)
    y = ssd_tpu(x, dt, A, Bm, Cm, chunk=chunk, heads_blk=hb, interpret=True)
    want = ref.ssd_ref(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_padding_kblocks():
    """nk not dividing Tk: trailing keys must be masked, not read OOB."""
    q, k, v = _qkv(1, 2, 2, 128, 96, 32, jnp.float32)
    out = flash_attention_tpu(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
