"""The serving tier's pinning suite (tentpole: batched == sequential).

Four layers:

1. The equivalence matrix (this process, smoke configs): for EVERY
   supported family, greedy continuous batching — multiple slots,
   shuffled admission order, mid-stream refills, bursty arrivals — is
   token-identical to serving each request alone at batch 1.  Decode
   batching must be pure throughput, never a semantic.
2. Sampling properties (pure numpy/jax, hypothesis when installed with
   the same deterministic seeded fallback as test_conformance): top-p
   renormalizes to a distribution and never selects out-of-nucleus
   tokens; temperature → 0 converges to argmax; the seeded sampler is a
   pure function of (seed, rid, position).
3. Termination and admission: eos / max_new_tokens / max_seq fire
   exactly once (the capacity reason at the function level — a
   validated admit makes it unreachable end to end); the seed engine's
   silent prompt truncation stays dead (exact-bucket, bucket+1 and
   over-budget regressions).
4. The multi-host tier (8-device subprocess,
   repro.testing.serve_cases): zero3-hosted serving — sharded slots,
   1/p gathered weights, kv_splice distribution, checkpoint restores —
   token-identical to replicated hosting.
"""
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import resolve
from repro.models import init_model
from repro.models.blockstack import family_smoke_archs
from repro.serve import (ContinuousBatcher, Request, SamplerConfig,
                         build_serve_step, make_scenario, request_key,
                         sample_token, scenario_families,
                         termination_reason, top_p_renormalize)
from repro.testing import serve_cases

# ---------------------------------------------------------------------------
# hypothesis, with a deterministic fallback sweep (same shim as
# test_conformance — coverage must not shrink on the minimal container)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover - env dep
    HAVE_HYPOTHESIS = False

    class _Ints:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Floats:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _Sampled:
        def __init__(self, xs):
            self.xs = list(xs)

        def draw(self, rng):
            return self.xs[int(rng.integers(len(self.xs)))]

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Ints(min_value, max_value)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Floats(min_value, max_value)

        @staticmethod
        def sampled_from(xs):
            return _Sampled(xs)

    def settings(**_kw):
        def deco(f):
            return f
        return deco

    def given(**strategies):
        def deco(f):
            # NOT functools.wraps: pytest would read the wrapped signature
            # and treat the strategy parameters as fixtures
            def run():
                rng = np.random.default_rng(0)
                for _ in range(25):
                    f(**{k: s.draw(rng) for k, s in strategies.items()})
            run.__name__ = f.__name__
            run.__doc__ = f.__doc__
            return run
        return deco


MAX_SEQ = 96
FAMILY_ARCHS = family_smoke_archs()


def _params(cfg, seed=0):
    return init_model(jax.random.PRNGKey(seed), cfg)


def _clone(r):
    return Request(r.rid, r.prompt, max_new_tokens=r.max_new_tokens,
                   arrival_step=r.arrival_step, extra=r.extra)


# ---------------------------------------------------------------------------
# layer 1: the batched-vs-sequential equivalence matrix
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
def test_batched_equals_sequential(family):
    """Greedy continuous batching (3 slots, 5 requests -> mid-stream
    refills) is token-identical to batch-1 sequential decode, for every
    supported family, under both submission orders."""
    cfg = resolve(FAMILY_ARCHS[family], smoke=True)
    params = _params(cfg)
    reqs = make_scenario(cfg, kind="mixed", n=5, seed=3, max_seq=MAX_SEQ)
    for r in reqs:
        r.arrival_step = 0            # ordering is the variable here

    step1 = build_serve_step(cfg, max_seq=MAX_SEQ, slots=1)
    sequential = {}
    for r in reqs:
        eng = ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ,
                                step=step1)
        (got,), _ = eng.run([_clone(r)])
        sequential[r.rid] = got.out
        assert got.finish_reason == "length", got.finish_reason

    step3 = build_serve_step(cfg, max_seq=MAX_SEQ, slots=3)
    for order in (list(reqs), list(reqs)[::-1]):
        eng = ContinuousBatcher(params, cfg, slots=3, max_seq=MAX_SEQ,
                                step=step3)
        done, stats = eng.run([_clone(r) for r in order])
        assert stats["decode_tokens"] > 0
        for r in done:
            assert r.out == sequential[r.rid], \
                (family, r.rid, r.out, sequential[r.rid])


def test_bursty_arrivals_match_sequential():
    """arrival_step staggering (slots drain and refill mid-stream) must
    not change any request's tokens either."""
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    params = _params(cfg)
    reqs = make_scenario(cfg, kind="bursty", n=7, seed=5, max_seq=MAX_SEQ)
    assert len({r.arrival_step for r in reqs}) > 1, \
        "bursty scenario must stagger arrivals"
    step1 = build_serve_step(cfg, max_seq=MAX_SEQ, slots=1)
    sequential = {}
    for r in reqs:
        eng = ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ,
                                step=step1)
        rr = _clone(r)
        rr.arrival_step = 0
        eng.run([rr])
        sequential[r.rid] = rr.out
    eng = ContinuousBatcher(params, cfg, slots=2, max_seq=MAX_SEQ)
    done, _ = eng.run([_clone(r) for r in reqs])
    for r in done:
        assert r.done and r.out == sequential[r.rid], (r.rid, r.out)


def test_seeded_replay_is_batching_invariant():
    """Same SamplerConfig -> same tokens per rid at slots=1 and slots=3:
    sampled serving replays regardless of slot assignment."""
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    params = _params(cfg)
    samp = SamplerConfig(temperature=0.9, top_p=0.8, seed=7)
    outs = []
    for slots in (1, 3):
        eng = ContinuousBatcher(params, cfg, slots=slots, max_seq=MAX_SEQ,
                                sampler=samp)
        done, _ = eng.run(make_scenario(cfg, kind="short_chat", n=5,
                                        seed=2, max_seq=MAX_SEQ))
        outs.append({r.rid: r.out for r in done})
    assert outs[0] == outs[1], outs


def test_injected_step_geometry_checked():
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    step = build_serve_step(cfg, max_seq=64, slots=2)
    with pytest.raises(ValueError, match="max_seq"):
        ContinuousBatcher(_params(cfg), cfg, slots=2, max_seq=MAX_SEQ,
                          step=step)


# ---------------------------------------------------------------------------
# layer 2: sampling properties
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**16), v=st.integers(4, 64),
       conc=st.sampled_from([0.1, 0.5, 2.0]),
       top_p=st.floats(0.05, 1.0))
def test_top_p_renormalizes_and_stays_in_nucleus(seed, v, conc, top_p):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(np.full(v, conc)).astype(np.float32)
    q = np.asarray(top_p_renormalize(jnp.asarray(p), top_p))
    assert abs(float(q.sum()) - 1.0) < 1e-4
    assert (q >= 0).all()
    order = np.argsort(-p)
    exclusive = np.cumsum(p[order]) - p[order]
    nucleus = set(order[exclusive < top_p].tolist())
    assert nucleus, "top-1 must always be kept"
    outside = [i for i in range(v) if i not in nucleus and q[i] > 0]
    assert not outside, (top_p, outside)


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**16), rid=st.integers(0, 2**20),
       pos=st.integers(0, 512))
def test_sampler_is_pure_in_seed_rid_position(seed, rid, pos):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal(33), jnp.float32)
    samp = SamplerConfig(temperature=0.7, top_p=0.9, seed=seed)
    a = int(sample_token(logits, samp, rid, pos))
    b = int(sample_token(logits, samp, rid, pos))
    assert a == b
    assert np.array_equal(np.asarray(request_key(seed, rid, pos)),
                          np.asarray(request_key(seed, rid, pos)))


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**16))
def test_temperature_to_zero_converges_to_argmax(seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal(47) * 3, jnp.float32)
    greedy = int(jnp.argmax(logits))
    assert int(sample_token(logits, SamplerConfig(temperature=0.0),
                            1, 1)) == greedy
    for t in (1e-2, 1e-3):
        got = int(sample_token(
            logits, SamplerConfig(temperature=t, seed=seed), 1, 1))
        assert got == greedy, (t, got, greedy)


@settings(deadline=None, max_examples=25)
@given(seed=st.integers(0, 2**16), top_p=st.floats(0.05, 0.95))
def test_sampler_never_selects_zero_probability(seed, top_p):
    """Tokens masked to probability zero (by top-p or by -inf logits)
    must never be sampled, at any (rid, position)."""
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal(21).astype(np.float32)
    dead = rng.choice(21, size=7, replace=False)
    logits[dead] = -np.inf
    samp = SamplerConfig(temperature=1.3, top_p=top_p, seed=seed)
    for pos in range(8):
        tok = int(sample_token(jnp.asarray(logits), samp, seed, pos))
        assert tok not in dead, (pos, tok)


# ---------------------------------------------------------------------------
# layer 3: termination + admission
# ---------------------------------------------------------------------------

@settings(deadline=None, max_examples=25)
@given(tok=st.integers(0, 50), n_out=st.integers(1, 40),
       length=st.integers(1, 128), eos=st.integers(-1, 50),
       max_new=st.integers(1, 40), max_seq=st.sampled_from([64, 96, 128]))
def test_termination_reason_priority_and_coverage(tok, n_out, length, eos,
                                                  max_new, max_seq):
    got = termination_reason(tok, n_out, length, eos_id=eos,
                             max_new_tokens=max_new, max_seq=max_seq)
    if eos >= 0 and tok == eos:
        assert got == "eos"
    elif n_out >= max_new:
        assert got == "length"
    elif length >= max_seq:
        assert got == "max_seq"
    else:
        assert got is None


def test_termination_reason_each_category_reachable():
    kw = dict(eos_id=5, max_new_tokens=4, max_seq=32)
    assert termination_reason(5, 1, 10, **kw) == "eos"
    assert termination_reason(3, 4, 10, **kw) == "length"
    assert termination_reason(3, 2, 32, **kw) == "max_seq"
    assert termination_reason(3, 2, 10, **kw) is None
    # eos wins over simultaneous budget exhaustion
    assert termination_reason(5, 4, 32, **kw) == "eos"


def test_engine_eos_and_length_fire_exactly_once():
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    params = _params(cfg)
    prompt = (np.arange(9, dtype=np.int32) % cfg.vocab_size) + 1
    probe = Request(0, prompt, max_new_tokens=8)
    ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ).run([probe])
    assert probe.finish_reason == "length" and len(probe.out) == 8
    # use a token the greedy run actually emits mid-stream as eos
    eos_tok, k = probe.out[3], 3
    r = Request(0, prompt, max_new_tokens=8)
    eng = ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ,
                            eos_id=eos_tok)
    eng.run([r])
    first_hit = probe.out.index(eos_tok)
    assert r.finish_reason == "eos" and first_hit <= k
    assert r.out == probe.out[:first_hit + 1]
    # finish_reason is write-once: _finish_if_done asserts on overwrite,
    # and a finished request's slot is freed (no further tokens)
    assert r.done and len(r.out) == first_hit + 1


def test_admit_exact_bucket_boundary():
    """L == bucket and L == bucket + 1 must both serve the FULL prompt
    (the seed engine silently truncated to the bucket)."""
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    params = _params(cfg)
    step = build_serve_step(cfg, max_seq=MAX_SEQ, slots=1)

    def serve_prompt(L):
        eng = ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ,
                                step=step)
        r = Request(0, (np.arange(L, dtype=np.int32) % 200) + 1,
                    max_new_tokens=3)
        eng.run([r])
        return eng, r

    eng32, r32 = serve_prompt(32)
    assert eng32._bucket_for(32) == 32 and len(r32.out) == 3
    eng33, r33 = serve_prompt(33)
    assert eng33._bucket_for(33) == 64 and len(r33.out) == 3
    # the two prompts share a 32-token prefix but must NOT produce the
    # same first token trajectory by truncation: check against a direct
    # batch-1 decode of the longer prompt through a fresh engine at a
    # bucket that holds it exactly
    eng64, r64 = serve_prompt(64)
    assert eng64._bucket_for(64) == 64 and len(r64.out) == 3


def test_admit_over_budget_raises():
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    params = _params(cfg)
    eng = ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ)
    bad = Request(9, (np.arange(90, dtype=np.int32) % 200) + 1,
                  max_new_tokens=10)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.run([bad])
    with pytest.raises(ValueError, match="empty prompt"):
        ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ).run(
            [Request(1, np.zeros((0,), np.int32))])
    # boundary: exactly max_seq budget is admitted
    ok = Request(2, (np.arange(MAX_SEQ - 10, dtype=np.int32) % 200) + 1,
                 max_new_tokens=10)
    ContinuousBatcher(params, cfg, slots=1, max_seq=MAX_SEQ).run([ok])
    assert ok.finish_reason == "length" and len(ok.out) == 10


def test_recurrent_families_prefill_exact_length():
    """ssm/hybrid fold every consumed token into their state, so their
    bucket IS the prompt length (pad tokens would contaminate the
    recurrence); attention families keep power-of-two-ish buckets."""
    ssm = resolve(FAMILY_ARCHS["ssm"], smoke=True)
    dense = resolve(FAMILY_ARCHS["dense"], smoke=True)
    e_ssm = ContinuousBatcher(_params(ssm), ssm, slots=1, max_seq=MAX_SEQ)
    e_dense = ContinuousBatcher(_params(dense), dense, slots=1,
                                max_seq=MAX_SEQ)
    assert e_ssm._bucket_for(13) == 13
    assert e_dense._bucket_for(13) == 32


# ---------------------------------------------------------------------------
# scenario generator
# ---------------------------------------------------------------------------

def test_scenarios_cover_registry_families_and_replay():
    from repro.comm import strategies_for
    assert set(scenario_families()) == set(strategies_for("block_stack"))
    for family, arch in sorted(FAMILY_ARCHS.items()):
        cfg = resolve(arch, smoke=True)
        for kind in ("short_chat", "long_context", "bursty", "mixed"):
            a = make_scenario(cfg, kind=kind, n=4, seed=9, max_seq=MAX_SEQ)
            b = make_scenario(cfg, kind=kind, n=4, seed=9, max_seq=MAX_SEQ)
            assert len(a) == 4
            for ra, rb in zip(a, b):
                assert np.array_equal(ra.prompt, rb.prompt)
                assert (ra.max_new_tokens, ra.arrival_step) == \
                    (rb.max_new_tokens, rb.arrival_step)
                if cfg.family in ("vlm", "audio"):
                    assert ra.extra is not None
                    assert np.array_equal(ra.extra, rb.extra)
    with pytest.raises(ValueError, match="unknown scenario kind"):
        make_scenario(resolve(FAMILY_ARCHS["dense"], smoke=True),
                      kind="nope", n=1, seed=0, max_seq=MAX_SEQ)


def test_long_context_spans_buckets():
    cfg = resolve(FAMILY_ARCHS["dense"], smoke=True)
    reqs = make_scenario(cfg, kind="long_context", n=6, seed=4,
                         max_seq=MAX_SEQ)
    assert any(len(r.prompt) > 32 for r in reqs), \
        "long_context must cross the smallest bucket"


# ---------------------------------------------------------------------------
# layer 4: the multi-host serve tier (8-device subprocess)
# ---------------------------------------------------------------------------

def _serve_results():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.run_serve_cases"],
        capture_output=True, text=True, timeout=3600)
    results = {"__stderr__": (f"rc={proc.returncode}\n"
                              + "\n".join(proc.stderr.splitlines()[-15:]))}
    for line in proc.stdout.splitlines():
        if line.startswith(("PASS ", "FAIL ")):
            status, rest = line.split(" ", 1)
            results[rest.split(":")[0].strip()] = (status, line)
    return results


_SERVE_RESULTS = None


@pytest.mark.parametrize("case", sorted(serve_cases.CASES))
def test_multihost_serve_case(case):
    global _SERVE_RESULTS
    if _SERVE_RESULTS is None:
        _SERVE_RESULTS = _serve_results()
    assert case in _SERVE_RESULTS, \
        f"case {case} produced no result (subprocess crash?):\n" \
        f"{_SERVE_RESULTS['__stderr__']}"
    status, line = _SERVE_RESULTS[case]
    assert status == "PASS", line
