"""Hardened conformance suite for the lane collectives.

Two layers:

1. The multi-device conformance grid (``repro.testing.conformance_cases``
   — every lane collective × odd topologies × bf16/int32 × odd payloads
   × root variants × divisibility errors) executed once in an 8-device
   subprocess, one pytest case per grid cell.

2. Property-based oracle-algebra checks (pure numpy, this process):
   the single-process oracles must satisfy the MPI-semantics identities
   the mock-ups are tested against, so a bug in an oracle cannot silently
   validate a matching bug in a mock-up.  Hypothesis-driven when
   hypothesis is installed; otherwise a deterministic seeded sweep draws
   the same strategies (the suite must not lose coverage on the minimal
   container — see requirements-dev.txt).
"""

import subprocess
import sys

import numpy as np
import pytest

from repro.core.costmodel import mockup_cost
from repro.testing import conformance_cases
from repro.core import ref as _ref

# ---------------------------------------------------------------------------
# hypothesis, with a deterministic fallback sweep
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                              # pragma: no cover - env dep
    HAVE_HYPOTHESIS = False

    class _Ints:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _Sampled:
        def __init__(self, xs):
            self.xs = list(xs)

        def draw(self, rng):
            return self.xs[int(rng.integers(len(self.xs)))]

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Ints(min_value, max_value)

        @staticmethod
        def sampled_from(xs):
            return _Sampled(xs)

    def settings(**_kw):
        def deco(f):
            return f
        return deco

    def given(**strategies):
        def deco(f):
            # NOT functools.wraps: pytest would read the wrapped signature
            # and treat the strategy parameters as fixtures
            def run():
                rng = np.random.default_rng(0)
                for _ in range(25):
                    f(**{k: s.draw(rng) for k, s in strategies.items()})
            run.__name__ = f.__name__
            run.__doc__ = f.__doc__
            return run
        return deco


# ---------------------------------------------------------------------------
# layer 1: the multi-device grid (subprocess, one pytest case per cell)
# ---------------------------------------------------------------------------

def _run_all():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.testing.run_conformance_cases"],
        capture_output=True, text=True, timeout=1200)
    results = {}
    for line in proc.stdout.splitlines():
        if line.startswith(("PASS ", "FAIL ")):
            status, rest = line.split(" ", 1)
            name = rest.split(":")[0].strip()
            results[name] = (status, line)
    # keep the crash context: an import-time failure produces zero result
    # lines and everything a developer needs is on stderr
    diag = (f"runner exit={proc.returncode}; stderr tail:\n"
            + "\n".join(proc.stderr.splitlines()[-15:]))
    return results, diag


_RESULTS = None


def _results():
    global _RESULTS
    if _RESULTS is None:
        _RESULTS = _run_all()
    return _RESULTS


@pytest.mark.parametrize("case", sorted(conformance_cases.CASES))
def test_conformance_case(case):
    res, diag = _results()
    assert case in res, \
        f"case {case} produced no result (runner crash?)\n{diag}"
    status, line = res[case]
    assert status == "PASS", line


def test_grid_covers_every_lane_collective():
    """The grid itself is conformant: every collective named by the PR-2
    mandate appears across every topology, and the dtype axis is present."""
    names = sorted(conformance_cases.CASES)
    for coll in conformance_cases.NAMED:
        for topo in conformance_cases.TOPOS:
            assert any(n.startswith(f"{coll}__{topo}__") for n in names), \
                (coll, topo)
        for dt in ("bf16", "int32"):
            assert any(n == f"{coll}__t3__{dt}" for n in names), (coll, dt)


# ---------------------------------------------------------------------------
# layer 2: oracle algebra (the identities the mock-ups are judged against)
# ---------------------------------------------------------------------------

def _xs(p, m, seed, feat=2):
    return np.random.default_rng(seed).normal(
        size=(p, m, feat)).astype(np.float32)


@settings(max_examples=50, deadline=None)
@given(p=st.integers(1, 12), m=st.integers(1, 5),
       seed=st.integers(0, 1000))
def test_oracle_ag_of_rs_is_allreduce(p, m, seed):
    xs = _xs(p, p * m, seed)
    rs = _ref.oracle_reduce_scatter(xs)
    np.testing.assert_allclose(_ref.oracle_allgather(rs),
                               _ref.oracle_allreduce(xs), rtol=1e-5)


@settings(max_examples=50, deadline=None)
@given(p=st.integers(1, 12), m=st.integers(1, 5),
       root=st.integers(0, 11), seed=st.integers(0, 1000))
def test_oracle_scatter_inverts_gather(p, m, root, seed):
    root = root % p
    xs = _xs(p, m, seed)
    g = _ref.oracle_gather(xs, root=root)
    np.testing.assert_allclose(_ref.oracle_scatter(g, root=root), xs)


@settings(max_examples=50, deadline=None)
@given(p=st.integers(1, 12), m=st.integers(1, 4),
       seed=st.integers(0, 1000))
def test_oracle_alltoall_is_involution(p, m, seed):
    xs = _xs(p, p * m, seed)
    np.testing.assert_allclose(_ref.oracle_alltoall(_ref.oracle_alltoall(xs)),
                               xs)


@settings(max_examples=50, deadline=None)
@given(p=st.integers(1, 12), m=st.integers(1, 5),
       seed=st.integers(0, 1000))
def test_oracle_scan_telescopes(p, m, seed):
    """Last rank of the inclusive scan = the allreduce total; first
    differences recover the inputs (MPI_Scan semantics)."""
    xs = _xs(p, m, seed)
    sc = _ref.oracle_scan(xs)
    np.testing.assert_allclose(sc[-1], _ref.oracle_allreduce(xs)[0],
                               rtol=1e-5)
    np.testing.assert_allclose(np.diff(sc, axis=0), xs[1:], rtol=1e-5,
                               atol=1e-6)


@settings(max_examples=50, deadline=None)
@given(p=st.integers(1, 12), m=st.integers(1, 5),
       root=st.integers(0, 11), seed=st.integers(0, 1000))
def test_oracle_reduce_is_rooted_allreduce(p, m, root, seed):
    root = root % p
    xs = _xs(p, m, seed)
    red = _ref.oracle_reduce(xs, root=root)
    ar = _ref.oracle_allreduce(xs)
    np.testing.assert_allclose(red[root], ar[root], rtol=1e-5)
    mask = np.ones(p, bool)
    mask[root] = False
    assert not red[mask].any()


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 32), N=st.integers(1, 32),
       c=st.integers(1, 10_000))
def test_fulllane_volumes_for_named_collectives(n, N, c):
    """§3 conservation: the six named mock-ups keep the per-node
    inter-node volume at (or under) the full-lane ideal."""
    b = mockup_cost("bcast", n, N, c)
    assert b.vol_internode_per_node == c
    for coll in ("gather", "scatter"):
        g = mockup_cost(coll, n, N, c)
        assert g.vol_node + g.vol_lane == (n * N - 1) * c
    rs = mockup_cost("reduce_scatter", n, N, c)
    assert rs.vol_internode_per_node <= c
    a2a = mockup_cost("alltoall", n, N, c)
    assert a2a.vol_lane == (N - 1) * n * c


def test_fallback_shim_is_deterministic():
    """When hypothesis is absent the sweep must be reproducible (the CI
    leg pins --hypothesis-seed=0 for the real thing; the shim's rng is
    seeded the same way every run)."""
    if HAVE_HYPOTHESIS:
        pytest.skip("hypothesis installed: determinism owned by "
                    "--hypothesis-seed")
    draws = []

    @given(a=st.integers(0, 100), b=st.sampled_from(["x", "y"]))
    def probe(a, b):
        draws.append((a, b))

    probe()
    first = list(draws)
    draws.clear()
    probe()
    assert draws == first and len(first) == 25
