# NOTE: deliberately NO XLA_FLAGS / device-count manipulation here — the
# main test process must see the real single CPU device (project policy).
# Multi-device coverage runs through subprocesses (test_collectives.py).
import os
import sys
import pathlib

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")
