"""Hypothesis property tests on the system's invariants.

Covers the paper's §3 cost algebra (full-lane volume conservation), the
§5 pipeline step count, loss masking, data-pipeline determinism/
partitioning, gradient-compression bounds, and elastic-mesh planning.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.costmodel import mockup_cost, speedup_bound
from repro.core.pipeline import pipeline_steps
from repro.configs import resolve, all_archs
from repro.models.transformer import loss_fn
from repro.models import init_model
from repro.data import make_loader
from repro.optim.gradsync import compress_int8, decompress_int8
from repro.runtime import plan_elastic_mesh

sizes = st.integers(min_value=2, max_value=64)
counts = st.integers(min_value=1, max_value=10_000)


# ---------------------------------------------------------------------------
# paper §3: cost-model invariants
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(n=sizes, N=sizes, c=counts)
def test_fulllane_internode_volume(n, N, c):
    """Bcast/allgather: total data in/out of a node is the full-lane ideal —
    c for bcast (§3.1), (N-1)·n·c? no: (p-n)·c/… — use the paper's exact
    expressions and check consistency relations instead of re-deriving."""
    b = mockup_cost("bcast", n, N, c)
    assert b.vol_internode_per_node == c                 # §3.1: exactly c
    ag = mockup_cost("allgather", n, N, c)
    # per-process total volume is optimal (p-1)c (§3.3)
    assert ag.vol_node + ag.vol_lane == (n * N - 1) * c
    ar = mockup_cost("allreduce", n, N, c)
    # §3.4: 2(p-1)/p·c total per process, up to the n/N split granularity
    total = ar.vol_node + ar.vol_lane
    assert total <= 2 * c
    assert total >= 2 * c * (n * N - 1) / (n * N) - 1e-9


@settings(max_examples=100, deadline=None)
@given(n=sizes, N=sizes, c=counts)
def test_scatter_gather_optimal_volume(n, N, c):
    g = mockup_cost("gather", n, N, c)
    p = n * N
    assert g.vol_node + g.vol_lane == (p - 1) * c        # §3.2 optimal
    assert g.vol_internode_per_node == (p - n) * c


@settings(max_examples=100, deadline=None)
@given(n=sizes, N=sizes, k=st.integers(1, 8))
def test_speedup_bound(n, N, k):
    s = speedup_bound("allreduce", n, N, k)
    assert 1 <= s <= max(k, 1)


@settings(max_examples=100, deadline=None)
@given(B=st.integers(1, 64), N=st.integers(2, 64))
def test_pipeline_step_count(B, N):
    """Prop. 1: steps = B + N - 1 = T_single(p/k, c/k) + O(1)."""
    assert pipeline_steps(B, N) == B + N - 1


# ---------------------------------------------------------------------------
# loss invariants
# ---------------------------------------------------------------------------

def _tiny():
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_loss_mask_drops_positions(seed):
    cfg, params = _tiny()
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    l_full = loss_fn(params, cfg, toks, labels)
    # masking all but one position = CE of that position alone
    masked = jnp.full_like(labels, -100).at[0, 3].set(labels[0, 3])
    l_one = loss_fn(params, cfg, toks, masked)
    assert np.isfinite(float(l_full)) and np.isfinite(float(l_one))
    # fully masked → zero CE (only aux, which is 0 for dense)
    l_none = loss_fn(params, cfg, toks, jnp.full_like(labels, -100))
    assert float(l_none) == 0.0


# ---------------------------------------------------------------------------
# data pipeline: determinism + host partition correctness
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 10_000), hosts=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 100))
def test_loader_determinism_and_partition(step, hosts, seed):
    cfg = resolve("llama3.2-3b", smoke=True)
    gb, sl = 8, 32
    ref = make_loader(cfg, sl, gb, seed=seed).batch_at(step)
    parts = [make_loader(cfg, sl, gb, seed=seed, host_index=h,
                         num_hosts=hosts).batch_at(step) for h in range(hosts)]
    toks = np.concatenate([p[0] for p in parts])
    labs = np.concatenate([p[1] for p in parts])
    np.testing.assert_array_equal(toks, ref[0])
    np.testing.assert_array_equal(labs, ref[1])
    # determinism across instances
    again = make_loader(cfg, sl, gb, seed=seed).batch_at(step)
    np.testing.assert_array_equal(again[0], ref[0])


# ---------------------------------------------------------------------------
# gradient compression: error bound
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5000),
       scale=st.floats(1e-3, 1e3))
def test_int8_roundtrip_bound(seed, n, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)) * scale, jnp.float32)
    q, s, n0 = compress_int8(x)
    y = decompress_int8(q, s, n0)
    err = np.abs(np.asarray(y) - np.asarray(x))
    # per-chunk bound: half a quantization step
    chunks = np.asarray(x)
    pad = (-n) % 1024
    cm = np.abs(np.pad(chunks, (0, pad))).reshape(-1, 1024).max(1)
    bound = np.repeat(cm / 127.0, 1024)[:n] * 0.5 + 1e-6
    assert (err <= bound + 1e-5 * cm.max()).all()


# ---------------------------------------------------------------------------
# elastic mesh planning
# ---------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(lost_pod=st.integers(0, 1))
def test_elastic_drop_pod(lost_pod):
    shape = (2, 4, 4)
    lost = [lost_pod * 16 + i for i in range(16)]
    em = plan_elastic_mesh(("pod", "data", "model"), shape, lost)
    assert em.shape == (1, 4, 4)
    assert em.global_batch_scale == 0.5


@settings(max_examples=50, deadline=None)
@given(bad=st.lists(st.integers(0, 3), min_size=1, max_size=3, unique=True))
def test_elastic_drop_data_rows(bad):
    # single-pod mesh: lose chips in given data rows
    shape = (4, 4)
    lost = [b * 4 + 1 for b in bad]
    em = plan_elastic_mesh(("data", "model"), shape, lost)
    assert em.shape[0] == 4 - len(set(bad))
    assert em.shape[1] == 4


def test_elastic_noop():
    em = plan_elastic_mesh(("pod", "data", "model"), (2, 16, 16), [])
    assert em.shape == (2, 16, 16) and em.global_batch_scale == 1.0
