"""Tier-1 coverage of the measured-cost tuning subsystem (repro.tuning).

Everything here runs on the main pytest process's single CPU device:
the table/store/fit layers are device-free, the dispatch tests trace on
a 1×1 mesh, and the one real probe runs three tiny cells.  The key
contracts under test (ISSUE acceptance):

  * a planted timing cache CONTRADICTING the spec-sheet model flips the
    recorded auto Selection (measured costs actually drive dispatch);
  * the cache round-trips through save → load → save bit-identically;
  * a stale topology signature or corrupt cache degrades dispatch to
    the closed-form model — never crashes it;
  * the fitter recovers known alpha/beta from synthetic timings.
"""
import dataclasses
import json

import numpy as np
import pytest

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.costmodel import HW, get_hw, set_hw
from repro.core.lane import LaneTopology
from repro.comm import CommConfig, LaneComm
from repro.tuning import (
    DEFAULT_TOLERANCE, TimingEntry, TimingTable, Tuner, TuningCacheError,
    apply_backend_setup, build_report, design_row, fit_hw,
    load_timing_table, load_timing_table_or_none, merge_xla_flags,
    parse_topology_signature, payload_bucket, probe_cells,
    save_timing_table, topology_signature, xla_flags_for,
)


def _entry(coll, strat, sig, payload, med, **kw):
    return TimingEntry(coll, strat, sig, payload, med,
                       kw.pop("min_us", med), kw.pop("reps", 3))


# ---------------------------------------------------------------------------
# table: buckets, signatures, lookup interpolation
# ---------------------------------------------------------------------------

def test_payload_bucket():
    assert payload_bucket(1) == 1
    assert payload_bucket(2) == 2
    assert payload_bucket(3) == 4
    assert payload_bucket(4096) == 4096
    assert payload_bucket(4097) == 8192
    assert payload_bucket(0) == 1        # degenerate payloads clamp


def test_topology_signature_roundtrip():
    sig = topology_signature(4, 2, platform="cpu", device_kind="host x")
    assert sig == "cpu/host_x/n4xN2"
    assert parse_topology_signature(sig) == (4, 2)
    with pytest.raises(ValueError, match="malformed"):
        parse_topology_signature("cpu/host/whatever")


def test_lookup_interpolation():
    sig = "cpu/cpu/n2xN2"
    t = TimingTable([_entry("grad_sync", "lane", sig, 1 << 12, 100.0),
                     _entry("grad_sync", "lane", sig, 1 << 16, 1600.0)])
    # exact probed sizes
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 12) == 100.0
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 16) == 1600.0
    # log-log midpoint of (2^12, 100) .. (2^16, 1600) = (2^14, 400)
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 14) == \
        pytest.approx(400.0, rel=1e-6)
    # within 2x beyond either end: linear byte scaling
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 17) == \
        pytest.approx(3200.0, rel=1e-6)
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 11) == \
        pytest.approx(50.0, rel=1e-6)
    # outside the trusted margin, or the wrong cell: a miss
    assert t.lookup_us("grad_sync", "lane", sig, 1 << 20) is None
    assert t.lookup_us("grad_sync", "native", sig, 1 << 12) is None
    assert t.lookup_us("grad_sync", "lane", "cpu/cpu/n1xN1", 1 << 12) is None


def test_measure_once_put_and_merge():
    sig = "cpu/cpu/n2xN2"
    t = TimingTable()
    assert t.put(_entry("grad_sync", "lane", sig, 4096, 10.0))
    # same cell (same bucket) measured again: first one is committed
    assert not t.put(_entry("grad_sync", "lane", sig, 4096, 99.0))
    assert t.lookup_us("grad_sync", "lane", sig, 4096) == 10.0
    other = TimingTable([_entry("grad_sync", "lane", sig, 4096, 99.0),
                         _entry("grad_sync", "native", sig, 4096, 7.0)])
    assert t.merge(other) == 1           # only the new cell lands
    assert t.lookup_us("grad_sync", "lane", sig, 4096) == 10.0
    assert len(t) == 2


# ---------------------------------------------------------------------------
# store: bit-identical round-trip + corruption fallback
# ---------------------------------------------------------------------------

def test_store_roundtrip_bit_identical(tmp_path):
    sig = topology_signature(2, 2, platform="cpu", device_kind="cpu")
    t = TimingTable([
        _entry("grad_sync", "native", sig, 4096, 123.45, min_us=100.0),
        _entry("grad_sync", "lane", sig, 4096, 222.5),
        _entry("allreduce", "lane_pipelined", sig, 1 << 15, 999.0),
    ])
    p = save_timing_table(tmp_path / "cache.json", t)
    restored = load_timing_table(p)
    assert restored.to_doc() == t.to_doc()
    p2 = save_timing_table(tmp_path / "cache2.json", restored)
    assert p2.read_bytes() == p.read_bytes()
    # and through the checkpoint-directory pattern: save-over is stable
    p3 = save_timing_table(p, restored)
    assert p3.read_bytes() == p2.read_bytes()


def test_corrupt_cache_never_crashes_dispatch(tmp_path):
    path = tmp_path / "cache.json"
    t = TimingTable([_entry("grad_sync", "lane", "cpu/cpu/n1xN1",
                            4096, 10.0)])
    save_timing_table(path, t)
    doc = json.loads(path.read_text())
    doc["payload"]["entries"][0]["median_us"] = 1e9   # rot a field
    path.write_text(json.dumps(doc))
    with pytest.raises(TuningCacheError, match="crc32"):
        load_timing_table(path)
    assert load_timing_table_or_none(path) is None
    path.write_text("{not json")
    assert load_timing_table_or_none(path) is None
    with pytest.raises(TuningCacheError, match="unreadable"):
        load_timing_table(path)
    assert load_timing_table_or_none(tmp_path / "absent.json") is None
    # version skew is a schema failure, not a crash
    save_timing_table(path, t)
    doc = json.loads(path.read_text())
    doc["payload"]["version"] = 999
    import zlib
    body = json.dumps(doc["payload"], sort_keys=True,
                      separators=(",", ":"))
    doc["crc32"] = zlib.crc32(body.encode())
    path.write_text(json.dumps(doc))
    with pytest.raises(TuningCacheError, match="version"):
        load_timing_table(path)
    # the dispatch-facing hook swallows even a broken table object
    class Boom:
        def lookup_us(self, *a):
            raise RuntimeError("rotten")
    tn = Tuner(Boom(), platform="cpu", device_kind="cpu")
    assert tn.measured_cost("grad_sync", "lane", 1, 1, 4096) is None


def test_misses_persist_without_breaking_byte_identity(tmp_path):
    """The persisted miss worklist (PR-8 follow-up): misses ride the
    cache payload, deduplicated and sorted, and the key is absent on a
    miss-free save — so the original byte-identity property holds."""
    from repro.tuning import load_misses
    sig = topology_signature(2, 2, platform="cpu", device_kind="cpu")
    t = TimingTable([_entry("grad_sync", "lane", sig, 4096, 10.0)])
    p0 = save_timing_table(tmp_path / "c.json", t)
    clean = p0.read_bytes()
    m = ("grad_sync", "native", 2, 2, 12345)
    save_timing_table(p0, t, misses=[m, list(m), ("allreduce", "lane",
                                                  2, 2, 64)])
    assert load_misses(p0) == [("allreduce", "lane", 2, 2, 64), m]
    assert load_timing_table(p0).to_doc() == t.to_doc()  # entries intact
    # a miss-free re-save drops the key and restores the exact bytes
    assert save_timing_table(p0, t).read_bytes() == clean
    assert load_misses(p0) == []
    # misses are advisory: corrupt/missing files yield [], never raise
    assert load_misses(tmp_path / "absent.json") == []
    p0.write_text("{not json")
    assert load_misses(p0) == []


def test_probe_eligibility_flag():
    """probe_ok decouples probing from auto-eligibility: the blocking
    prefetch control is probed (never auto-selected); an explicit
    probe_ok=False excludes a priced cell."""
    from repro.comm.registry import ImplEntry, get_impl
    import repro.comm.impls  # noqa: F401 — populate the registry
    def fn(comm, x):
        return x
    cost = lambda n, N, c, cfg: 1.0      # noqa: E731
    assert not ImplEntry("c", "s", fn).probe_eligible          # unpriced
    assert ImplEntry("c", "s", fn, cost=cost).probe_eligible
    assert not ImplEntry("c", "s", fn, cost=cost,
                         auto_ok=False).probe_eligible
    assert ImplEntry("c", "s", fn, auto_ok=False,
                     probe_ok=True).probe_eligible
    assert not ImplEntry("c", "s", fn, cost=cost,
                         probe_ok=False).probe_eligible
    e = get_impl("prefetch_allgather", "blocking")
    assert not e.auto_ok and e.probe_eligible


def test_probe_worklist_replays_misses():
    """probe_worklist measures exactly the recorded misses at the
    payloads dispatch asked for, skipping stale topologies and
    collectives the harness cannot drive."""
    from repro.tuning import probe_worklist
    mesh = _mesh11()
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    table = TimingTable()
    misses = [
        ("grad_sync", "lane", 1, 1, 4096),
        ("grad_sync", "lane", 1, 1, 4096),        # dup: probed once
        ("grad_sync", "native", 1, 1, 4096),
        ("grad_sync", "lane", 4, 2, 4096),        # stale topology
        ("kv_splice", "native", 1, 1, 4096),      # not probeable
    ]
    probed = probe_worklist(mesh, topo, misses, table=table, reps=2,
                            warmup=1, verbose=False)
    assert probed == 2
    assert {(e.collective, e.strategy) for e in table.entries()} == \
        {("grad_sync", "lane"), ("grad_sync", "native")}
    # idempotent: replaying the same worklist measures nothing new
    assert probe_worklist(mesh, topo, misses, table=table, reps=2,
                          warmup=1, verbose=False) == 0


# ---------------------------------------------------------------------------
# dispatch: measured costs outrank the model; stale signatures fall back
# ---------------------------------------------------------------------------

def _mesh11():
    return jax.make_mesh((1, 1), ("pod", "data"))


def _trace_grad_sync(comm, mesh, elems=64):
    """Trace (not run) one auto grad_sync; returns the recorded Selection."""
    def f(g):
        return comm.grad_sync(g)
    fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P(("pod", "data")),
                               out_specs=P(), check_vma=False))
    x = np.zeros((elems,), np.float32)
    fn.lower(jax.device_put(x, NamedSharding(mesh, P(("pod", "data")))))
    return comm.last_selection


def test_planted_cache_flips_selection():
    """THE acceptance test: a timing cache contradicting the spec-sheet
    model must flip the recorded auto Selection to the measured winner.

    On the 1×1 mesh the closed-form model ranks lane_pipelined LAST
    (its pipeline pays pure latency; native/lane cost ~0 there), so a
    cache that measured lane_pipelined fastest is a direct
    contradiction — dispatch must follow the measurement.
    """
    mesh = _mesh11()
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    sig = topology_signature(1, 1)       # live-backend platform fields
    payload = 64 * 4
    table = TimingTable([
        _entry("grad_sync", "lane_pipelined", sig, payload, 5.0),
        _entry("grad_sync", "native", sig, payload, 300.0),
        _entry("grad_sync", "lane", sig, payload, 400.0),
    ])
    # control: model-only dispatch does NOT pick lane_pipelined
    sel0 = _trace_grad_sync(
        LaneComm(topo, CommConfig(), mesh=mesh), mesh)
    assert sel0.source == "model"
    assert sel0.strategy != "lane_pipelined"
    # with the cache: measured ranking, measured winner
    comm = LaneComm(topo, CommConfig(tuner=Tuner(table)), mesh=mesh)
    sel = _trace_grad_sync(comm, mesh)
    assert sel.strategy == "lane_pipelined"
    assert sel.source == "measured"
    assert sel.ranking[0] == (pytest.approx(5e-6), "lane_pipelined")
    # ranking stays ((seconds, strategy), ...) 2-tuples for consumers
    for t, s in sel.ranking:
        assert isinstance(t, float) and isinstance(s, str)


def test_partial_cache_measured_tier_wins():
    """Measure-once-then-commit: one measured cell outranks every
    closed-form cell even when its seconds are larger."""
    mesh = _mesh11()
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    sig = topology_signature(1, 1)
    table = TimingTable([
        _entry("grad_sync", "lane_pipelined", sig, 64 * 4, 10_000.0)])
    comm = LaneComm(topo, CommConfig(tuner=Tuner(table)), mesh=mesh)
    sel = _trace_grad_sync(comm, mesh)
    assert sel.strategy == "lane_pipelined"
    assert sel.source == "measured"
    # the unmeasured cells are recorded as misses for the next probe
    missed = {s for _, s, *_ in comm.cfg.tuner.misses}
    assert missed == {"native", "lane"}


def test_stale_topology_signature_falls_back_to_model():
    """A cache probed on another topology (or backend) must not match:
    dispatch silently degrades to the closed-form model."""
    mesh = _mesh11()
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    stale_sig = topology_signature(4, 2, platform="cpu", device_kind="cpu")
    table = TimingTable([
        _entry("grad_sync", "lane_pipelined", stale_sig, 64 * 4, 5.0)])
    comm = LaneComm(topo, CommConfig(tuner=Tuner(table)), mesh=mesh)
    sel = _trace_grad_sync(comm, mesh)
    assert sel.source == "model"
    assert sel.strategy != "lane_pipelined"


def test_probe_fills_table_and_drives_dispatch():
    """The real probe on the 1×1 mesh: every auto-eligible grad_sync
    cell lands in the table and subsequent dispatch is measured."""
    mesh = _mesh11()
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    table = probe_cells(mesh, topo, collectives=("grad_sync",),
                        ladder=(1 << 10,), reps=2, warmup=1,
                        verbose=False)
    assert {e.strategy for e in table.entries()} == \
        {"native", "lane", "lane_pipelined"}
    assert all(e.median_us > 0 and e.min_us <= e.median_us
               for e in table.entries())
    # measure-once: a second probe pass adds nothing
    n0 = len(table)
    probe_cells(mesh, topo, collectives=("grad_sync",), ladder=(1 << 10,),
                reps=2, warmup=1, table=table, verbose=False)
    assert len(table) == n0
    comm = LaneComm(topo, CommConfig(tuner=Tuner(table)), mesh=mesh)
    sel = _trace_grad_sync(comm, mesh, elems=(1 << 10) // 4)
    assert sel.source == "measured"


# ---------------------------------------------------------------------------
# fit: recover known constants from synthetic timings
# ---------------------------------------------------------------------------

def test_fitter_recovers_known_hw():
    true = HW(alpha_ici=3e-6, ici_bw=40e9, alpha_dcn=25e-6, dcn_bw=20e9)
    x = np.array([true.alpha_ici, 1 / true.ici_bw,
                  true.alpha_dcn, 1 / true.dcn_bw])
    sig = topology_signature(4, 2, platform="cpu", device_kind="cpu")
    entries = []
    for payload in (1 << 12, 1 << 15, 1 << 18):
        for strat in ("native", "lane", "lane_pipelined"):
            us = float(design_row("grad_sync", strat, 4, 2, payload)
                       @ x) * 1e6
            entries.append(_entry("grad_sync", strat, sig, payload, us))
    fit = fit_hw(TimingTable(entries))
    assert fit.params["alpha_ici"] == pytest.approx(3e-6, rel=1e-3)
    assert fit.params["alpha_dcn"] == pytest.approx(25e-6, rel=1e-3)
    assert fit.hw.ici_bw == pytest.approx(40e9, rel=1e-3)
    assert fit.hw.dcn_bw == pytest.approx(20e9, rel=1e-3)
    assert fit.residual_rms_us == pytest.approx(0.0, abs=1e-3)
    assert fit.num_cells == 9 and len(fit.cells) == 9


def test_fit_is_clamped_and_degenerate_safe():
    # one cell cannot identify four parameters; the solution must still
    # come back positive (clamped), never negative or zero
    sig = topology_signature(2, 2, platform="cpu", device_kind="cpu")
    fit = fit_hw(TimingTable([_entry("grad_sync", "native", sig,
                                     4096, 50.0)]))
    assert all(v > 0 for v in fit.params.values())
    assert fit.hw.ici_bw > 0 and fit.hw.dcn_bw > 0
    with pytest.raises(ValueError, match="no fittable cells"):
        fit_hw(TimingTable())


def test_tune_restore_adopts_fitted_hw(capsys):
    """PR-10 satellite: on --tune restore the driver feeds fit_hw output
    through core.costmodel.set_hw BEFORE step building, so a planted
    timing cache reprices the closed-form costs for the whole run — and
    the adoption (or its skip) is recorded in the run log."""
    from repro.comm.costs import native_cost
    from repro.launch.train import _adopt_fitted_hw
    true = HW(alpha_ici=3e-6, ici_bw=40e9, alpha_dcn=25e-6, dcn_bw=5e9)
    x = np.array([true.alpha_ici, 1 / true.ici_bw,
                  true.alpha_dcn, 1 / true.dcn_bw])
    sig = topology_signature(4, 2, platform="cpu", device_kind="cpu")
    entries = [
        _entry("grad_sync", strat, sig, payload,
               float(design_row("grad_sync", strat, 4, 2, payload) @ x)
               * 1e6)
        for payload in (1 << 12, 1 << 15, 1 << 18)
        for strat in ("native", "lane", "lane_pipelined")
    ]
    c = native_cost("allreduce")
    base = c(4, 2, 1 << 20, CommConfig())
    prev = get_hw()
    try:
        _adopt_fitted_hw(Tuner(TimingTable(entries), platform="cpu",
                               device_kind="cpu"))
        hw = get_hw()
        assert hw.ici_bw == pytest.approx(40e9, rel=1e-3)
        assert hw.dcn_bw == pytest.approx(5e9, rel=1e-3)
        # the adopted constants reprice dispatch costs at CALL time
        assert c(4, 2, 1 << 20, CommConfig()) != pytest.approx(base)
        assert "cost-model HW adopted" in capsys.readouterr().out
    finally:
        set_hw(prev)
    # no tuner (no --tune) and an unfittable cache are recorded no-ops:
    # the shipped constants stay active
    _adopt_fitted_hw(None)
    assert get_hw() == prev
    _adopt_fitted_hw(Tuner(TimingTable(), platform="cpu",
                           device_kind="cpu"))
    assert get_hw() == prev
    assert "adoption skipped" in capsys.readouterr().out


def test_active_hw_reprices_costs():
    """set_hw flows into the closed-form costs at CALL time (the fitted
    constants reprice every ranking without re-registering anything)."""
    from repro.comm.costs import native_cost
    c = native_cost("allreduce")
    base = c(4, 2, 1 << 20, CommConfig())
    prev = set_hw(dataclasses.replace(get_hw(), dcn_bw=get_hw().dcn_bw / 4))
    try:
        assert c(4, 2, 1 << 20, CommConfig()) > base * 2
    finally:
        set_hw(prev)
    assert c(4, 2, 1 << 20, CommConfig()) == pytest.approx(base)


# ---------------------------------------------------------------------------
# guideline report + backend setup
# ---------------------------------------------------------------------------

def test_build_report_flags_violations():
    sig = topology_signature(2, 2, platform="cpu", device_kind="cpu")
    ok_t = TimingTable([
        _entry("grad_sync", "native", sig, 4096, 100.0),
        _entry("grad_sync", "lane", sig, 4096, 150.0),
        _entry("allreduce", "native", sig, 4096, 100.0),
        _entry("allreduce", "lane", sig, 4096, 90.0),
    ])
    rep = build_report(ok_t, tolerance=2.0)
    assert rep["ok"] and rep["violations"] == 0
    cells = {c["collective"]: c for c in rep["cells"]}
    assert not cells["grad_sync"]["beats_native"]
    assert cells["allreduce"]["beats_native"]
    assert cells["allreduce"]["best_strategy"] == "lane"
    bad = TimingTable([
        _entry("grad_sync", "native", sig, 4096, 100.0),
        _entry("grad_sync", "lane", sig, 4096, 500.0),
    ])
    rep = build_report(bad, tolerance=2.0)
    assert not rep["ok"] and rep["violations"] == 1
    assert rep["cells"][0]["status"] == "violation"
    assert DEFAULT_TOLERANCE >= 1.0


def test_backend_setup_merge_idempotent():
    assert xla_flags_for("cpu", host_device_count=8) == \
        {"--xla_force_host_platform_device_count": "8"}
    assert xla_flags_for("tpu") == {}
    gpu = xla_flags_for("gpu")
    assert gpu["--xla_gpu_enable_async_collectives"] == "true"
    assert gpu["--xla_gpu_enable_latency_hiding_scheduler"] == "true"
    with pytest.raises(ValueError, match="unknown platform"):
        xla_flags_for("quantum")
    merged = merge_xla_flags(
        "--user_flag=1 --xla_force_host_platform_device_count=2",
        {"--xla_force_host_platform_device_count": "8"})
    assert merged == \
        "--user_flag=1 --xla_force_host_platform_device_count=8"
    # idempotent: applying the same flags twice changes nothing
    assert merge_xla_flags(
        merged, {"--xla_force_host_platform_device_count": "8"}) == merged
    env = {}
    out = apply_backend_setup("cpu", host_device_count=8, env=env)
    assert env["XLA_FLAGS"] == out
    assert apply_backend_setup("cpu", host_device_count=8, env=env) == out


# ---------------------------------------------------------------------------
# extras pseudo-layer prefetch resolution (satellite regression)
# ---------------------------------------------------------------------------

def test_extras_prefetch_gets_own_resolution():
    from repro.models.blockstack import (resolve_extras_prefetch_blocks,
                                         resolve_prefetch_blocks)
    n, N = 4, 2
    big = 1 << 24                        # a vocab·d-sized extras row
    model_b = resolve_prefetch_blocks(big, n, N, 0)
    assert model_b > 2                   # cost model wants real depth here
    # a positive override tuned for the LAYER stack is not inherited:
    # extras resolves from its own payload
    assert resolve_extras_prefetch_blocks(big, n, N, 2) == model_b
    assert resolve_prefetch_blocks(big, n, N, 2) == 2
    # the blocking negative control still reaches the extras gather
    assert resolve_extras_prefetch_blocks(big, n, N, -1) == 1
    assert resolve_extras_prefetch_blocks(big, n, N, 0) == model_b


def test_shard_stack_extras_uses_own_resolution():
    import jax.numpy as jnp
    from repro.models.blockstack import (resolve_extras_prefetch_blocks,
                                         shard_stack, stack_layout)
    extras = {"w": jnp.arange(64.0), "b": jnp.arange(8.0)}
    lay = stack_layout(extras, stacked=False)
    _, B = shard_stack(extras, 2, 2, fsdp_prefetch=3, stacked=False)
    assert B == resolve_extras_prefetch_blocks(lay.row_elems, 2, 2, 3)
    assert B == 1                        # model-resolved, not the layer 3
    stacked = {"w": jnp.zeros((2, 64)), "b": jnp.zeros((2, 8))}
    _, Bs = shard_stack(stacked, 2, 2, fsdp_prefetch=3)
    assert Bs == 3                       # the layer stack keeps overrides
