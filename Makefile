# CI / local developer targets.
#
# `make ci` is what every PR must keep green:
#   tier1         — the test suite (with the 8-host-device flag so the
#                   multi-device subprocess cases are exercised even where
#                   the runner defaults differ)
#   props-det     — the property suites re-run with a PINNED hypothesis
#                   seed so a red property leg is reproducible verbatim;
#                   where hypothesis isn't installed the suites already
#                   ran in tier1 through their built-in seeded fallback
#                   (see tests/test_conformance.py), so the leg is a no-op
#   api-surface   — the repro.comm public-surface lock (names, signatures,
#                   registered strategy tables) re-run on its own so a
#                   surface break is named even when tier1 dies earlier
#   bench-smoke   — lowers the gradient-sync strategies and structurally
#                   verifies the §5 lane/node overlap on the optimized HLO
#                   (writes BENCH_gradsync.json)
#   bench-schema  — fails the build if the benchmark silently stopped
#                   emitting a strategy or a row field; the required
#                   strategy list derives from the repro.comm registry
#   train-smoke   — drives the TRAINING DRIVER (launch/train.py) across
#                   every registered gradsync strategy on the 8-device
#                   multi-pod CPU mesh with a save→restore round-trip,
#                   so a strategy the driver can't actually serve fails
#                   the build (the strategy list derives from the
#                   registry; incl. auto and the ZeRO layouts)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci tier1 props-det api-surface bench-smoke bench bench-schema \
	train-smoke test

tier1:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PY) -m pytest -x -q

test: tier1

# the 8-device conformance grid is deselected from props-det: it is
# exhaustive, not property-based, and tier1 already ran it
props-det:
	@if $(PY) -c "import hypothesis" 2>/dev/null; then \
		$(PY) -m pytest -q tests/test_properties.py \
			tests/test_conformance.py --hypothesis-seed=0 \
			-k "not test_conformance_case"; \
	else \
		echo "hypothesis absent: property suites ran via the seeded" \
		     "fallback in tier1"; \
	fi

api-surface:
	$(PY) -m pytest -q tests/test_api_surface.py

bench-smoke:
	$(PY) -m benchmarks.run --smoke

bench:
	$(PY) -m benchmarks.run

bench-schema:
	$(PY) -m benchmarks.check_bench_schema

# sets its own 8-device flag internally (before jax import)
train-smoke:
	$(PY) -m repro.launch.train_smoke

ci: tier1 props-det api-surface bench-smoke bench-schema train-smoke
