# CI / local developer targets.
#
# `make ci` is what every PR must keep green: the tier-1 suite (with the
# 8-host-device flag so the multi-device subprocess cases are exercised
# even where the runner defaults differ) plus the benchmark smoke, which
# lowers the gradient-sync strategies and structurally verifies the §5
# lane/node overlap on the optimized HLO (writes BENCH_gradsync.json).

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci tier1 bench-smoke bench test

tier1:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PY) -m pytest -x -q

test: tier1

bench-smoke:
	$(PY) -m benchmarks.run --smoke

bench:
	$(PY) -m benchmarks.run

ci: tier1 bench-smoke
