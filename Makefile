# CI / local developer targets.
#
# `make ci` is what every PR must keep green:
#   tier1         — the test suite (with the 8-host-device flag so the
#                   multi-device subprocess cases are exercised even where
#                   the runner defaults differ)
#   props-det     — the property suites re-run with a PINNED hypothesis
#                   seed so a red property leg is reproducible verbatim;
#                   where hypothesis isn't installed the suites already
#                   ran in tier1 through their built-in seeded fallback
#                   (see tests/test_conformance.py), so the leg is a no-op
#   api-surface   — the repro.comm public-surface lock (names, signatures,
#                   registered strategy tables) re-run on its own so a
#                   surface break is named even when tier1 dies earlier
#   tune-smoke    — the measured-cost tuning loop (repro.tuning) end to
#                   end on the host-platform 2×4 mesh: probe the
#                   registered (collective, strategy) cells at the
#                   reduced ladder, commit tuning_cache.json (verified
#                   bit-identical through a save→load→save round-trip),
#                   fit HW constants, and write the decomposed-vs-native
#                   guideline report (BENCH_tuning.json) — fails on a
#                   guideline violation above tolerance; bench-smoke
#                   then feeds the committed cache to gradsync_bench so
#                   the auto row dispatches on measured costs
#   bench-smoke   — lowers the gradient-sync strategies and structurally
#                   verifies the §5 lane/node overlap on the optimized HLO
#                   (writes BENCH_gradsync.json), then drives the
#                   injected-fault recovery ladder and measures steps
#                   lost / time-to-recover / quorum overhead (writes
#                   BENCH_recovery.json)
#   bench-schema  — fails the build if a benchmark silently stopped
#                   emitting a strategy or a row field; the required
#                   strategy list derives from the repro.comm registry
#   fault-smoke   — the fault-injection driver matrix alone (the
#                   ``fault_*`` cases of testing/driver_cases.py:
#                   corrupt-latest fallback, kill-mid-write .old swap,
#                   transient-I/O retry, quorum bit-identity, the
#                   DEGRADED→RESTART ladder) — tier1 also runs these
#                   per-case; this leg names a red recovery path even
#                   when tier1 dies earlier
#   train-smoke   — drives the TRAINING DRIVER (launch/train.py) across
#                   every registered gradsync strategy on the 8-device
#                   multi-pod CPU mesh with a save→restore round-trip,
#                   so a strategy the driver can't actually serve fails
#                   the build (the strategy list derives from the
#                   registry; incl. auto and the ZeRO layouts)
#   tp-smoke      — the THIRD parallelism axis through the driver:
#                   sweeps --model-parallel 2 (tensor parallelism over
#                   the mesh's 'model' axis) and --expert-parallel (MoE
#                   routing as the decomposed moe_route alltoall, incl.
#                   --ep-blocks 2 pipelined routing) over dense + MoE
#                   archs × lane/lane_zero3, each with a checkpoint
#                   save→restore round trip
#   lint          — lanelint (repro.analysis): lowers EVERY registered
#                   (collective, strategy) cell plus the train/serve
#                   step builders on the 8-host-device grid and checks
#                   the R1-R4 communication invariants against the
#                   closed-form algebra, then runs the A1-A4
#                   architectural AST rules over src/repro/**; exit 1
#                   on any unsuppressed finding (suppressions live in
#                   lint_baseline.json, each with a justification),
#                   exit 2 if the lint itself breaks
#   serve-smoke   — drives the SERVING TIER (repro.serve) end to end:
#                   the registry-derived scenario generator through the
#                   continuous batcher for a bucketed and an exact-
#                   length-prefill family, then a training-driver
#                   checkpoint restored into serving with zero3-hosted
#                   tokens byte-identical to replicated (the full
#                   hosting × family matrix runs in tier1 via
#                   testing/serve_cases.py; this leg names a red
#                   serving path even when tier1 dies earlier)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: ci tier1 props-det api-surface tune-smoke bench-smoke bench \
	bench-schema train-smoke tp-smoke fault-smoke serve-smoke lint test

tier1:
	XLA_FLAGS="--xla_force_host_platform_device_count=8 $$XLA_FLAGS" \
		$(PY) -m pytest -x -q

test: tier1

# the 8-device conformance grid is deselected from props-det: it is
# exhaustive, not property-based, and tier1 already ran it
props-det:
	@if $(PY) -c "import hypothesis" 2>/dev/null; then \
		$(PY) -m pytest -q tests/test_properties.py \
			tests/test_conformance.py --hypothesis-seed=0 \
			-k "not test_conformance_case"; \
	else \
		echo "hypothesis absent: property suites ran via the seeded" \
		     "fallback in tier1"; \
	fi

api-surface:
	$(PY) -m pytest -q tests/test_api_surface.py

# sets its own 8-device flag internally (before jax import); the schema
# of the emitted BENCH_tuning.json is validated in the same leg
tune-smoke:
	$(PY) -m repro.tuning.tune_smoke
	$(PY) -c "import json, sys; \
		from benchmarks.check_bench_schema import check_tuning; \
		errs = check_tuning(json.load(open('BENCH_tuning.json'))); \
		[print('SCHEMA FAIL:', e) for e in errs]; \
		print('schema ok: BENCH_tuning.json' if not errs else ''); \
		sys.exit(1 if errs else 0)"

bench-smoke:
	$(PY) -m benchmarks.run --smoke

bench:
	$(PY) -m benchmarks.run

bench-schema:
	$(PY) -m benchmarks.check_bench_schema

# sets its own 8-device flag internally (before jax import)
train-smoke:
	$(PY) -m repro.launch.train_smoke

# sets its own 8-device flag internally (before jax import)
tp-smoke:
	$(PY) -m repro.launch.tp_smoke

# sets its own 8-device flag internally (before jax import)
fault-smoke:
	$(PY) -m repro.testing.run_driver_cases --match fault_

# sets its own 8-device flag internally (before jax import)
serve-smoke:
	$(PY) -m repro.serve.serve_smoke

# sets its own 8-device flag internally (before jax import)
lint:
	$(PY) -m repro.analysis.lint

ci: tier1 props-det api-surface lint tune-smoke bench-smoke bench-schema \
	train-smoke tp-smoke fault-smoke serve-smoke
