"""Self-consistent performance-guideline checking (paper §3/§4, refs [9,19]).

A guideline says: the native implementation of a collective must not be
slower than a correct mock-up built from other collectives of the same
library.  The paper benchmarks MPI mock-ups against native MPI; we benchmark
XLA's one-shot lowering against the explicit full-lane decomposition, both
in wall-clock (multi-device CPU backend) and in the k-lane cost model.

`time_fn` uses the paper's measurement protocol: repetitions separated by a
barrier-equivalent (block_until_ready), warmup discarded, report average
and minimum (paper reports both; minimum is the headline number).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

__all__ = ["time_fn", "time_fn_samples", "median_us", "GuidelineResult",
           "check_guideline"]


def time_fn_samples(fn: Callable, *args, reps: int = 30,
                    warmup: int = 5) -> list:
    """Raw per-repetition wall times in µs after ``warmup`` discarded
    calls — the paper's measurement protocol with the samples kept, so
    callers choose their own statistic (the tuning probe keys its cache
    on the MEDIAN: robust to the one-off scheduler hiccups that poison
    an average and, unlike the minimum, not a best-case fiction)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return times


def median_us(samples) -> float:
    """Median of a non-empty sample list (mean of the middle two)."""
    s = sorted(samples)
    if not s:
        raise ValueError("median of an empty sample list")
    m = len(s) // 2
    return s[m] if len(s) % 2 else (s[m - 1] + s[m]) / 2.0


def time_fn(fn: Callable, *args, reps: int = 30, warmup: int = 5):
    """Return (avg_us, min_us) over `reps` timed calls after `warmup`."""
    times = time_fn_samples(fn, *args, reps=reps, warmup=warmup)
    return sum(times) / len(times), min(times)


@dataclasses.dataclass
class GuidelineResult:
    name: str
    native_avg_us: float
    native_min_us: float
    mockup_avg_us: float
    mockup_min_us: float

    @property
    def violated(self) -> bool:
        """True ⇔ the mock-up beats the native (a library defect à la §4)."""
        return self.mockup_min_us < self.native_min_us

    @property
    def ratio(self) -> float:
        """native/mockup min-time ratio; >1 means guideline violation."""
        return self.native_min_us / max(self.mockup_min_us, 1e-9)

    def row(self) -> str:
        return (f"{self.name},{self.native_min_us:.2f},{self.mockup_min_us:.2f},"
                f"{self.ratio:.3f},{'VIOLATED' if self.violated else 'ok'}")


def check_guideline(name: str, native_fn: Callable, mockup_fn: Callable,
                    *args, reps: int = 30) -> GuidelineResult:
    na, nm = time_fn(native_fn, *args, reps=reps)
    ma, mm = time_fn(mockup_fn, *args, reps=reps)
    return GuidelineResult(name, na, nm, ma, mm)
