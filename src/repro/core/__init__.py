"""repro.core — the paper's contribution: multi-lane collective decomposition.

Träff 2019, "Decomposing Collectives for Exploiting Multi-lane
Communication", transplanted to TPU meshes: nodecomm = intra-pod axes,
lanecomm = cross-pod axis.  See DESIGN.md §2 for the mapping.
"""
from .lane import LaneTopology, PRODUCTION, SINGLE_POD
from .collectives import (
    allreduce_lane, reduce_scatter_lane, allgather_lane, bcast_lane,
    alltoall_lane, reduce_lane, gather_lane, scatter_lane, scan_lane,
    native_allreduce, native_allgather, native_reduce_scatter,
    native_alltoall, native_scan,
)
from .pipeline import (
    pipelined_bcast_lane, pipelined_allreduce_lane, pipelined_allgather_lane,
    pipeline_steps, allreduce_pipeline_steps, allgather_pipeline_steps,
)
from .costmodel import (
    CollectiveCost, mockup_cost, klane_time, HW, get_hw, set_hw,
    optimal_num_buckets, bucket_pipeline_time, optimal_prefetch_blocks,
)
from .guidelines import (
    check_guideline, GuidelineResult, median_us, time_fn, time_fn_samples,
)

__all__ = [
    "LaneTopology", "PRODUCTION", "SINGLE_POD",
    "allreduce_lane", "reduce_scatter_lane", "allgather_lane", "bcast_lane",
    "alltoall_lane", "reduce_lane", "gather_lane", "scatter_lane",
    "scan_lane",
    "native_allreduce", "native_allgather", "native_reduce_scatter",
    "native_alltoall", "native_scan",
    "pipelined_bcast_lane", "pipelined_allreduce_lane",
    "pipelined_allgather_lane", "pipeline_steps",
    "allreduce_pipeline_steps", "allgather_pipeline_steps",
    "CollectiveCost", "mockup_cost", "klane_time", "HW", "get_hw", "set_hw",
    "optimal_num_buckets", "bucket_pipeline_time", "optimal_prefetch_blocks",
    "check_guideline", "GuidelineResult", "time_fn", "time_fn_samples",
    "median_us",
]
