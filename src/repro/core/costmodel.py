"""Paper §3/§5 cost model: rounds + volumes per hierarchy level, k-lane time.

The paper analyses each full-lane mock-up under best-case, single-ported,
fully-connected assumptions; §5 defines the k-lane model (per step: one
inter-node send+recv and, simultaneously, exchanges with the k-1 on-node
peers).  We reuse those exact expressions to (a) produce the `derived`
column of the benchmark CSVs, (b) sanity-check the full-lane property
(total inter-node bytes per node == c) in property tests, and (c) predict
multi-pod collective times on the production mesh from the dry-run's
counted collective bytes.

Units: `c` is an element count per the MPI convention; multiply by
`elem_bytes` for wire bytes.  n = processes (chips) per node (pod),
N = nodes (pods), p = n·N, k = physical lanes.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["CollectiveCost", "mockup_cost", "klane_time", "speedup_bound",
           "HW", "get_hw", "set_hw", "optimal_num_buckets",
           "bucket_pipeline_time", "optimal_prefetch_blocks"]


@dataclasses.dataclass(frozen=True)
class CollectiveCost:
    """Best-case cost of one full-lane mock-up (paper §3 analysis)."""
    name: str
    rounds_node: int         # communication rounds on nodecomm level
    rounds_lane: int         # rounds on lanecomm level
    vol_node: float          # elements sent+received per process, node level
    vol_lane: float          # elements sent+received per process, lane level
    vol_internode_per_node: float  # total elements in/out of one node
    optimal_vol: float       # per-process volume of an optimal direct algo


def _lg(x: int) -> int:
    return max(1, math.ceil(math.log2(max(2, x))))


def mockup_cost(coll: str, n: int, N: int, c: float) -> CollectiveCost:
    """Paper §3 best-case numbers for each full-lane mock-up."""
    p = n * N
    if coll == "bcast":
        # Scatter(node): ceil(log n) rounds, (n-1)/n·c; Bcast(lane):
        # ceil(log N), c/n; Allgather(node): ceil(log n), (n-1)/n·c.
        return CollectiveCost(
            "bcast", 2 * _lg(n), _lg(N),
            2 * (n - 1) / n * c, c / n, c, c)
    if coll in ("gather", "scatter"):
        # (n-1)Nc on the root node + (N-1)c on the lanes = (p-1)c total.
        return CollectiveCost(
            coll, _lg(n), _lg(N),
            (n - 1) * N * c, (N - 1) * c, (p - n) * c, (p - 1) * c)
    if coll == "allgather":
        # AG(lane): (N-1)c; AG(node): (n-1)Nc; total (p-1)c = optimal.
        return CollectiveCost(
            "allgather", _lg(n), _lg(N),
            (n - 1) * N * c, (N - 1) * c, (N - 1) * n * c, (p - 1) * c)
    if coll in ("allreduce", "reduce"):
        # RS(node)+AG(node): 2·(n-1)/n·c; AR(lane): 2·(N-1)/N·c/n.
        return CollectiveCost(
            coll, 2 * _lg(n), 2 * _lg(N),
            2 * (n - 1) / n * c, 2 * (N - 1) / N * c / n,
            2 * (N - 1) / N * c, 2 * (p - 1) / p * c)
    if coll == "reduce_scatter":
        # RS(node): (n-1)/n·c; RS(lane): (N-1)/N·c/n.
        return CollectiveCost(
            "reduce_scatter", _lg(n), _lg(N),
            (n - 1) / n * c, (N - 1) / N * c / n,
            (N - 1) / N * c, (p - 1) / p * c)
    if coll == "alltoall":
        # A2A(lane): (N-1)n·c_blk rows with c = p·c_blk total per proc —
        # per paper §3.5 with per-destination block c: (N-1)nc + (n-1)Nc.
        return CollectiveCost(
            "alltoall", 1, 1,
            (n - 1) * N * c, (N - 1) * n * c, (N - 1) * n * c * n,
            (p - 1) * c)
    raise ValueError(f"unknown collective {coll!r}")


def klane_time(cost: CollectiveCost, *, k: int, elem_bytes: int,
               alpha_node: float, beta_node: float,
               alpha_lane: float, beta_lane: float) -> float:
    """Predicted seconds in the k-lane model (paper §5).

    The lane-level part is carried by k physical lanes concurrently (it is
    already expressed per-process = per-lane); the node-level part is the
    serial bottleneck the paper identifies.  alpha = per-round latency,
    beta = seconds/byte at that level.
    """
    t_node = cost.rounds_node * alpha_node + cost.vol_node * elem_bytes * beta_node
    # the n lane collectives run concurrently but only k physical lanes
    # exist: effective slowdown max(1, n_virtual/k) is already folded in by
    # vol_lane being per-process; k enters through beta_lane sharing:
    t_lane = cost.rounds_lane * alpha_lane + cost.vol_lane * elem_bytes * beta_lane
    return t_node + t_lane


def speedup_bound(coll: str, n: int, N: int, k: int) -> float:
    """Upper bound on full-lane speedup vs single-root hierarchical algo:
    the inter-node phase accelerates by ≤ k; node phases don't."""
    return float(min(k, n))


# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per task spec) — used by roofline + predictions
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops_bf16: float = 197e12       # FLOP/s per chip
    hbm_bw: float = 819e9                 # B/s per chip
    ici_bw: float = 50e9                  # B/s per link (per chip, per spec)
    dcn_bw: float = 25e9                  # B/s per host NIC (cross-pod lane)
    chips_per_host: int = 4               # v5e: 4 chips share a host NIC
    # per-collective setup latencies (launch + sync), the alpha terms of
    # the k-lane model.  DCN alpha dominates — it is what caps how finely
    # the gradient bucket can be split before latency eats the overlap win.
    alpha_ici: float = 2e-6               # s per intra-pod collective
    alpha_dcn: float = 20e-6              # s per cross-pod collective


# ---------------------------------------------------------------------------
# active constants: spec-sheet HW() until a fitted instance is installed
# ---------------------------------------------------------------------------
#
# The spec-sheet defaults above are FICTION on any real deployment (the
# BENCH_gradsync auto row predicted 68 µs for a 394 µs path); the tuning
# subsystem (repro.tuning.fit) least-squares fits alpha/beta per level
# from measured timings and installs the result here.  Every cost read
# goes through get_hw() at CALL time — never bind HW.* as a default
# argument, or a fitted instance silently won't take.  CAUTION: the
# bucket/block resolutions below feed ZeRO shard LAYOUTS; installing a
# different HW between building a layout and building its train step
# would make the two sides disagree on K/B (the driver therefore never
# calls set_hw mid-run — see DESIGN.md §11).

_ACTIVE_HW: HW = HW()


def get_hw() -> HW:
    """The active hardware constants (spec-sheet default or fitted)."""
    return _ACTIVE_HW


def set_hw(hw: "HW | None") -> HW:
    """Install ``hw`` as the active constants (None restores the
    spec-sheet default).  Returns the PREVIOUS instance so callers can
    scope the change (tests / what-if reports restore it in finally)."""
    global _ACTIVE_HW
    prev = _ACTIVE_HW
    _ACTIVE_HW = HW() if hw is None else hw
    return prev


# ---------------------------------------------------------------------------
# §5 pipelining: bucket-count choice from the latency/bandwidth crossover
# ---------------------------------------------------------------------------

def bucket_pipeline_time(c_bytes: float, K: int, *, stages: int = 3,
                         alpha: "float | None" = None,
                         beta: "float | None" = None) -> float:
    """Predicted seconds for K buckets through an S-stage pipeline.

    Standard pipeline algebra: (K + S - 1) waves, each costing one stage's
    alpha plus the per-bucket bandwidth term c/K·beta.  The bandwidth term
    is taken at the slowest level (the DCN lane hop by default; None
    resolves alpha/beta from the ACTIVE constants, so fitted values flow
    through) — the other stages overlap under it once the pipeline is
    full.
    """
    if K < 1:
        raise ValueError(f"K must be >= 1, got {K}")
    hw = get_hw()
    alpha = hw.alpha_dcn if alpha is None else alpha
    beta = 1.0 / hw.dcn_bw if beta is None else beta
    return (K + stages - 1) * (alpha + c_bytes * beta / K)


def optimal_num_buckets(c_bytes: float, *, stages: int = 3,
                        alpha: "float | None" = None,
                        beta: "float | None" = None,
                        max_buckets: int = 64) -> int:
    """Bucket count K from the k-lane latency/bandwidth crossover.

    Minimizing bucket_pipeline_time over K:  d/dK (K+S-1)(alpha + cβ/K)
    = alpha - (S-1)·cβ/K² = 0  ⇒  K* = sqrt((S-1)·cβ/alpha).  Below the
    crossover payload (cβ ≲ alpha) a single bucket wins — pipelining pure
    latency backfires; far above it the win saturates at ~S× while per-
    bucket alphas accumulate, hence the clamp.  Deterministic in its
    inputs AND the active HW so callers on both sides of a shard_map
    boundary agree on K (the ZeRO-1 shard layout depends on it) — which
    is why the driver never swaps the active HW mid-run.
    """
    if c_bytes <= 0:
        return 1
    hw = get_hw()
    alpha = hw.alpha_dcn if alpha is None else alpha
    beta = 1.0 / hw.dcn_bw if beta is None else beta
    k_star = math.sqrt(max(stages - 1, 1) * c_bytes * beta / alpha)
    return max(1, min(max_buckets, int(round(k_star))))


def optimal_prefetch_blocks(shard_bytes: float, *, max_blocks: int = 16) -> int:
    """Block count B for the ZeRO-3 per-layer weight all-gather pipeline.

    Same latency/bandwidth crossover as :func:`optimal_num_buckets`, but
    for the 2-stage AG(lane)→AG(node) pipeline
    (:func:`repro.core.pipeline.pipelined_allgather_lane`), where
    ``shard_bytes`` is the per-chip 1/p stripe of one layer's flat weight
    vector (the bytes the DCN lane hop actually moves).  The cap is lower
    than the gradient path's: the prefetch must finish under ONE layer's
    compute, so there is no point splitting past a few blocks — each
    block adds a DCN alpha that eats into the overlap window.
    Deterministic so the host-side shard layout (outside shard_map) and
    the train step (inside) agree on B.
    """
    from .pipeline import ALLGATHER_STAGES
    return optimal_num_buckets(shard_bytes, stages=ALLGATHER_STAGES,
                               max_buckets=max_blocks)
