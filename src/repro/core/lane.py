"""Lane/node factorization of a device mesh (paper §3, Figure 1).

The paper splits a regular communicator ``comm`` (p = n·N processes,
N nodes × n per node, consecutively ranked) into

  * ``nodecomm``  — the n processes sharing a compute node, and
  * ``lanecomm``  — the N processes with the same on-node index i
                    (one per node), i = 0..n-1.

On a TPU fleet the analogue is a named mesh: the *node* level is the set of
intra-pod axes (fast ICI domain) and the *lane* level is the cross-pod axis
(DCN, one independent NIC per host => physically multi-lane).  Communicator
splitting is free: it is just axis naming, resolved at trace time — the
paper caches split communicators as MPI attributes; we get the same effect
structurally.

``LaneTopology`` only names axes; sizes are read off the enclosing mesh, so
the same topology object works for the single-pod (16×16) and multi-pod
(2×16×16) production meshes as well as tiny test meshes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class LaneTopology:
    """Names the mesh axes that play the paper's nodecomm/lanecomm roles.

    node_axes: intra-node (intra-pod) axes — the paper's ``nodecomm``.
        Multiple axes are allowed (e.g. ("data", "model")); they are the
        per-dimension torus rings inside the ICI domain.
    lane_axis: the inter-node axis — the paper's ``lanecomm`` (e.g. "pod").
    """

    node_axes: tuple[str, ...]
    lane_axis: str

    def __post_init__(self):
        if isinstance(self.node_axes, str):  # tolerate a single name
            object.__setattr__(self, "node_axes", (self.node_axes,))
        if self.lane_axis in self.node_axes:
            raise ValueError(
                f"lane axis {self.lane_axis!r} also listed in node axes "
                f"{self.node_axes!r}")

    # -- sizes (valid inside shard_map / under a mesh context) ------------
    def n(self) -> int:
        """Processes per node (paper's n) = product of node-axis sizes."""
        return math.prod(jax.lax.axis_size(a) for a in self.node_axes)

    def N(self) -> int:
        """Number of nodes (paper's N) = lane-axis size."""
        return jax.lax.axis_size(self.lane_axis)

    def p(self) -> int:
        return self.n() * self.N()

    def node_rank(self):
        """Rank within the node communicator (paper's noderank, 0..n-1).

        Row-major over node_axes, matching the order used by the sequential
        per-axis collectives in :mod:`repro.core.collectives`.
        """
        r = 0
        for a in self.node_axes:
            r = r * jax.lax.axis_size(a) + jax.lax.axis_index(a)
        return r

    def lane_rank(self):
        """Rank within the lane communicator (paper's lanerank, 0..N-1)."""
        return jax.lax.axis_index(self.lane_axis)

    def global_rank(self):
        """Consecutive global rank: lane_rank * n + node_rank (paper §3)."""
        return self.lane_rank() * self.n() + self.node_rank()

    # -- static validation against a concrete mesh ------------------------
    def validate(self, mesh: Mesh) -> None:
        """Regularity check — the paper's 'few allreduce' probe, statically.

        Every node must host the same number of processes and ranks must be
        consecutive; on a named mesh both hold by construction, so the only
        failure mode is a missing axis.
        """
        names = set(mesh.axis_names)
        missing = [a for a in (*self.node_axes, self.lane_axis) if a not in names]
        if missing:
            raise ValueError(f"mesh {mesh.axis_names} lacks axes {missing}")

    def sizes(self, mesh: Mesh) -> tuple[int, int]:
        """(n, N) read off a concrete mesh (outside shard_map)."""
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        n = math.prod(ax[a] for a in self.node_axes)
        return n, ax[self.lane_axis]


# Default production factorization: cross-pod "pod" axis is the lane level,
# everything inside the pod is the node level.
PRODUCTION = LaneTopology(node_axes=("data", "model"), lane_axis="pod")
# Single-pod view: "model" rings act as lanes for the "data" reduction —
# the intra-pod analogue used when there is no pod axis.
SINGLE_POD = LaneTopology(node_axes=("model",), lane_axis="data")
