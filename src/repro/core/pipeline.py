"""Paper §5, Proposition 1: pipelined k-lane broadcast from a linear pipeline.

The construction: replicate a single-ported linear pipeline over p/k
processors k times (one replica per on-node processor), stripe the payload
1/k per replica, and close every pipeline step with a k-clique exchange on
the node so each node reassembles full blocks as they arrive.  Steps:
T_single(p/k, c/k) + O(1); total data in/out of each node: exactly c.

TPU mapping: one pipeline replica per intra-pod chip index; the lane ring
is a `jax.lax.ppermute` chain along the cross-pod ("lane") axis; the
k-clique exchange is an `all_gather` over the intra-pod ("node") axis.  The
two collectives inside one scan step use disjoint axes, so XLA's scheduler
can run them concurrently — the k-lane model's simultaneity assumption,
verified structurally on the HLO in benchmarks/paper_tables.py.

SPMD adaptation: the paper's special root steps (the root feeding its k-1
replicas, and the leaf→root back-edge supplying the root's missing stripe)
exist because an MPI root *uniquely* owns the buffer.  Under SPMD the root
node's chips are all handed the same buffer (root replication), so both
special steps vanish; what remains — and what we implement — is the steady
state of Proposition 1: k striped pipelines + per-step clique exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .collectives import _ag_seq, _rs_seq
from .lane import LaneTopology

__all__ = ["pipelined_bcast_lane", "pipelined_reduce_lane",
           "pipelined_allreduce_lane", "pipelined_allgather_lane",
           "pipeline_steps", "allreduce_pipeline_steps",
           "allgather_pipeline_steps"]


def pipeline_steps(num_blocks: int, N: int) -> int:
    """Scan length: last block reaches the last node at step N-2+num_blocks."""
    return num_blocks + N - 1


ALLREDUCE_STAGES = 3     # RS(node) → ring-AR(lane) → AG(node)

ALLGATHER_STAGES = 2     # AG(lane) → AG(node)


def allreduce_pipeline_steps(num_blocks: int) -> int:
    """Scan length of the pipelined allreduce: B blocks through 3 stages."""
    return num_blocks + ALLREDUCE_STAGES - 1


def allgather_pipeline_steps(num_blocks: int) -> int:
    """Scan length of the pipelined allgather: B blocks through 2 stages."""
    return num_blocks + ALLGATHER_STAGES - 1


def pipelined_bcast_lane(x, topo: LaneTopology, *, num_blocks: int,
                         root_lane: int = 0):
    """Pipelined k-lane broadcast of the root lane's node-replicated buffer.

    x: (c, ...) — meaningful on chips with lane_rank == root_lane (all of
    them, node-replicated); other chips' x is ignored.  Requires
    c % (num_blocks * n) == 0 and a single node axis is fine but multiple
    node axes are also supported (the clique exchange becomes the sequential
    per-axis all_gather).

    Returns the broadcast buffer (c, ...) on every chip.
    """
    if root_lane != 0:
        raise NotImplementedError("ring is rooted at lane rank 0")
    n = topo.n()
    N = topo.N()
    c = x.shape[0]
    B = num_blocks
    if c % (B * n):
        raise ValueError(f"payload {c} not divisible by num_blocks*n={B * n}")
    s = c // (B * n)                          # stripe rows per block per chip
    rest = x.shape[1:]

    i = topo.node_rank()
    j = topo.lane_rank()

    # Own-stripe view: block b, stripe i → rows  (b*n + i)*s : +s
    xb = x.reshape(B, n, s, *rest)
    stripes = jnp.take(xb, i, axis=1)         # (B, s, ...) traced-index pick

    is_root = (j == 0)
    axes = (topo.lane_axis, *topo.node_axes)
    # carries must be device-varying from the start (shard_map vma typing)
    buf0 = lax.pcast(jnp.zeros((s, *rest), x.dtype), axes, to="varying")
    out0 = lax.pcast(jnp.zeros((B, n, s, *rest), x.dtype), axes, to="varying")

    perm = [(a, a + 1) for a in range(N - 1)]  # linear chain 0→1→…→N-1

    def step(carry, t):
        buf, out = carry
        b = t - j                              # block this chip holds now
        valid = jnp.logical_and(b >= 0, b < B)
        bc = jnp.clip(b, 0, B - 1)
        own = lax.dynamic_slice_in_dim(stripes, bc, 1, axis=0)[0]
        cur = jnp.where(is_root, own, buf)     # root injects, others forward
        # ---- the two simultaneous k-lane-model operations ----
        # (1) lane hop: forward `cur` to the lane successor
        recv = lax.ppermute(cur, topo.lane_axis, perm)
        # (2) node clique exchange: assemble the full block from all stripes
        full = cur[None]
        for a in reversed(topo.node_axes):
            full = lax.all_gather(full.reshape(-1, s, *rest), a, axis=0,
                                  tiled=False).reshape(-1, s, *rest)
        full = full.reshape(n, s, *rest)
        upd = lax.dynamic_update_slice_in_dim(out, full[None], bc, axis=0)
        out = jnp.where(valid, upd, out)
        return (recv, out), None

    T = pipeline_steps(B, N)
    (_, out), _ = lax.scan(step, (buf0, out0), jnp.arange(T))
    return out.reshape(c, *rest)


def pipelined_reduce_lane(x, topo: LaneTopology, *, num_blocks: int,
                          root_lane: int = 0):
    """Pipelined k-lane REDUCE — the dual of the broadcast construction.

    Blocks flow DOWN each lane ring toward the root lane, accumulating the
    lane dimension; each step's simultaneous node-clique operation is a
    reduce-scatter that folds the node dimension into the per-chip stripe
    (the paper's k-clique exchange, §5: "for binary trees the construction
    is simpler" — a ring is the depth-1 tree here).  Steps: B + N - 1.

    Returns the full sum on chips with lane_rank == root_lane (node-
    replicated after the trailing clique all-gather), zeros elsewhere —
    SPMD rooted-collective convention, cf. reduce_lane.
    """
    if root_lane != 0:
        raise NotImplementedError("ring is rooted at lane rank 0")
    n = topo.n()
    N = topo.N()
    c = x.shape[0]
    B = num_blocks
    if c % (B * n):
        raise ValueError(f"payload {c} not divisible by num_blocks*n={B * n}")
    s = c // (B * n)
    rest = x.shape[1:]
    j = topo.lane_rank()

    xb = x.reshape(B, n * s, *rest)            # block b = rows [b·n·s, …)
    axes = (topo.lane_axis, *topo.node_axes)
    buf0 = lax.pcast(jnp.zeros((s, *rest), jnp.float32), axes, to="varying")
    out0 = lax.pcast(jnp.zeros((B, s, *rest), jnp.float32), axes,
                     to="varying")
    perm = [(a, a - 1) for a in range(1, N)]    # ring: j → j-1 (toward root)

    def step(carry, t):
        buf, out = carry
        b = t - (N - 1 - j)                     # block this chip forwards
        valid = jnp.logical_and(b >= 0, b < B)
        bc = jnp.clip(b, 0, B - 1)
        # ---- the two simultaneous k-lane-model operations ----
        # (1) node clique: fold the node dim of my block into my stripe
        blk = lax.dynamic_slice_in_dim(xb, bc, 1, axis=0)[0]
        mine = blk.astype(jnp.float32)
        for a in topo.node_axes:
            mine = lax.psum_scatter(mine, a, scatter_dimension=0, tiled=True)
        part = jnp.where(valid, mine + jnp.where(j == N - 1, 0.0, buf),
                         jnp.zeros_like(mine))
        # (2) lane hop: pass the partial toward the root lane
        recv = lax.ppermute(part, topo.lane_axis, perm)
        done = jnp.logical_and(j == 0, valid)
        upd = lax.dynamic_update_slice_in_dim(out, part[None], bc, axis=0)
        out = jnp.where(done, upd, out)
        return (recv, out), None

    T = pipeline_steps(B, N)
    (_, out), _ = lax.scan(step, (buf0, out0), jnp.arange(T))
    # trailing clique all-gather reassembles full blocks on the root lane
    full = out.reshape(B, s, *rest)
    for a in reversed(topo.node_axes):
        full = lax.all_gather(full, a, axis=1, tiled=True)
    full = full.reshape(c, *rest).astype(x.dtype)
    is_root = jnp.logical_and(topo.lane_rank() == root_lane,
                              topo.node_rank() == 0)
    return jnp.where(is_root, full, jnp.zeros_like(full))


def _lane_ring_allreduce(v, topo: LaneTopology):
    """Ring allreduce over the lane axis: circulate partials N-1 hops.

    Each hop is one ppermute on the ring j → j+1 (mod N); after N-1 hops
    every lane rank has accumulated all N contributions.  One-ported per
    step, (N-1)·|v| wire volume per chip — equal to the optimal
    2(N-1)/N·|v| at N=2 (the common pod count) and within 2× beyond; the
    simplicity buys the scan-carry shape staying fixed, which is what lets
    the surrounding pipeline overlap it with the node-level collectives.
    """
    N = topo.N()
    if N == 1:
        return v
    perm = [(a, (a + 1) % N) for a in range(N)]
    acc, msg = v, v
    for _ in range(N - 1):
        msg = lax.ppermute(msg, topo.lane_axis, perm)
        acc = acc + msg
    return acc


def pipelined_allreduce_lane(x, topo: LaneTopology, *, num_blocks: int):
    """DEPRECATED direct entry point — use
    ``repro.comm.LaneComm.allreduce(x, strategy="lane_pipelined")``.

    Thin shim over the real implementation (bit-identical: it IS the same
    function the registry dispatches to); warns once per process."""
    from repro._deprecation import warn_once
    warn_once(
        "repro.core.pipeline.pipelined_allreduce_lane",
        "direct pipelined_allreduce_lane(...) use is deprecated; route "
        "through repro.comm.LaneComm.allreduce(x, "
        "strategy=\"lane_pipelined\", num_blocks=...) so the strategy "
        "registry (and its cost-model auto-dispatch) sees the call")
    return _pipelined_allreduce_lane(x, topo, num_blocks=num_blocks)


def _pipelined_allreduce_lane(x, topo: LaneTopology, *, num_blocks: int):
    """Pipelined full-lane ALLREDUCE — the §5 recipe applied to Listing 4.

    The monolithic full-lane allreduce (collectives.allreduce_lane) runs
    RS(node) → AR(lane) → AG(node) once over the whole payload, strictly
    serializing the ICI and DCN phases.  Here the payload is split into
    ``num_blocks`` blocks that stream through the three stages under one
    ``lax.scan``: at scan step t,

      stage 1  RS(node)  of block t        — intra-pod ICI collective
      stage 2  ring-AR(lane) of block t-1  — cross-pod DCN ppermute chain
      stage 3  AG(node)  of block t-2      — intra-pod ICI collective

    Stage 2 reads only the scan carry written by stage 1 of the *previous*
    step, and stage 1 reads only this step's input block, so within one
    step the lane ppermute and the node collectives have no data
    dependence — XLA's latency-hiding scheduler may run them concurrently
    (verified structurally by launch.hlo_stats.collective_concurrency).
    Steps: B + 2 (= allreduce_pipeline_steps); every step keeps both the
    ICI and the DCN level busy once the pipeline is full — the k-lane
    model's simultaneity assumption for the training hot path.

    Requires ``x.shape[0] % (num_blocks * n) == 0`` (pad upstream; the
    gradsync bucketing helper does).  Returns the full sum on every chip,
    matching native_allreduce.  Sums in fp32 for inexact dtypes (exact
    dtypes accumulate natively).
    """
    n = topo.n()
    c = x.shape[0]
    B = num_blocks
    if B < 1:
        raise ValueError(f"num_blocks must be >= 1, got {B}")
    if c % (B * n):
        raise ValueError(f"payload {c} not divisible by num_blocks*n={B * n}")
    blk = c // B                               # rows per block
    s = blk // n                               # rows per chip after node RS
    rest = x.shape[1:]
    acc_dtype = jnp.float32 if jnp.issubdtype(x.dtype, jnp.inexact) \
        else x.dtype

    xb = x.reshape(B, blk, *rest)
    axes = (topo.lane_axis, *topo.node_axes)
    # carries must be device-varying from the start (shard_map vma typing)
    rs0 = lax.pcast(jnp.zeros((s, *rest), acc_dtype), axes, to="varying")
    ar0 = lax.pcast(jnp.zeros((s, *rest), acc_dtype), axes, to="varying")

    def step(carry, t):
        rs_c, ar_c = carry
        # ---- stage 1: node reduce-scatter of block t (ICI) --------------
        b1 = jnp.clip(t, 0, B - 1)             # t >= B: result is discarded
        cur = lax.dynamic_slice_in_dim(xb, b1, 1, axis=0)[0].astype(acc_dtype)
        cur = _rs_seq(cur, topo.node_axes)
        # ---- stage 2: lane ring allreduce of block t-1 (DCN) ------------
        # reads only the carry — no data dependence on stage 1 above
        ar_t = _lane_ring_allreduce(rs_c, topo)
        # ---- stage 3: node all-gather of block t-2 (ICI) ----------------
        full = _ag_seq(ar_c, topo.node_axes)
        # step t emits block t-2: ys[2:] below is exactly blocks 0..B-1
        return (cur, ar_t), full.astype(x.dtype)

    T = allreduce_pipeline_steps(B)
    _, ys = lax.scan(step, (rs0, ar0), jnp.arange(T))
    return ys[ALLREDUCE_STAGES - 1:].reshape(c, *rest)


def pipelined_allgather_lane(x, topo: LaneTopology, *, num_blocks: int):
    """Pipelined full-lane ALLGATHER — the §5 recipe applied to Listing 3.

    The input is this chip's 1/p stripe of the result (the ZeRO-3 FSDP
    parameter shard), split into ``num_blocks`` blocks that stream through
    the two stages of the Listing-3 composition under one ``lax.scan``:
    at scan step t,

      stage 1  AG(lane)  of block t    — cross-pod DCN collective
      stage 2  AG(node)  of block t-1  — intra-pod ICI collective

    Stage 2 reads only the scan carry written by stage 1 of the *previous*
    step, so within one step the lane and node all-gathers have no data
    dependence — the same overlap structure as pipelined_allreduce_lane,
    but for the gather-shaped collective the FSDP weight prefetch is built
    from (the k-lane follow-up paper's gather/scatter case).  The scan
    runs B steps; the last block's node gather is the epilogue OUTSIDE
    the loop (B + 1 waves total = allgather_pipeline_steps) — a drain
    iteration inside the scan would re-execute the DCN lane hop of block
    B-1 and discard it, and XLA cannot drop work from one trip of a
    while loop.

    Output layout (per block, zero-copy — no trailing transpose): the
    lane hop lands lane-rank-major inside each block, the node hop wraps
    node-rank-major outside, so the c = B·n·N·s result rows are ordered
    (block, node_rank, lane_rank, s) — the ``zero3`` shard layout of
    :func:`repro.optim.gradsync.zero3_param_shard`.  Requires
    ``x.shape[0] % num_blocks == 0``.
    """
    n = topo.n()
    N = topo.N()
    c = x.shape[0]
    B = num_blocks
    if B < 1:
        raise ValueError(f"num_blocks must be >= 1, got {B}")
    if c % B:
        raise ValueError(f"shard {c} not divisible by num_blocks={B}")
    s = c // B                                 # shard rows per block
    rest = x.shape[1:]

    def ag_lane(blk):
        return lax.all_gather(blk, topo.lane_axis, axis=0, tiled=True)

    if B == 1:                                 # no pipeline to fill
        return _ag_seq(ag_lane(x), topo.node_axes)

    xb = x.reshape(B, s, *rest)
    # prologue fills the pipe with block 0's lane hop (a zeros carry
    # would cost a discarded node all-gather on the first scan step)
    carry0 = ag_lane(xb[0])

    def step(carry, t):
        # ---- stage 1: lane all-gather of block t (DCN) ------------------
        cur = ag_lane(lax.dynamic_slice_in_dim(xb, t, 1, axis=0)[0])
        # ---- stage 2: node all-gather of block t-1 (ICI) ----------------
        # reads only the carry — no data dependence on stage 1 above
        full = _ag_seq(carry, topo.node_axes)
        # step t emits block t-1: steps 1..B-1 yield blocks 0..B-2
        return cur, full

    last, ys = lax.scan(step, carry0, jnp.arange(1, B))
    tail = _ag_seq(last, topo.node_axes)       # epilogue: block B-1 (ICI)
    return jnp.concatenate([ys.reshape((B - 1) * n * N * s, *rest), tail])
