"""Full-lane collective mock-ups (paper §3, Listings 1-6), for TPU meshes.

Every function here is the JAX/shard_map transplant of one of the paper's
performance-guideline implementations: the payload is split evenly over the
*node*-level processes, the inter-node part runs as n concurrent collectives
over the *lane* communicators (each carrying 1/n of the payload — the
"full-lane" property), and node-level collectives split/reassemble.

They must be called **inside** ``jax.shard_map`` (or any context where the
mesh axes named by the :class:`~repro.core.lane.LaneTopology` are bound) and
operate on the per-device local shard.  The leading dimension of ``x`` plays
the role of the MPI element count ``c``.

SPMD adaptations (documented per function; see DESIGN.md §2):

* MPI's rooted collectives (bcast/gather/scatter/reduce) have no exact SPMD
  twin — every device runs the same program.  Roots are expressed with
  masks/selects; where MPI would send nothing, XLA still moves a masked
  operand (the paper makes the mirror-image observation that MPI lacks
  "restricted" collectives, §3.1).  The cost model in
  :mod:`repro.core.costmodel` accounts for both the ideal (paper) and the
  SPMD-emulated volumes.
* MPI derived-datatype zero-copy reassembly becomes *layout choice*: each
  composition below is ordered so the result lands in global-rank-major
  order without a transpose wherever possible; where the paper itself needs
  a pre-permutation (reduce_scatter_block, Listing 5) we need the same
  transpose and say so.
* Multi-axis node communicators ((data, model) inside a pod) use
  per-axis sequential collectives — the TPU-native per-torus-dimension
  form.  Sequential RS/AG over (A, B) compose to the product collective
  with row-major block order, matching ``LaneTopology.node_rank``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax

from .lane import LaneTopology

__all__ = [
    "allreduce_lane", "reduce_scatter_lane", "allgather_lane", "bcast_lane",
    "alltoall_lane", "reduce_lane", "gather_lane", "scatter_lane",
    "scan_lane",
    "native_allreduce", "native_allgather", "native_reduce_scatter",
    "native_alltoall", "native_scan",
]


# --------------------------------------------------------------------------
# helpers: sequential per-axis reduce-scatter / all-gather (exact inverses)
# --------------------------------------------------------------------------

def _rs_seq(x, axes: Sequence[str]):
    """Reduce-scatter over each axis in order; leading dim shrinks by n."""
    for a in axes:
        sz = lax.axis_size(a)
        if x.shape[0] % sz:
            raise ValueError(
                f"leading dim {x.shape[0]} not divisible by axis {a!r} size {sz}")
        x = lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    return x


def _ag_seq(x, axes: Sequence[str]):
    """All-gather over each axis in *reverse* order — inverse of _rs_seq."""
    for a in reversed(tuple(axes)):
        x = lax.all_gather(x, a, axis=0, tiled=True)
    return x


def _a2a_flip(x, axes: Sequence[str], first_dim: int):
    """Product all-to-all over several axes.

    ``x`` must carry one explicit *destination* dimension per axis, in axis
    order, starting at ``first_dim`` (each of size = that axis).  Each
    per-axis a2a (split == concat dim, untiled) flips that dimension's
    meaning from destination-rank to source-rank.  Composing per axis keeps
    the dims separated, so no source/destination interleaving can occur
    (a sequential *tiled* composition would nest the second split inside the
    first axis' source chunks — wrong).
    """
    for idx, a in enumerate(axes):
        d = first_dim + idx
        x = lax.all_to_all(x, a, split_axis=d, concat_axis=d)
    return x


def _node_sizes(topo: LaneTopology) -> tuple[int, ...]:
    return tuple(lax.axis_size(a) for a in topo.node_axes)


def _unravel(rank: int, sizes: Sequence[int]) -> tuple[int, ...]:
    out = []
    for s in reversed(tuple(sizes)):
        out.append(rank % s)
        rank //= s
    return tuple(reversed(out))


def _n(topo: LaneTopology) -> int:
    return topo.n()


# --------------------------------------------------------------------------
# Allreduce (paper Listing 4):  RS(node) ∘ AR(lane) ∘ AG(node)
# --------------------------------------------------------------------------

def allreduce_lane(x, topo: LaneTopology):
    """Full-lane allreduce.

    ReduceScatter on the node level leaves each chip with c/n partial sums;
    the n concurrent lane-level allreduces each move only c/n over the
    inter-node fabric (every NIC busy, total in/out per node = c — the
    full-lane property); AllGather on the node level reassembles.

    Works on any dtype with '+'; commutative reduction only, like the paper.
    Leading dim must be divisible by n.
    """
    lead = x.shape[0]
    r = _rs_seq(x, topo.node_axes)
    r = lax.psum(r, topo.lane_axis)
    out = _ag_seq(r, topo.node_axes)
    if out.shape[0] != lead:
        raise RuntimeError(
            f"gather reassembled {out.shape[0]} rows, expected {lead}")
    return out


def native_allreduce(x, topo: LaneTopology):
    """The 'native library' comparator: one-shot psum over all axes."""
    return lax.psum(x, (topo.lane_axis, *topo.node_axes))


# --------------------------------------------------------------------------
# Reduce_scatter_block (paper Listing 5):  permute ∘ RS(node) ∘ RS(lane)
# --------------------------------------------------------------------------

def reduce_scatter_lane(x, topo: LaneTopology):
    """Full-lane reduce-scatter-block.

    Input: p·m leading elements = p blocks of m rows, block g destined for
    global rank g (= lane_rank·n + node_rank, paper's consecutive ranking).
    Output: this chip's block of m rows, fully reduced.

    The paper must pre-permute blocks into lanecomm process order with a
    derived-datatype self-copy (Listing 5 / [18]); the same reorder appears
    here as the (N, n) → (n, N) transpose — not zero-copy, exactly as in
    the paper.
    """
    n, N = _n(topo), topo.N()
    p = n * N
    if x.shape[0] % p:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by p={p}")
    m = x.shape[0] // p
    xb = x.reshape(N, n, m, *x.shape[1:])
    xb = jnp.swapaxes(xb, 0, 1)                     # the Listing-5 permute
    xb = xb.reshape(n * N * m, *x.shape[1:])
    r = _rs_seq(xb, topo.node_axes)                 # stripe node_rank: (N*m, ...)
    r = lax.psum_scatter(r, topo.lane_axis, scatter_dimension=0, tiled=True)
    return r                                        # (m, ...): own block


def native_reduce_scatter(x, topo: LaneTopology):
    """One-shot comparator: RS over the product communicator.

    Sequential tiled psum_scatter over (lane, node...) delivers block
    lane-major = global-rank order directly.
    """
    x = lax.psum_scatter(x, topo.lane_axis, scatter_dimension=0, tiled=True)
    return _rs_seq(x, topo.node_axes)


# --------------------------------------------------------------------------
# Allgather (paper Listing 3):  AG(lane) ∘ AG(node)  [+ rank-order fixup]
# --------------------------------------------------------------------------

def allgather_lane(x, topo: LaneTopology, *, reorder: bool = True):
    """Full-lane allgather.

    Each chip first allgathers its own m-row block over its lane (n
    concurrent lane collectives, (N-1)·m per chip inter-node — full-lane),
    then the node level replicates.  The natural output order is
    node-major [i][j]; ``reorder=True`` transposes to global-rank order
    [j][i].  ``reorder=False`` is the zero-copy variant for consumers that
    are order-agnostic or layout-adapted (the framework's FSDP weight
    layout is defined lane-major so this transpose never materializes —
    the JAX analogue of the paper's derived-datatype tiling).
    """
    m = x.shape[0]
    n, N = _n(topo), topo.N()
    y = lax.all_gather(x, topo.lane_axis, axis=0, tiled=True)   # (N*m, ...)
    z = _ag_seq(y, topo.node_axes)                               # (n*N*m, ...)
    if reorder:
        z = z.reshape(n, N, m, *x.shape[1:])
        z = jnp.swapaxes(z, 0, 1).reshape(n * N * m, *x.shape[1:])
    return z


def native_allgather(x, topo: LaneTopology):
    """One-shot comparator in global-rank order: AG(node) ∘ AG(lane).

    Note this is the *redundant* composition the paper attributes to
    Kühnemann et al. [12] when used as a mock-up (every lane carries the
    full node block); as a native baseline it stands in for the library's
    internal algorithm.
    """
    y = _ag_seq(x, topo.node_axes)
    return lax.all_gather(y, topo.lane_axis, axis=0, tiled=True)


# --------------------------------------------------------------------------
# Broadcast (paper Listing 1):  Scatter(node) ∘ Bcast(lane) ∘ AG(node)
# --------------------------------------------------------------------------

def bcast_lane(x, topo: LaneTopology, *, root_lane: int = 0,
               root_node: int = 0, root_replicated: bool = True):
    """Full-lane broadcast of the root chip's buffer to every chip.

    root = (root_lane, root_node) in (lane_rank, node_rank) coordinates.

    * Scatter(node): if ``root_replicated`` (the buffer is already
      node-replicated on the root node — the common weight-sync case) the
      scatter is a free local stripe slice, the zero-copy ideal.  Otherwise
      an all-to-all emulates MPI_Scatterv (SPMD upper bound, see module
      docstring).
    * Bcast(lane): n concurrent lane broadcasts of c/n each — masked psum
      (reduce+bcast; 2·(N-1)/N·c/n wire bytes vs the ideal c/n; the
      pipelined §5 construction in :mod:`repro.core.pipeline` closes this
      gap for large c).
    * AllGather(node) reassembles; stripes were cut in node-rank order so
      the result needs no reorder (zero-copy).
    """
    n = _n(topo)
    if x.shape[0] % n:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by n={n}")
    m = x.shape[0] // n
    node_rank = topo.node_rank()
    if root_replicated:
        stripe = lax.dynamic_slice_in_dim(x, node_rank * m, m, axis=0)
    else:
        sizes = _node_sizes(topo)
        xs = x.reshape(*sizes, m, *x.shape[1:])
        recv = _a2a_flip(xs, topo.node_axes, first_dim=0)
        stripe = recv[_unravel(root_node, sizes)]
    on_root_lane = topo.lane_rank() == root_lane
    stripe = jnp.where(on_root_lane, stripe, jnp.zeros_like(stripe))
    stripe = lax.psum(stripe, topo.lane_axis)
    return _ag_seq(stripe, topo.node_axes)


# --------------------------------------------------------------------------
# Alltoall (paper Listing 6):  A2A(lane) ∘ A2A(node)
# --------------------------------------------------------------------------

def alltoall_lane(x, topo: LaneTopology):
    """Full-lane all-to-all.

    Input: p blocks of m rows in global-destination-rank order.  Output: p
    blocks in global-source-rank order.  Lane-level first, node-level
    second — this order lands source-rank-major with **no transpose**
    (the zero-copy composition; the paper notes both orders are correct,
    Listing 6 uses datatypes to the same effect).

    Inter-node volume per chip: (N-1)·n·m, carried by n concurrent lane
    a2a's; node level moves (n-1)·N·m — the unavoidable node bottleneck
    the paper analyses in §3.5.
    """
    n, N = _n(topo), topo.N()
    p = n * N
    if x.shape[0] % p:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by p={p}")
    m = x.shape[0] // p
    rest = x.shape[1:]
    sizes = _node_sizes(topo)
    # explicit dims: (dest_j, dest_iA, dest_iB, ..., m, ...)
    xb = x.reshape(N, *sizes, m, *rest)
    y = lax.all_to_all(xb, topo.lane_axis, split_axis=0, concat_axis=0)
    z = _a2a_flip(y, topo.node_axes, first_dim=1)
    # dims now (src_j, src_iA, src_iB, ..., m) row-major = global source rank
    return z.reshape(p * m, *rest)


def native_alltoall(x, topo: LaneTopology):
    """One-shot comparator: direct a2a over the product communicator.

    XLA lowers this as a single all-to-all over the flattened device group
    when the axis dims stay explicit — the 'direct algorithm' of §3.5 with
    (p-1)·c volume per chip.
    """
    n, N = _n(topo), topo.N()
    p = n * N
    m = x.shape[0] // p
    rest = x.shape[1:]
    sizes = _node_sizes(topo)
    xb = x.reshape(N, *sizes, m, *rest)
    z = _a2a_flip(xb, (topo.lane_axis, *topo.node_axes), first_dim=0)
    return z.reshape(p * m, *rest)


# --------------------------------------------------------------------------
# Reduce (paper §3.4):  RS(node) ∘ Reduce(lane) ∘ Gather(node→root)
# --------------------------------------------------------------------------

def reduce_lane(x, topo: LaneTopology, *, root_lane: int = 0,
                root_node: int = 0):
    """Full-lane reduce; the summed buffer is valid on the root chip,
    zeros elsewhere (SPMD rooted-collective convention)."""
    r = _rs_seq(x, topo.node_axes)
    r = lax.psum(r, topo.lane_axis)          # lane-level reduce (emulated)
    out = _ag_seq(r, topo.node_axes)          # gather emulated by allgather
    is_root = jnp.logical_and(topo.lane_rank() == root_lane,
                              topo.node_rank() == root_node)
    return jnp.where(is_root, out, jnp.zeros_like(out))


# --------------------------------------------------------------------------
# Scan (paper abstract list / §3):  Scan(node) ∘ Exscan(lane, striped) ∘
#                                   AG(node)
# --------------------------------------------------------------------------

def scan_lane(x, topo: LaneTopology):
    """Full-lane inclusive scan (MPI_Scan): out on global rank g is
    Σ_{g'≤g} x_{g'}, elementwise, ranks consecutive (g = lane_rank·n +
    node_rank — processes of one node are contiguous, paper §3).

    Decomposition: (1) inclusive Scan over the node communicator; (2) the
    node TOTALS need an *exclusive* scan over the lane communicator — the
    payload for that step is striped 1/n per on-node process, so the n
    concurrent lane exscans each move only c/n inter-node (the full-lane
    property, same as Listing 4's lane hop); (3) AllGather(node)
    reassembles the exscanned totals, which are then added to the local
    node-scan.

    SPMD adaptations (see module docstring + DESIGN.md §2): MPI_Scan /
    MPI_Exscan have no lax primitive, so both scans are emulated as
    all-gather + node_rank/lane_rank-masked local sums — the rank-indexed
    prefix mask replaces MPI's rank-asymmetric reduction tree, at the
    all-gather's (g-1)/g·c wire cost per level.

    Leading dim must be divisible by n.
    """
    n, N = _n(topo), topo.N()
    c = x.shape[0]
    if c % n:
        raise ValueError(f"leading dim {c} not divisible by n={n}")
    m = c // n
    i = topo.node_rank()
    j = topo.lane_rank()

    # (1) node-level inclusive scan: gather node peers, prefix-sum i' <= i
    gn = _ag_seq(x, topo.node_axes)                   # (n*c,) node-rank-major
    gn = gn.reshape(n, c, *x.shape[1:])
    keep = (jnp.arange(n) <= i).reshape(n, *([1] * (x.ndim)))
    t = jnp.sum(jnp.where(keep, gn, 0), axis=0)       # my inclusive node scan
    tot = jnp.sum(gn, axis=0)                         # node total (replicated)

    # (2) lane-level exclusive scan of node totals, striped 1/n per chip
    stripe = lax.dynamic_slice_in_dim(tot, i * m, m, axis=0)
    gl = lax.all_gather(stripe, topo.lane_axis, axis=0, tiled=False)  # (N, m)
    keep_l = (jnp.arange(N) < j).reshape(N, *([1] * (x.ndim)))
    e = jnp.sum(jnp.where(keep_l, gl, 0), axis=0)     # exscan of my stripe

    # (3) node-level all-gather reassembles the full exscanned total
    E = _ag_seq(e, topo.node_axes)                    # (c,), stripe order
    return t + E


def native_scan(x, topo: LaneTopology):
    """One-shot comparator: gather the whole communicator, prefix-sum by
    global rank locally (the direct algorithm — every chip moves (p-1)·c;
    the mock-up's inter-node traffic is the full-lane (N-1)/N·c/n)."""
    n, N = _n(topo), topo.N()
    p = n * N
    y = _ag_seq(x, topo.node_axes)                               # (n*c,)
    z = lax.all_gather(y, topo.lane_axis, axis=0, tiled=True)    # (p*c,)
    z = z.reshape(p, x.shape[0], *x.shape[1:])       # global-rank-major
    g = topo.global_rank()
    keep = (jnp.arange(p) <= g).reshape(p, *([1] * x.ndim))
    return jnp.sum(jnp.where(keep, z, 0), axis=0)


# --------------------------------------------------------------------------
# Gather / Scatter (paper §3.2, Listing 2)
# --------------------------------------------------------------------------

def gather_lane(x, topo: LaneTopology, *, root_lane: int = 0,
                root_node: int = 0):
    """Full-lane gather: root chip ends with all p blocks in global rank
    order; others zeros.  Gather(lane) then Gather(node), gathers emulated
    by allgathers (SPMD).  The paper's derived-datatype placement becomes
    the final [i][j]→[j][i] transpose."""
    m = x.shape[0]
    n, N = _n(topo), topo.N()
    g1 = lax.all_gather(x, topo.lane_axis, axis=0, tiled=True)   # (N*m)
    g2 = _ag_seq(g1, topo.node_axes)                              # (n*N*m) [i][j]
    g2 = g2.reshape(n, N, m, *x.shape[1:])
    g2 = jnp.swapaxes(g2, 0, 1).reshape(n * N * m, *x.shape[1:])
    is_root = jnp.logical_and(topo.lane_rank() == root_lane,
                              topo.node_rank() == root_node)
    return jnp.where(is_root, g2, jnp.zeros_like(g2))


def scatter_lane(x, topo: LaneTopology, *, root_lane: int = 0,
                 root_node: int = 0, root_replicated: bool = True):
    """Full-lane scatter: every chip receives its global-rank block of the
    root's p·m buffer.  Scatter(node@root-node) ∘ Scatter(lane).

    With ``root_replicated`` the node-level scatter is a local stripe
    slice; the lane-level scatter is an all-to-all + column select (SPMD
    emulation, see module docstring).
    """
    n, N = _n(topo), topo.N()
    p = n * N
    if x.shape[0] % p:
        raise ValueError(f"leading dim {x.shape[0]} not divisible by p={p}")
    m = x.shape[0] // p
    rest = x.shape[1:]
    node_rank = topo.node_rank()
    xb = x.reshape(N, n, m, *rest)
    if root_replicated:
        # node-level scatter degenerates to a local stripe pick (zero-copy):
        # blocks destined to (j, node_rank) for all lane ranks j.
        stripe = jnp.take(xb, node_rank, axis=1)              # (N, m, ...)
    else:
        sizes = _node_sizes(topo)
        mine = jnp.swapaxes(xb, 0, 1).reshape(*sizes, N * m, *rest)
        recv = _a2a_flip(mine, topo.node_axes, first_dim=0)
        stripe = recv[_unravel(root_node, sizes)].reshape(N, m, *rest)
    # lane-level scatter: tiled a2a over the lane, keep the root lane's column
    got = lax.all_to_all(stripe.reshape(N * m, *rest), topo.lane_axis,
                         split_axis=0, concat_axis=0, tiled=True)
    return got.reshape(N, m, *rest)[root_lane]
