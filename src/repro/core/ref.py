"""Single-process oracles for every collective (pure jnp/numpy).

Each oracle takes the stacked per-rank inputs ``xs`` with leading axis =
global rank (paper's consecutive ranking: rank = lane_rank·n + node_rank)
and returns the stacked per-rank expected outputs.  Tests compare the
shard_map mock-ups and natives against these, and the Pallas kernels have
their own oracles in repro/kernels/ref.py.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "oracle_allreduce", "oracle_reduce_scatter", "oracle_allgather",
    "oracle_bcast", "oracle_alltoall", "oracle_reduce", "oracle_gather",
    "oracle_scatter", "oracle_scan",
]


def oracle_allreduce(xs: np.ndarray) -> np.ndarray:
    total = xs.sum(axis=0)
    return np.broadcast_to(total, xs.shape).copy()


def oracle_reduce_scatter(xs: np.ndarray) -> np.ndarray:
    """xs: (p, p*m, ...). out[r] = sum_r' xs[r'][r*m:(r+1)*m]."""
    p = xs.shape[0]
    assert xs.shape[1] % p == 0
    m = xs.shape[1] // p
    total = xs.sum(axis=0)
    return np.stack([total[r * m:(r + 1) * m] for r in range(p)])


def oracle_allgather(xs: np.ndarray) -> np.ndarray:
    """xs: (p, m, ...). out[r] = concat_r' xs[r'] for every r."""
    p = xs.shape[0]
    cat = xs.reshape(p * xs.shape[1], *xs.shape[2:])
    return np.broadcast_to(cat, (p, *cat.shape)).copy()


def oracle_bcast(xs: np.ndarray, root: int = 0) -> np.ndarray:
    return np.broadcast_to(xs[root], xs.shape).copy()


def oracle_alltoall(xs: np.ndarray) -> np.ndarray:
    """xs: (p, p*m, ...). out[r] = concat_j xs[j][r*m:(r+1)*m]."""
    p = xs.shape[0]
    m = xs.shape[1] // p
    out = np.empty_like(xs)
    for r in range(p):
        out[r] = np.concatenate([xs[j][r * m:(r + 1) * m] for j in range(p)])
    return out


def oracle_reduce(xs: np.ndarray, root: int = 0) -> np.ndarray:
    out = np.zeros_like(xs)
    out[root] = xs.sum(axis=0)
    return out


def oracle_gather(xs: np.ndarray, root: int = 0) -> np.ndarray:
    p = xs.shape[0]
    out = np.zeros((p, p * xs.shape[1], *xs.shape[2:]), dtype=xs.dtype)
    out[root] = xs.reshape(p * xs.shape[1], *xs.shape[2:])
    return out


def oracle_scan(xs: np.ndarray) -> np.ndarray:
    """Inclusive scan: out[r] = sum_{r' <= r} xs[r']."""
    return np.cumsum(xs, axis=0)


def oracle_scatter(xs: np.ndarray, root: int = 0) -> np.ndarray:
    p = xs.shape[0]
    m = xs.shape[1] // p
    return np.stack([xs[root][r * m:(r + 1) * m] for r in range(p)])
