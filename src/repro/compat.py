"""JAX version compatibility shims.

The codebase is written against the current JAX surface (``jax.shard_map``
with ``check_vma``, ``lax.pcast`` varying-manual-axes casts, Pallas
``pltpu.CompilerParams``).  Containers pin older releases (0.4.x) where
those spell ``jax.experimental.shard_map.shard_map(check_rep=...)``,
no-pcast (no VMA type system to cast in), and ``pltpu.TPUCompilerParams``.

``install()`` bridges the gap *forward only*: it adds the modern names as
aliases when missing and never overrides a real implementation.  It is
invoked from ``repro.__init__`` so any ``import repro.*`` makes the rest
of the code version-agnostic.

Shims:
  lax.axis_size   — ``lax.psum(1, axis)`` (statically folded) on 0.4.x.
  jax.shard_map   — wraps experimental shard_map; ``check_vma`` maps to
                    ``check_rep``.  On 0.4.x replication checking predates
                    the VMA rules our scans rely on (carries start
                    replicated and become device-varying mid-scan), so
                    check_rep is forced off there; values are unaffected —
                    it is a static typing pass, and correctness is covered
                    by the oracle tests.
  lax.pcast       — identity on 0.4.x: without the VMA type system there
                    is nothing to cast; on modern JAX the real pcast runs.
  pallas CompilerParams — alias of TPUCompilerParams on 0.4.x.
"""
from __future__ import annotations

import functools


def install() -> None:
    import jax
    from jax import lax

    if not hasattr(jax, "shard_map"):
        from jax.experimental import shard_map as _esm

        @functools.wraps(_esm.shard_map)
        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                      **kw):
            kw.pop("check_rep", None)
            return _esm.shard_map(f, mesh=mesh, in_specs=in_specs,
                                  out_specs=out_specs, check_rep=False,
                                  **kw)

        jax.shard_map = shard_map

    if not hasattr(lax, "axis_size"):
        def axis_size(axis_name):
            # the classic spelling: psum of a literal 1 is folded to the
            # static axis size at trace time
            return lax.psum(1, axis_name)

        lax.axis_size = axis_size

    if not hasattr(lax, "pcast"):
        def pcast(x, axes, *, to):  # noqa: ARG001 - mirror the real sig
            return x

        lax.pcast = pcast

    try:
        from jax.experimental.pallas import tpu as pltpu
        if not hasattr(pltpu, "CompilerParams") and \
                hasattr(pltpu, "TPUCompilerParams"):
            pltpu.CompilerParams = pltpu.TPUCompilerParams
    except ImportError:  # pallas not in this build; kernels guard anyway
        pass
