"""repro — Decomposing Collectives for Exploiting Multi-lane Communication.

Importing any submodule installs the JAX version-compat shims first (see
repro.compat): the code targets the modern jax.shard_map / lax.pcast
surface but must also run on pinned 0.4.x containers.
"""
from . import compat as _compat

_compat.install()
