"""Gradient synchronization backends — where the paper meets training.

The cross-replica gradient reduction of data-parallel training IS an
MPI_Allreduce over the (pod × data) communicator.  Strategies:

  native    one-shot ``psum`` over ("pod","data") — the "native library"
            baseline (XLA picks the algorithm).
  lane      the paper's Listing-4 decomposition, bucketed: K buckets each
            run ReduceScatter(node) → Allreduce(lane) → AllGather(node).
            Every chip of a pod carries 1/|node| of the cross-pod (DCN)
            payload concurrently — the full-lane property — and bucket
            b's DCN lane hop has no data dependence on bucket b+1's
            intra-pod reduce-scatter, so the two levels overlap (§5).
  lane_pipelined
            the §5 pipelined construction proper: all buckets stream
            through the three stages under one ``lax.scan``
            (core.pipeline.pipelined_allreduce_lane) — O(1) HLO size in
            the bucket count, same overlap structure.
  lane_int8 bucketed like ``lane``, but the DCN hop is int8-compressed
            (per-chunk scales): 4× fewer DCN bytes; the intra-pod ICI
            hops stay fp32.  Beyond-paper distributed-optimization trick.
  lane_zero1 reduce-scatter only (no trailing all-gather): returns
            data-sharded grads for a ZeRO-1 sharded optimizer update; the
            all-gather of the paper's decomposition moves AFTER the
            optimizer (same bytes, applied to fresh params, moments stay
            sharded).  See launch/steps.py.  Bucketed on the RS + lane
            phases.

All strategies flatten the gradient pytree into one fp32 vector, then
split it into K equal buckets (K from the cost model's §5 latency/
bandwidth crossover, ``core.costmodel.optimal_num_buckets``, overridable
via ``RunConfig.gradsync_buckets``).  K collectives per level instead of
one trades latency (K·alpha) for pipeline overlap of the ICI and DCN
levels — the k-lane model's simultaneity term; see DESIGN.md §3.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import LaneTopology, optimal_num_buckets
from repro.core.collectives import _ag_seq, _rs_seq
from repro.core.pipeline import pipelined_allreduce_lane

STRATEGIES = ("native", "lane", "lane_pipelined", "lane_int8", "lane_zero1")


def _flatten_bucket(tree, pad_to: int):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    pad = (-n) % pad_to
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, (leaves, treedef, n)


def _unflatten_bucket(flat, spec):
    leaves, treedef, n = spec
    flat = flat[:n]
    out, ofs = [], 0
    for l in leaves:
        sz = math.prod(l.shape)
        out.append(flat[ofs:ofs + sz].reshape(l.shape).astype(l.dtype))
        ofs += sz
    return jax.tree.unflatten(treedef, out)


def compress_int8(x):
    """Chunked symmetric int8 quantization; returns (q, scales)."""
    chunk = 1024
    n = x.shape[0]
    pad = (-n) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    xr = x.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(xr), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xr / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def decompress_int8(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


# ---------------------------------------------------------------------------
# bucket schedule (shared by every lane strategy)
# ---------------------------------------------------------------------------

def resolve_num_buckets(total_elems: int, n_node: int,
                        override: int = 0, *, elem_bytes: int = 4) -> int:
    """The K every bucketed strategy uses for ``total_elems`` gradients.

    override > 0 wins; otherwise the cost model picks K from the DCN
    latency/bandwidth crossover on the per-lane payload (c/n bytes — the
    full-lane stripe is what actually crosses the DCN).  K is additionally
    capped so each bucket keeps at least one row per chip after the node
    reduce-scatter.  Takes plain ints (not a topology) so callers outside
    shard_map — the ZeRO-1 optimizer-state init — resolve the same K.
    """
    if override > 0:
        k = override
    else:
        k = optimal_num_buckets(total_elems * elem_bytes / max(n_node, 1))
    return max(1, min(k, max(1, total_elems // max(n_node, 1))))


def bucket_schedule(flat, num_buckets: int,
                    stages: Sequence[Callable[[Any], Any]]):
    """Run ``flat`` through per-bucket ``stages`` in stage-skewed order.

    Splits ``flat`` (leading dim divisible by num_buckets) into equal
    contiguous buckets and applies every stage to every bucket, emitting
    ops wave by wave: bucket b's stage s+1 lands next to bucket b+1's
    stage s.  Cross-bucket ops never share operands, so the DCN stage of
    one bucket and the ICI stage of the next have no data dependence —
    XLA's scheduler is free to overlap them (structurally verified in
    launch.hlo_stats.collective_concurrency).  Emission order only hints
    the scheduler; correctness needs nothing from it.

    Returns the per-bucket results as a list (stages may change shapes,
    e.g. a reduce-scatter stage shrinks rows by n — concatenation is the
    caller's business).
    """
    K = num_buckets
    if flat.shape[0] % K:
        raise ValueError(
            f"flat dim {flat.shape[0]} not divisible by num_buckets={K}")
    bsz = flat.shape[0] // K
    vals = [lax.slice_in_dim(flat, b * bsz, (b + 1) * bsz, axis=0)
            for b in range(K)]
    S = len(stages)
    done = [0] * K                     # stages applied so far, per bucket
    for wave in range(K + S - 1):
        for b in range(min(wave, K - 1), max(wave - S, -1), -1):
            s = wave - b
            if 0 <= s < S and done[b] == s:
                vals[b] = stages[s](vals[b])
                done[b] += 1
    assert all(d == S for d in done)
    return vals


def _rs_node(topo: LaneTopology):
    return lambda v: _rs_seq(v, topo.node_axes)


def _ag_node(topo: LaneTopology):
    return lambda v: _ag_seq(v, topo.node_axes)


def _ar_lane(topo: LaneTopology):
    return lambda v: lax.psum(v, topo.lane_axis)


def _ar_lane_int8(topo: LaneTopology):
    def stage(v):
        q, scale, n = compress_int8(v)
        qg = lax.all_gather(q, topo.lane_axis, axis=0, tiled=False)
        sg = lax.all_gather(scale, topo.lane_axis, axis=0, tiled=False)
        N = qg.shape[0]
        return sum(decompress_int8(qg[i], sg[i], n) for i in range(N))
    return stage


# ---------------------------------------------------------------------------
# ZeRO-1 shard layout (bucket-major, mirrors the bucketed reduce-scatter)
# ---------------------------------------------------------------------------
#
# With K buckets, chip i's lane_zero1 shard is the concatenation of its
# node-RS stripe from every bucket: [b0·stripe_i, b1·stripe_i, …] — the
# flat vector viewed as (K, n, s) sliced at node_rank on the middle axis.
# Reassembly therefore needs the (n, K) → (K, n) swap, the same reorder
# the paper's Listing 5 expresses with derived datatypes (DESIGN.md §3).

def zero1_param_shard(flat, topo: LaneTopology, num_buckets: int):
    """This chip's shard of a padded flat vector, matching the layout
    grad_sync(..., "lane_zero1", num_buckets=K) returns for gradients."""
    n = topo.n()
    K = num_buckets
    s = flat.shape[0] // (K * n)
    r = topo.node_rank()
    xb = flat.reshape(K, n, s)
    return jnp.take(xb, r, axis=1).reshape(K * s)    # traced-index pick


def zero1_unshard(shard, topo: LaneTopology, num_buckets: int):
    """All-gather per-chip (K·s,) shards back to the flat (K·n·s,) order."""
    n = topo.n()
    K = num_buckets
    g = _ag_seq(shard, topo.node_axes)                 # (n·K·s,) chip-major
    s = g.shape[0] // (n * K)
    return jnp.swapaxes(g.reshape(n, K, s), 0, 1).reshape(n * K * s)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------

def grad_sync(grads: Any, topo: LaneTopology, strategy: str = "native",
              *, num_buckets: int = 0):
    """Synchronize (mean) gradients over the (lane × node) batch axes.

    Must be called inside shard_map with topo's axes manual.  Returns the
    fully-reduced tree for native/lane/lane_pipelined/lane_int8, or
    (sharded_flat, spec) for lane_zero1 (see steps.py for the deferred
    all-gather).  ``num_buckets``: 0 = cost-model auto (§5 crossover);
    callers that must agree on the padded layout across call sites (the
    ZeRO-1 optimizer state) should resolve K once via resolve_num_buckets
    and pass it explicitly.
    """
    axes = (topo.lane_axis, *topo.node_axes)
    nrep = 1
    for a in axes:
        nrep *= lax.axis_size(a)

    if strategy == "native":
        return jax.tree.map(lambda g: lax.psum(g, axes) / nrep, grads)
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown gradsync strategy {strategy!r}; "
                         f"have {STRATEGIES}")

    n_node = topo.n()
    total = sum(math.prod(l.shape) for l in jax.tree.leaves(grads))
    K = resolve_num_buckets(total, n_node, num_buckets)
    # every bucket must stay divisible by n after the K-way split
    flat, spec = _flatten_bucket(grads, pad_to=K * n_node)

    if strategy == "lane_pipelined":
        out = pipelined_allreduce_lane(flat, topo, num_blocks=K) / nrep
        return _unflatten_bucket(out, spec)

    if strategy == "lane":
        parts = bucket_schedule(
            flat, K, (_rs_node(topo), _ar_lane(topo), _ag_node(topo)))
        return _unflatten_bucket(jnp.concatenate(parts) / nrep, spec)

    if strategy == "lane_int8":
        parts = bucket_schedule(
            flat, K, (_rs_node(topo), _ar_lane_int8(topo), _ag_node(topo)))
        return _unflatten_bucket(jnp.concatenate(parts) / nrep, spec)

    if strategy == "lane_zero1":
        parts = bucket_schedule(
            flat, K,
            (_rs_node(topo), lambda v: lax.psum(v, topo.lane_axis) / nrep))
        return jnp.concatenate(parts), spec   # caller owns the deferred AG
