"""Gradient synchronization backends — where the paper meets training.

The cross-replica gradient reduction of data-parallel training IS an
MPI_Allreduce over the (pod × data) communicator.  Strategies:

  native    one-shot ``psum`` over ("pod","data") — the "native library"
            baseline (XLA picks the algorithm).
  lane      the paper's Listing-4 decomposition, bucketed: K buckets each
            run ReduceScatter(node) → Allreduce(lane) → AllGather(node).
            Every chip of a pod carries 1/|node| of the cross-pod (DCN)
            payload concurrently — the full-lane property — and bucket
            b's DCN lane hop has no data dependence on bucket b+1's
            intra-pod reduce-scatter, so the two levels overlap (§5).
  lane_pipelined
            the §5 pipelined construction proper: all buckets stream
            through the three stages under one ``lax.scan``
            (core.pipeline.pipelined_allreduce_lane) — O(1) HLO size in
            the bucket count, same overlap structure.
  lane_int8 bucketed like ``lane``, but the DCN hop is int8-compressed
            (per-chunk scales, bitcast-fused into the SAME all-gather as
            the payload — one DCN collective per bucket): ~4× fewer DCN
            bytes; the intra-pod ICI hops stay fp32.  Beyond-paper
            distributed-optimization trick.
  lane_zero1 reduce-scatter only (no trailing all-gather): returns
            data-sharded grads for a ZeRO-1 sharded optimizer update; the
            all-gather of the paper's decomposition moves AFTER the
            optimizer (same bytes, applied to fresh params, moments stay
            sharded).  See launch/steps.py.  Bucketed on the RS + lane
            phases.
  lane_zero3 full reduce-scatter over BOTH levels — RS(node) then
            RS(lane) — leaving each chip its 1/p stripe of the reduced
            gradient, matching the ZeRO-3/FSDP parameter shard layout
            (zero3_param_shard).  No all-gather here at all: parameters
            stay sharded through the optimizer and are re-gathered
            layer-by-layer during the NEXT forward pass by the pipelined
            prefetch (core.pipeline.pipelined_allgather_lane; see
            launch/steps.py and DESIGN.md §5).

All strategies flatten the gradient pytree into one fp32 vector, then
split it into K equal buckets (K from the cost model's §5 latency/
bandwidth crossover, ``core.costmodel.optimal_num_buckets``, overridable
via ``RunConfig.gradsync_buckets``).  K collectives per level instead of
one trades latency (K·alpha) for pipeline overlap of the ICI and DCN
levels — the k-lane model's simultaneity term; see DESIGN.md §3.

The strategy DISPATCH lives in the :mod:`repro.comm` registry now (one
``@register_impl("grad_sync", ...)`` per strategy in repro/comm/impls.py,
DESIGN.md §6); this module keeps the shared machinery — flatten/pad,
bucket schedule, int8 packing, ZeRO shard layouts — plus a deprecated
``grad_sync`` shim over :class:`repro.comm.LaneComm` for old callers.
``STRATEGIES`` is derived from the registry (module ``__getattr__``).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import LaneTopology, optimal_num_buckets
from repro.core.collectives import _ag_seq, _rs_seq


def __getattr__(name):
    # STRATEGIES is derived from the repro.comm registry (the strategy
    # table lives there now), lazily to avoid a module-level import cycle
    # — new registrations are self-documenting here too.
    if name == "STRATEGIES":
        from repro.comm import strategies_for
        return strategies_for("grad_sync")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def _flatten_bucket(tree, pad_to: int):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    pad = (-n) % pad_to
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, (leaves, treedef, n)


def _unflatten_bucket(flat, spec):
    leaves, treedef, n = spec
    flat = flat[:n]
    out, ofs = [], 0
    for l in leaves:
        sz = math.prod(l.shape)
        out.append(flat[ofs:ofs + sz].reshape(l.shape).astype(l.dtype))
        ofs += sz
    return jax.tree.unflatten(treedef, out)


_INT8_CHUNK = 1024


def compress_int8(x):
    """Chunked symmetric int8 quantization; returns (q, scales)."""
    chunk = _INT8_CHUNK
    n = x.shape[0]
    pad = (-n) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    xr = x.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(xr), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xr / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def decompress_int8(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def pack_int8_payload(q, scale):
    """(C, chunk) int8 values + (C, 1) fp32 scales -> ONE 1-D int8 wire
    buffer ``[q-bytes | scale-bytes]``.

    The fp32 scales are bitcast to 4 int8 lanes each and appended, so the
    per-bucket DCN hop needs a single all-gather instead of a payload
    gather plus a separate scale gather (the ROADMAP-noted 2-collective
    inefficiency: the second gather paid a full DCN alpha for C·4 bytes).
    Bit-exact: the bytes are reinterpreted, never converted."""
    sb = lax.bitcast_convert_type(scale.astype(jnp.float32).reshape(-1),
                                  jnp.int8)                     # (C, 4)
    return jnp.concatenate([q.reshape(-1), sb.reshape(-1)])


def unpack_int8_payload(buf, num_chunks: int):
    """Inverse of pack_int8_payload: -> ((C, chunk) int8, (C, 1) fp32)."""
    m = num_chunks * _INT8_CHUNK
    q = buf[:m].reshape(num_chunks, _INT8_CHUNK)
    scale = lax.bitcast_convert_type(
        buf[m:].reshape(num_chunks, 4), jnp.float32)
    return q, scale.reshape(num_chunks, 1)


# ---------------------------------------------------------------------------
# bucket schedule (shared by every lane strategy)
# ---------------------------------------------------------------------------

def resolve_num_buckets(total_elems: int, n_node: int,
                        override: int = 0, *, elem_bytes: int = 4) -> int:
    """The K every bucketed strategy uses for ``total_elems`` gradients.

    override > 0 wins; otherwise the cost model picks K from the DCN
    latency/bandwidth crossover on the per-lane payload (c/n bytes — the
    full-lane stripe is what actually crosses the DCN).  K is additionally
    capped so each bucket keeps at least one row per chip after the node
    reduce-scatter.  Takes plain ints (not a topology) so callers outside
    shard_map — the ZeRO-1 optimizer-state init — resolve the same K.
    """
    if override > 0:
        k = override
    else:
        k = optimal_num_buckets(total_elems * elem_bytes / max(n_node, 1))
    return max(1, min(k, max(1, total_elems // max(n_node, 1))))


def bucket_schedule(flat, num_buckets: int,
                    stages: Sequence[Callable[[Any], Any]]):
    """Run ``flat`` through per-bucket ``stages`` in stage-skewed order.

    Splits ``flat`` (leading dim divisible by num_buckets) into equal
    contiguous buckets and applies every stage to every bucket, emitting
    ops wave by wave: bucket b's stage s+1 lands next to bucket b+1's
    stage s.  Cross-bucket ops never share operands, so the DCN stage of
    one bucket and the ICI stage of the next have no data dependence —
    XLA's scheduler is free to overlap them (structurally verified in
    launch.hlo_stats.collective_concurrency).  Emission order only hints
    the scheduler; correctness needs nothing from it.

    Returns the per-bucket results as a list (stages may change shapes,
    e.g. a reduce-scatter stage shrinks rows by n — concatenation is the
    caller's business).
    """
    K = num_buckets
    if flat.shape[0] % K:
        raise ValueError(
            f"flat dim {flat.shape[0]} not divisible by num_buckets={K}")
    bsz = flat.shape[0] // K
    vals = [lax.slice_in_dim(flat, b * bsz, (b + 1) * bsz, axis=0)
            for b in range(K)]
    S = len(stages)
    done = [0] * K                     # stages applied so far, per bucket
    for wave in range(K + S - 1):
        for b in range(min(wave, K - 1), max(wave - S, -1), -1):
            s = wave - b
            if 0 <= s < S and done[b] == s:
                vals[b] = stages[s](vals[b])
                done[b] += 1
    if not all(d == S for d in done):
        raise RuntimeError(
            f"bucket schedule incomplete: stage counts {done}, "
            f"expected {S} each")
    return vals


def _rs_node(topo: LaneTopology):
    return lambda v: _rs_seq(v, topo.node_axes)


def _ag_node(topo: LaneTopology):
    return lambda v: _ag_seq(v, topo.node_axes)


def _ar_lane(topo: LaneTopology):
    return lambda v: lax.psum(v, topo.lane_axis)


def _ar_lane_int8(topo: LaneTopology):
    """Compressed DCN allreduce stage: ONE fused all-gather per bucket.

    The per-chunk scales ride inside the int8 payload (bitcast, see
    pack_int8_payload) instead of a second scale all-gather — one DCN
    alpha per bucket, not two, and the schedule's wave structure sees a
    single collective to overlap with the neighbouring ICI stages."""
    def stage(v):
        q, scale, n = compress_int8(v)
        num_chunks = q.shape[0]
        buf = pack_int8_payload(q, scale)
        g = lax.all_gather(buf, topo.lane_axis, axis=0, tiled=False)
        N = g.shape[0]
        out = jnp.zeros((n,), jnp.float32)
        for i in range(N):
            qi, si = unpack_int8_payload(g[i], num_chunks)
            out = out + decompress_int8(qi, si, n)
        return out
    return stage


# ---------------------------------------------------------------------------
# ZeRO-1 shard layout (bucket-major, mirrors the bucketed reduce-scatter)
# ---------------------------------------------------------------------------
#
# With K buckets, chip i's lane_zero1 shard is the concatenation of its
# node-RS stripe from every bucket: [b0·stripe_i, b1·stripe_i, …] — the
# flat vector viewed as (K, n, s) sliced at node_rank on the middle axis.
# Reassembly therefore needs the (n, K) → (K, n) swap, the same reorder
# the paper's Listing 5 expresses with derived datatypes (DESIGN.md §3).

def zero1_param_shard(flat, topo: LaneTopology, num_buckets: int):
    """This chip's shard of a padded flat vector, matching the layout
    grad_sync(..., "lane_zero1", num_buckets=K) returns for gradients."""
    n = topo.n()
    K = num_buckets
    s = flat.shape[0] // (K * n)
    r = topo.node_rank()
    xb = flat.reshape(K, n, s)
    return jnp.take(xb, r, axis=1).reshape(K * s)    # traced-index pick


def zero1_unshard(shard, topo: LaneTopology, num_buckets: int):
    """All-gather per-chip (K·s,) shards back to the flat (K·n·s,) order."""
    n = topo.n()
    K = num_buckets
    g = _ag_seq(shard, topo.node_axes)                 # (n·K·s,) chip-major
    s = g.shape[0] // (n * K)
    return jnp.swapaxes(g.reshape(n, K, s), 0, 1).reshape(n * K * s)


# ---------------------------------------------------------------------------
# ZeRO-3 shard layout (bucket-major over node_rank × lane_rank)
# ---------------------------------------------------------------------------
#
# ZeRO-3 shards over the FULL p = n·N product communicator: with B buckets,
# chip (node_rank i, lane_rank j) holds the flat vector viewed as
# (B, n, N, s) sliced at [:, i, j, :].  This is exactly the order the
# pipelined AG(lane)→AG(node) reassembly of pipelined_allgather_lane emits
# blocks in, so the hot-path per-layer weight gather needs NO transpose
# (the Listing-3 zero-copy layout choice, DESIGN.md §2.2) — only the
# monolithic debug/negative-control unshard below pays a permute.

def zero3_param_shard(flat, topo: LaneTopology, num_blocks: int):
    """This chip's 1/p stripe of a padded flat vector, matching both the
    layout grad_sync(..., "lane_zero3", num_buckets=B) returns for
    gradients and the block order pipelined_allgather_lane reassembles."""
    n, N = topo.n(), topo.N()
    B = num_blocks
    s = flat.shape[0] // (B * n * N)
    idx = topo.node_rank() * N + topo.lane_rank()
    xb = flat.reshape(B, n * N, s, *flat.shape[1:])
    return jnp.take(xb, idx, axis=1).reshape(B * s, *flat.shape[1:])


def zero3_unshard(shard, topo: LaneTopology, num_blocks: int):
    """Monolithic reassembly of per-chip (B·s,) stripes to flat (B·n·N·s,).

    AG(lane) then AG(node) on the WHOLE shard — the blocking comparator
    to the pipelined per-block gather (and the negative control of the
    prefetch-overlap proof).  Gathering whole shards lands (i, j, b, s)
    order, so this path pays the (n·N, B) → (B, n·N) permute the
    pipelined path avoids."""
    n, N = topo.n(), topo.N()
    B = num_blocks
    g = lax.all_gather(shard, topo.lane_axis, axis=0, tiled=True)
    g = _ag_seq(g, topo.node_axes)                    # (n·N·B·s,) (i, j, b, s)
    s = g.shape[0] // (n * N * B)
    g = g.reshape(n * N, B, s, *shard.shape[1:])
    return jnp.swapaxes(g, 0, 1).reshape(B * n * N * s, *shard.shape[1:])


# ---------------------------------------------------------------------------
# optimizer-layout helper (shared by the sharded-AdamW call sites)
# ---------------------------------------------------------------------------

def decay_mask_flat(tree, pad_to: int):
    """0/1 fp32 mask over the ``_flatten_bucket`` layout of ``tree``:
    1 where the element's source leaf has ndim >= 2 — exactly the leaves
    ``adamw_update`` applies weight decay to.  Padding is 0 (never
    decayed).  Lets the flat sharded AdamW (launch/steps.py:_adamw_flat)
    reproduce the tree optimizer's matrices-only decay per element."""
    leaves = jax.tree.leaves(tree)
    mask = jnp.concatenate([
        jnp.full((math.prod(l.shape),),
                 1.0 if l.ndim >= 2 else 0.0, jnp.float32)
        for l in leaves])
    pad = (-mask.shape[0]) % pad_to
    if pad:
        mask = jnp.concatenate([mask, jnp.zeros((pad,), jnp.float32)])
    return mask


# ---------------------------------------------------------------------------
# entry point — DEPRECATED shim over repro.comm.LaneComm
# ---------------------------------------------------------------------------

def grad_sync(grads: Any, topo: LaneTopology, strategy: str = "native",
              *, num_buckets: int = 0):
    """DEPRECATED: construct a :class:`repro.comm.LaneComm` and call
    ``comm.grad_sync(...)`` instead.

    Synchronize (mean) gradients over the (lane × node) batch axes.
    Must be called inside shard_map with topo's axes manual.  Returns the
    fully-reduced tree for native/lane/lane_pipelined/lane_int8, or
    (sharded_flat, spec) for lane_zero1 / lane_zero3 (see steps.py for
    the deferred all-gather / the per-layer prefetch re-gather).
    ``num_buckets``: 0 = cost-model auto (§5 crossover); callers that
    must agree on the padded layout across call sites (the ZeRO-1
    optimizer state) should resolve K once via resolve_num_buckets and
    pass it explicitly.

    The shim delegates verbatim to the registry implementation LaneComm
    dispatches to — bit-identical results by construction (pinned by the
    conformance grid's gradsync_shim_bitident cases) — and warns once per
    process.  The per-strategy implementations (and the valid-strategy
    list in the unknown-strategy error) live in :mod:`repro.comm.impls`.
    """
    from repro._deprecation import warn_once
    from repro.comm import CommConfig, LaneComm
    warn_once(
        "repro.optim.gradsync.grad_sync",
        "grad_sync(grads, topo, strategy) is deprecated; construct "
        "repro.comm.LaneComm(topo, CommConfig(...)) once and call "
        "comm.grad_sync(grads, strategy=...) — strategies now resolve "
        "through the repro.comm registry")
    comm = LaneComm(topo, CommConfig(strategy=strategy,
                                     buckets=num_buckets))
    return comm.grad_sync(grads, strategy=strategy, num_buckets=num_buckets)
