"""Gradient synchronization backends — where the paper meets training.

The cross-replica gradient reduction of data-parallel training IS an
MPI_Allreduce over the (pod × data) communicator.  Strategies:

  native    one-shot ``psum`` over ("pod","data") — the "native library"
            baseline (XLA picks the algorithm).
  lane      the paper's Listing-4 decomposition: ReduceScatter(data) →
            Allreduce(pod) → AllGather(data).  Every chip of a pod carries
            1/|data| of the cross-pod (DCN) payload concurrently — the
            full-lane property; DCN bytes per pod = c, striped over all
            host NICs.
  lane_int8 same, but the pod hop is int8-compressed (per-chunk scales):
            4× fewer DCN bytes; the intra-pod ICI hops stay bf16.
            Beyond-paper distributed-optimization trick.
  lane_zero1 reduce-scatter only (no trailing all-gather): returns
            data-sharded grads for a ZeRO-1 sharded optimizer update; the
            all-gather of the paper's decomposition moves AFTER the
            optimizer (same bytes, applied to fresh params, moments stay
            sharded).  See launch/steps.py.

All functions run inside shard_map with ("pod","data") manual; gradients
are bucketed into one flat fp32/bf16 vector so each strategy is a single
collective sequence regardless of the parameter count (comm-op count: O(1)
instead of O(#tensors) — latency term of the k-lane model).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import LaneTopology, allreduce_lane


def _flatten_bucket(tree, pad_to: int):
    leaves, treedef = jax.tree.flatten(tree)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    n = flat.shape[0]
    pad = (-n) % pad_to
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, (leaves, treedef, n)


def _unflatten_bucket(flat, spec):
    leaves, treedef, n = spec
    flat = flat[:n]
    out, ofs = [], 0
    for l in leaves:
        sz = math.prod(l.shape)
        out.append(flat[ofs:ofs + sz].reshape(l.shape).astype(l.dtype))
        ofs += sz
    return jax.tree.unflatten(treedef, out)


def compress_int8(x):
    """Chunked symmetric int8 quantization; returns (q, scales)."""
    chunk = 1024
    n = x.shape[0]
    pad = (-n) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    xr = x.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(xr), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xr / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def decompress_int8(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def grad_sync(grads: Any, topo: LaneTopology, strategy: str = "native"):
    """Synchronize (mean) gradients over the (lane × node) batch axes.

    Must be called inside shard_map with topo's axes manual.  Returns the
    fully-reduced tree for native/lane/lane_int8, or (sharded_flat, spec)
    for lane_zero1 (see steps.py for the deferred all-gather).
    """
    axes = (topo.lane_axis, *topo.node_axes)
    nrep = 1
    for a in axes:
        nrep *= lax.axis_size(a)

    if strategy == "native":
        return jax.tree.map(lambda g: lax.psum(g, axes) / nrep, grads)

    n_node = topo.n()
    flat, spec = _flatten_bucket(grads, pad_to=n_node)

    if strategy == "lane":
        out = allreduce_lane(flat, topo) / nrep
        return _unflatten_bucket(out, spec)

    if strategy == "lane_int8":
        # RS(node level) — bf16/fp32 on ICI
        r = flat
        for a in topo.node_axes:
            r = lax.psum_scatter(r, a, scatter_dimension=0, tiled=True)
        # compressed AR over the DCN (lane) hop: int8 all-gather + local sum
        q, scale, n = compress_int8(r)
        qg = lax.all_gather(q, topo.lane_axis, axis=0, tiled=False)
        sg = lax.all_gather(scale, topo.lane_axis, axis=0, tiled=False)
        N = qg.shape[0]
        r = sum(decompress_int8(qg[i], sg[i], n) for i in range(N))
        # AG(node level) to reassemble
        for a in reversed(topo.node_axes):
            r = lax.all_gather(r, a, axis=0, tiled=True)
        return _unflatten_bucket(r / nrep, spec)

    if strategy == "lane_zero1":
        r = flat
        for a in topo.node_axes:
            r = lax.psum_scatter(r, a, scatter_dimension=0, tiled=True)
        r = lax.psum(r, topo.lane_axis) / nrep
        return r, spec                     # caller owns the deferred AG

    raise ValueError(f"unknown gradsync strategy {strategy!r}")
