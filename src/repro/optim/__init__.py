from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .gradsync import grad_sync, compress_int8, decompress_int8
