"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Optimizer state (m, v, fp32) mirrors the parameter pytree; under GSPMD it
inherits the parameter shardings (rule in launch/sharding.py), so FSDP
parameters automatically get ZeRO-sharded moments.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, params, *, grad_norm=None):
    """Returns (new_params, new_state).  All math in fp32; params keep dtype.

    grad_norm: override for the clipping norm — sharded-optimizer callers
    (launch/steps.py ZeRO paths) pass the TRUE global norm computed with
    an extra scalar psum over shard norms, so a partial tree (e.g. the
    zero3 rest-params) clips by the full-model norm exactly like the
    unsharded optimizer.  None = compute from ``grads`` (the default,
    correct when ``grads`` is the whole tree).
    """
    count = state["count"] + 1
    gnorm = global_norm(grads) if grad_norm is None else grad_norm
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_lr(cfg, count)
    c1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / c1, v / c2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decay matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "count": count}
