"""Composable model zoo: dense/GQA/SWA, MoE, Mamba2-SSD, hybrid, enc-dec, VLM."""
from .blockstack import (
    BlockSpec, ShardedStack, StackLayout, block_stack_families,
    block_stack_spec, scan_stack, shard_stack, stack_layout,
)
from .transformer import (
    init_model, model_forward, init_cache, prefill, decode_step,
    make_train_step, make_prefill_step, make_decode_step, loss_fn,
    ShardedBlocks,
)
