"""Composable model zoo: dense/GQA/SWA, MoE, Mamba2-SSD, hybrid, enc-dec, VLM."""
from .transformer import (
    init_model, model_forward, init_cache, prefill, decode_step,
    make_train_step, make_prefill_step, make_decode_step, loss_fn,
    ShardedBlocks,
)
