"""Attention: GQA with optional QKV bias and sliding window.

Three compute paths:

* ``attention_xla``      — blocked online-softmax (flash-style) in pure lax,
                           used for train/prefill.  Causal masking is applied
                           per block; the banded variant skips out-of-window
                           blocks entirely for SWA (honest linear FLOPs).
* ``decode_attention``   — single-query attention against a KV cache whose
                           sequence dim may be sharded over the "model" mesh
                           axis; written so GSPMD's partial-reduction rules
                           lower the softmax into the distributed
                           log-sum-exp combine (no cache all-gather).
* Pallas kernel          — repro.kernels.flash_attention, the TPU-target
                           path (see kernels/ops.py for dispatch).

Projections are kept *flat* (d → H·hd) so tensor-parallel sharding is a
plain column/row split; KV projections are replicated over the model axis
when num_kv_heads doesn't divide the TP degree (GQA kv < tp case).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import dense_init, apply_rope, _dtype

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, K, hd, dt = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd(), _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, H * hd), dt),
         "wk": dense_init(ks[1], (d, K * hd), dt),
         "wv": dense_init(ks[2], (d, K * hd), dt),
         "wo": dense_init(ks[3], (H * hd, d), dt)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dt)
        p["bk"] = jnp.zeros((K * hd,), dt)
        p["bv"] = jnp.zeros((K * hd,), dt)
    return p


def qkv(p: dict, x, cfg: ModelConfig, positions=None, rope: bool = True):
    """x: (B, T, d) → q (B,T,H,hd), k/v (B,T,K,hd), rotary applied."""
    B, T, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd()
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, H, hd)
    k = k.reshape(B, T, K, hd)
    v = v.reshape(B, T, K, hd)
    if rope:
        if positions is None:
            positions = jnp.arange(T)[None, :]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# blocked online-softmax attention (training / prefill)
# ---------------------------------------------------------------------------

def _pick_block(T: int, target: int) -> int:
    """Largest divisor of T that is ≤ target (whisper's 1500-frame encoder
    isn't 512-divisible; blocks must tile the sequence exactly)."""
    b = min(target, T)
    while T % b:
        b -= 1
    return b


def _gqa_scores(qb, kb):
    """qb: (B,Tq,K,G,hd)  kb: (B,Tk,K,hd) → (B,K,G,Tq,Tk) fp32."""
    return jnp.einsum("btkgd,bskd->bkgts", qb, kb,
                      preferred_element_type=jnp.float32)


def attention_xla(q, k, v, *, causal: bool, window: int = 0,
                  q_offset: int = 0, block_q: int = 512, block_k: int = 512,
                  save_memory: bool = True):
    """Flash-style blocked attention, pure lax (runs/lowers anywhere).

    q: (B, Tq, H, hd); k, v: (B, Tk, K, hd); H = K·G.
    ``q_offset``: absolute position of q[0] (prefill continuation).
    ``window`` > 0 selects the banded path: for each q block only the k
    blocks intersecting [pos-window, pos] are touched — honest O(T·w) FLOPs.
    ``save_memory`` checkpoints each k-step so the backward recomputes the
    score block instead of storing nk fp32 (bq,bk) tiles per layer — the
    flash-attention trade, expressed at the lax level (the Pallas kernel
    does the same natively on TPU).
    Returns (B, Tq, H, hd) in q.dtype.
    """
    B, Tq, H, hd = q.shape
    Tk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qs = (q * scale).reshape(B, Tq, K, G, hd)

    if window and window < Tk:
        return _attention_banded(qs, k, v, window=window, causal=causal,
                                 q_offset=q_offset, block_q=block_q)

    block_q = _pick_block(Tq, block_q)
    block_k = _pick_block(Tk, block_k)
    nq, nk = Tq // block_q, Tk // block_k

    kpos = jnp.arange(Tk)

    def q_block(qi):
        qb = lax.dynamic_slice_in_dim(qs, qi * block_q, block_q, axis=1)
        qpos = q_offset + qi * block_q + jnp.arange(block_q)

        def k_step(carry, ki):
            m, l, acc = carry
            kb = lax.dynamic_slice_in_dim(k, ki * block_k, block_k, axis=1)
            vb = lax.dynamic_slice_in_dim(v, ki * block_k, block_k, axis=1)
            s = _gqa_scores(qb, kb)                       # (B,K,G,bq,bk)
            kp = ki * block_k + jnp.arange(block_k)
            if causal:
                mask = qpos[:, None] >= kp[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum("bkgts,bskd->bkgtd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, K, G, block_q, hd), jnp.float32)
        step_fn = jax.checkpoint(k_step) if save_memory else k_step
        (m, l, acc), _ = lax.scan(step_fn, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]      # (B,K,G,bq,hd)
        return jnp.moveaxis(out, 3, 1)                    # (B,bq,K,G,hd)

    outs = lax.map(q_block, jnp.arange(nq))               # (nq,B,bq,K,G,hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tq, H, hd)
    return out.astype(q.dtype)


def _attention_banded(qs, k, v, *, window: int, causal: bool, q_offset: int,
                      block_q: int):
    """Sliding-window attention: each q block reads only its KV band.

    qs pre-scaled: (B, Tq, K, G, hd).  Band width = window + block_q rows of
    KV per q block — FLOPs are O(Tq·(window+block_q)), not O(Tq·Tk).
    """
    B, Tq, K, G, hd = qs.shape
    Tk = k.shape[1]
    block_q = _pick_block(Tq, block_q)
    nq = Tq // block_q
    band = window + block_q

    def q_block(qi):
        qb = lax.dynamic_slice_in_dim(qs, qi * block_q, block_q, axis=1)
        qpos = q_offset + qi * block_q + jnp.arange(block_q)
        start = jnp.clip(q_offset + qi * block_q - window, 0, max(Tk - band, 0))
        kb = lax.dynamic_slice_in_dim(k, start, min(band, Tk), axis=1)
        vb = lax.dynamic_slice_in_dim(v, start, min(band, Tk), axis=1)
        kp = start + jnp.arange(kb.shape[1])
        s = _gqa_scores(qb, kb)
        mask = kp[None, :] >= qpos[:, None] - window
        if causal:
            mask &= qpos[:, None] >= kp[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = s.max(-1, keepdims=True)
        p = jnp.exp(s - m)
        out = jnp.einsum("bkgts,bskd->bkgtd", p.astype(vb.dtype), vb,
                         preferred_element_type=jnp.float32)
        out = out / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
        return jnp.moveaxis(out, 3, 1)                    # (B,bq,K,G,hd)

    outs = lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Tq, K * G, hd)
    return out.astype(k.dtype)


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------

def decode_attention(q, kcache, vcache, cache_len, *, window: int = 0):
    """q: (B, 1, H, hd); caches: (B, S, K, hd); cache_len: current length.

    The cache's S dim may be sharded over the model axis — the max/sum
    reductions below are over that dim, which GSPMD lowers to local partial
    softmax stats + cross-shard combine (the distributed LSE pattern), never
    an all-gather of the cache.
    """
    B, _, H, hd = q.shape
    S, K = kcache.shape[1], kcache.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qh = (q[:, 0] * scale).reshape(B, K, G, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, kcache,
                   preferred_element_type=jnp.float32)
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        cache_len = jnp.broadcast_to(cache_len, (B,))
    pos = jnp.arange(S)
    valid = pos[None, :] < cache_len[:, None]
    if window:
        valid = jnp.logical_and(valid,
                                pos[None, :] >= cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    num = jnp.einsum("bkgs,bskd->bkgd", p.astype(vcache.dtype), vcache,
                     preferred_element_type=jnp.float32)
    out = num / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    return out.reshape(B, 1, H, hd).astype(q.dtype)
