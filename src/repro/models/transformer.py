"""Model assembly: one stack covering every assigned architecture family.

Families
  dense  — llama-style pre-norm blocks (GQA attn + [Sw]GLU MLP), scanned
  moe    — same skeleton with the MLP swapped for the capacity MoE
  ssm    — Mamba2 blocks only (attention-free)
  hybrid — Mamba2 backbone + ONE weight-shared attention block applied
           every `hybrid_attn_every` layers (Zamba2; weight sharing is the
           published design, simplification: standard residual insertion)
  vlm    — dense backbone consuming [projected patch embeds | token embeds]
  audio  — Whisper backbone: bidirectional encoder over stub frame
           embeddings + causal decoder with cross-attention

All parameters for scanned layers are stacked along a leading L dim
(init via vmap over per-layer keys), so compile time is O(1) in depth and
FSDP/TP shardings apply uniformly.  Serving uses functional caches threaded
through the layer scan as scan xs/ys.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig
from . import layers as L
from . import attention as A
from . import ssm as S
from . import moe as M
from .blockstack import (BlockSpec, ShardedBlocks, ShardedStack,
                         block_stack_spec, register_block_stack, scan_stack,
                         scan_stack_cached)

# activation-sharding hints live in layers.py (shared with moe/ssm);
# re-exported here for the launch layer.
from .layers import activation_batch_axes, pin_act, pin_kv  # noqa: E402
from .parallel import parallel_ctx  # noqa: E402


def _pin(h):
    """Layer-boundary pin: batch axes + optional d_axis on the feature dim.

    Without this, GSPMD under FSDP params may flip activations to
    batch-replicated / d-sharded (verified: 16× activation memory on
    qwen110b train_4k); with d_axis set the saved-for-backward h stacks
    also shrink by the TP degree (Megatron-SP-along-d algebra).
    """
    return pin_act(h, shard_last=True)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_norm(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return L.init_layernorm(d, L._dtype(cfg))
    return L.init_rmsnorm(d, L._dtype(cfg))


def _init_attn_layer(key, cfg: ModelConfig, cross: bool = False) -> dict:
    ks = jax.random.split(key, 6)
    p = {"ln1": _init_norm(cfg, cfg.d_model),
         "attn": A.init_attention(ks[0], cfg),
         "ln2": _init_norm(cfg, cfg.d_model)}
    if cfg.family == "moe":
        p["moe"] = M.init_moe(ks[1], cfg)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg)
    if cross:
        p["lnx"] = _init_norm(cfg, cfg.d_model)
        p["xattn"] = A.init_attention(ks[2], cfg)
    return p


def _init_mamba_layer(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {"ln1": _init_norm(cfg, cfg.d_model),
            "mamba": S.init_mamba2(k1, cfg)}


def _stacked(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def init_model(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {"embed": L.init_embed(ks[0], cfg),
                              "final_norm": _init_norm(cfg, cfg.d_model)}
    if cfg.family in ("dense", "vlm", "moe"):
        params["blocks"] = _stacked(lambda k: _init_attn_layer(k, cfg),
                                    ks[1], cfg.num_layers)
    elif cfg.family == "ssm":
        params["blocks"] = _stacked(lambda k: _init_mamba_layer(k, cfg),
                                    ks[1], cfg.num_layers)
    elif cfg.family == "hybrid":
        params["blocks"] = _stacked(lambda k: _init_mamba_layer(k, cfg),
                                    ks[1], cfg.num_layers)
        params["shared_attn"] = _init_attn_layer(ks[2], cfg)
    elif cfg.family == "audio":
        params["blocks"] = _stacked(
            lambda k: _init_attn_layer(k, cfg, cross=True), ks[1],
            cfg.num_layers)
        params["encoder"] = {
            "blocks": _stacked(lambda k: _init_attn_layer(k, cfg), ks[3],
                               cfg.encoder_layers),
            "final_norm": _init_norm(cfg, cfg.d_model),
            "pos": L.dense_init(ks[4], (cfg.encoder_seq, cfg.d_model),
                                L._dtype(cfg), scale=0.01),
        }
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        params["vis_proj"] = L.dense_init(ks[5], (cfg.d_model, cfg.d_model),
                                          L._dtype(cfg))
    return params


# ---------------------------------------------------------------------------
# blocks (shared by the no-cache and cached paths)
# ---------------------------------------------------------------------------

def _norm(cfg, p, x):
    return L.apply_norm(p, x, cfg.norm_eps)


def _attn_noncache(lp, h, cfg: ModelConfig, *, causal: bool, positions,
                   window: int, kv=None):
    """Full-sequence attention (train / encoder / cross with given kv)."""
    hn = _norm(cfg, lp["ln1"] if kv is None else lp["lnx"], h)
    ap = lp["attn"] if kv is None else lp["xattn"]
    if kv is None:
        q, k, v = A.qkv(ap, hn, cfg, positions=positions, rope=True)
    else:
        q, _, _ = A.qkv(ap, hn, cfg, positions=positions, rope=False)
        k, v = kv
    o = A.attention_xla(q, k, v, causal=causal, window=window)
    o = o.reshape(*o.shape[:2], -1) @ ap["wo"]
    return h + o


def _ffn(lp, h, cfg: ModelConfig):
    hn = _norm(cfg, lp["ln2"], h)
    ctx = parallel_ctx()
    if "moe" in lp:
        if ctx.ep and ctx.ep_comm is not None:
            out, aux = M.moe_block_ep(lp["moe"], hn, cfg, comm=ctx.ep_comm,
                                      ep_blocks=ctx.ep_blocks,
                                      strategy=ctx.ep_strategy)
        else:
            out, aux = M.moe_block(lp["moe"], hn, cfg)
        return h + out, aux
    if ctx.tp > 1 and ctx.tp_comm is not None:
        tp_mlp = L.mlp_tp_reduce if ctx.tp_variant == "reduce" else L.mlp_tp
        return h + tp_mlp(lp["mlp"], hn, cfg, comm=ctx.tp_comm,
                          strategy=ctx.tp_strategy), 0.0
    return h + L.mlp(lp["mlp"], hn, cfg), 0.0


def _dense_block(lp, h, cfg: ModelConfig, *, positions, enc_out=None):
    causal = True
    h = _attn_noncache(lp, h, cfg, causal=causal, positions=positions,
                       window=cfg.sliding_window)
    if enc_out is not None and "xattn" in lp:
        k, v = _cross_kv(lp["xattn"], enc_out, cfg)
        h = _attn_noncache(lp, h, cfg, causal=False, positions=positions,
                           window=0, kv=(k, v))
    h, aux = _ffn(lp, h, cfg)
    return h, aux


def _cross_kv(ap, enc_out, cfg: ModelConfig):
    Bz, Te, _ = enc_out.shape
    K, hd = cfg.num_kv_heads, cfg.hd()
    k = (enc_out @ ap["wk"]).reshape(Bz, Te, K, hd)
    v = (enc_out @ ap["wv"]).reshape(Bz, Te, K, hd)
    if "bk" in ap:
        k = k + ap["bk"].reshape(K, hd)
        v = v + ap["bv"].reshape(K, hd)
    return k, v


def _mamba_block(lp, h, cfg: ModelConfig, state=None):
    hn = _norm(cfg, lp["ln1"], h)
    out, new_state = S.mamba2_block(lp["mamba"], hn, cfg, state=state)
    return h + out, new_state


# families whose layer stack is one lax.scan over params["blocks"] — the
# shape ZeRO-3 sharding (ShardedStack, repro.models.blockstack) can
# substitute into directly; ssm/hybrid scan through their own bodies
_SCANNED_FAMILIES = ("dense", "vlm", "moe", "audio")


# ---------------------------------------------------------------------------
# forward (no cache): training and encoder passes
# ---------------------------------------------------------------------------

def _maybe_remat(f, policy: str):
    if policy == "none":
        return f
    if policy == "full":
        return jax.checkpoint(f)
    if policy == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


def _encoder_forward(params, cfg: ModelConfig, frames, remat: str = "none"):
    """Whisper encoder over stub frame embeddings (B, Te, d)."""
    enc = params["encoder"]
    h = frames + enc["pos"][None, :frames.shape[1]]
    positions = jnp.arange(frames.shape[1])[None]

    def body(h, lp):
        h = _attn_noncache(lp, h, cfg, causal=False, positions=positions,
                           window=0)
        h, _ = _ffn(lp, h, cfg)
        return _pin(h), None

    body = _maybe_remat(body, remat)
    h, _ = lax.scan(body, h, enc["blocks"])
    return _norm(cfg, enc["final_norm"], h)


def _embed_inputs(params, cfg: ModelConfig, tokens, extra_embeds):
    """Token embeds, with VLM patch prefix when provided."""
    h = L.embed(params["embed"], tokens)
    if cfg.family == "vlm":
        if extra_embeds is None:
            raise ValueError("vlm needs patch embeddings")
        vis = extra_embeds @ params["vis_proj"]
        h = jnp.concatenate([vis.astype(h.dtype), h], axis=1)
    return h


def model_forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
                  remat: str = "none"):
    """Full forward to logits; `extra_embeds` = patches (vlm) / frames (audio).

    Returns (logits (B, T_total, V), aux_loss).
    """
    enc_out = None
    if cfg.family == "audio":
        if extra_embeds is None:
            raise ValueError("audio needs frame embeddings")
        enc_out = _encoder_forward(params, cfg, extra_embeds, remat)
        h = L.embed(params["embed"], tokens)
    else:
        h = _embed_inputs(params, cfg, tokens, extra_embeds)
    h = _pin(h)
    Bz, T, _ = h.shape
    positions = jnp.arange(T)[None]
    aux_total = jnp.zeros((), jnp.float32)

    if isinstance(params.get("blocks"), ShardedStack):
        # ONE code path for every lane-capable family: the registered
        # BlockSpec supplies the per-layer body, scan_stack supplies the
        # prefetch/blocking/regather layer scan (models/blockstack.py)
        spec = block_stack_spec(cfg)
        body = spec.make_body(cfg, params, positions=positions,
                              enc_out=enc_out, remat=remat)
        h, aux_ys = scan_stack(params["blocks"], h, body)
        aux_total = jnp.sum(aux_ys)

    elif cfg.family in _SCANNED_FAMILIES:
        # aux losses leave via ys, not the carry (a mixed-dtype carry made
        # XLA:CPU stack an f32 copy of every layer's h for the backward)
        def body(h, lp):
            h, a = _dense_block(lp, h, cfg, positions=positions,
                                enc_out=enc_out)
            return _pin(h), a
        body = _maybe_remat(body, remat)
        h, aux_ys = lax.scan(body, h, params["blocks"])
        aux_total = jnp.sum(aux_ys)

    elif cfg.family == "ssm":
        def body(h, lp):
            h, _ = _mamba_block(lp, h, cfg)
            return _pin(h), None
        body = _maybe_remat(body, remat)
        h, _ = lax.scan(body, h, params["blocks"])

    elif cfg.family == "hybrid":
        h = _hybrid_forward(params, cfg, h, positions, remat)

    h = _norm(cfg, params["final_norm"], h)
    logits = L.unembed(params["embed"], h)
    return logits, aux_total


def _hybrid_split(cfg: ModelConfig):
    every = cfg.hybrid_attn_every
    groups = cfg.num_layers // every
    tail = cfg.num_layers - groups * every
    return groups, every, tail


def _tree_first(tree, n):
    return jax.tree.map(lambda a: a[:n], tree)


def _tree_rest(tree, n):
    return jax.tree.map(lambda a: a[n:], tree)


def _hybrid_forward(params, cfg: ModelConfig, h, positions, remat):
    """Zamba2: every `every` Mamba2 layers, apply the shared attn block."""
    groups, every, tail = _hybrid_split(cfg)
    shared = params["shared_attn"]
    head = _tree_first(params["blocks"], groups * every)
    head = jax.tree.map(
        lambda a: a.reshape(groups, every, *a.shape[1:]), head)

    def mamba_body(h, lp):
        h, _ = _mamba_block(lp, h, cfg)
        return _pin(h), None

    # nested remat: without it the whole 6-layer group's SSD internals
    # (the (nc,Q,Q,H) decay tensors) stay live during the group backward
    mamba_body = _maybe_remat(mamba_body, remat)

    def group_body(h, gp):
        h = _attn_noncache(shared, h, cfg, causal=True, positions=positions,
                           window=cfg.sliding_window)
        h, _ = _ffn(shared, h, cfg)
        h, _ = lax.scan(mamba_body, h, gp)
        return _pin(h), None

    group_body = _maybe_remat(group_body, remat)
    h, _ = lax.scan(group_body, h, head)
    if tail:
        tail_p = _tree_rest(params["blocks"], groups * every)
        h, _ = lax.scan(mamba_body, h, tail_p)
    return h


# ---------------------------------------------------------------------------
# block-stack specs: how each family rides the ZeRO-3 sharded stack
# (registered through the repro.comm registry seam; the machinery lives in
# models/blockstack.py, the zero3 step resolves specs via block_stack_spec)
# ---------------------------------------------------------------------------

def _scanned_stack_body(cfg, params, *, positions, enc_out, remat):
    """Per-layer body of the scanned attention families (dense/vlm/moe/
    audio): identical math to the replicated layer scan.

    Under expert-parallel ``lane_zero3`` the expert weights live OUTSIDE
    the flat stack in a never-gathered (L, E/p, ...) local master
    (``ParallelContext.ep_experts``); layer i's row is sliced out here
    and merged into ``lp["moe"]`` so the block math below is untouched.
    """
    def body(h, lp, i):
        experts = parallel_ctx().ep_experts
        if experts is not None and "moe" in lp:
            row = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                experts)
            lp = {**lp, "moe": {**lp["moe"], **row}}
        h, a = _dense_block(lp, h, cfg, positions=positions,
                            enc_out=enc_out)
        return _pin(h), a
    return _maybe_remat(body, remat)


def _ssm_stack_body(cfg, params, *, positions, enc_out, remat):
    """Mamba2 SSD scan bodies as the sharded layer unit."""
    def body(h, lp, i):
        h, _ = _mamba_block(lp, h, cfg)
        return _pin(h), jnp.zeros((), jnp.float32)
    return _maybe_remat(body, remat)


def _hybrid_stack_body(cfg, params, *, positions, enc_out, remat):
    """Zamba2 grouped layout as a flat per-layer scan: the weight-SHARED
    attention block (replicated — it runs ``groups`` times per forward,
    so sharding it would re-gather the same bytes repeatedly) fires
    before Mamba2 layer i exactly when i opens a group; the tail layers
    past ``groups·every`` never see it — the same schedule as the
    replicated ``_hybrid_forward``, without its nested group scan.  The
    remat cell is the per-layer body only, and the prefetch gather stays
    OUTSIDE it, so a backward recompute re-runs the block math but never
    the gather (pinned by the gather-count HLO case)."""
    groups, every, tail = _hybrid_split(cfg)
    shared = params["shared_attn"]

    def shared_block(h):
        h = _attn_noncache(shared, h, cfg, causal=True, positions=positions,
                           window=cfg.sliding_window)
        h, _ = _ffn(shared, h, cfg)
        return h

    def body(h, lp, i):
        at_group_start = jnp.logical_and(i % every == 0,
                                         i < groups * every)
        h = lax.cond(at_group_start, shared_block, lambda hh: hh, h)
        h, _ = _mamba_block(lp, h, cfg)
        return _pin(h), jnp.zeros((), jnp.float32)
    return _maybe_remat(body, remat)


@register_block_stack("dense")
@register_block_stack("vlm")
@register_block_stack("audio")
def _block_stack_attn(cfg: ModelConfig) -> BlockSpec:
    """Scanned attention families: the (L, ...) block stack is the
    sharding unit; embed/final_norm (+ vis_proj / encoder) ride as the
    extras pseudo-layer.  vlm/audio forwards consume extra_embeds
    (patches / frames) the training driver does not synthesize, so
    driver-level sweeps skip them (family_smoke_archs)."""
    return BlockSpec(family=cfg.family, make_body=_scanned_stack_body,
                     needs_extra_embeds=cfg.family in ("vlm", "audio"))


@register_block_stack("moe")
def _block_stack_moe(cfg: ModelConfig) -> BlockSpec:
    """MoE: same scanned skeleton, but the per-layer flat vector is
    dominated by the stacked (E, d, f) expert tensors, so the 1/p
    stripes slice through the experts — the experts are the sharding
    unit, exactly the payload ZeRO-3 exists for."""
    return BlockSpec(family="moe", make_body=_scanned_stack_body)


@register_block_stack("ssm")
def _block_stack_ssm(cfg: ModelConfig) -> BlockSpec:
    return BlockSpec(family="ssm", make_body=_ssm_stack_body)


@register_block_stack("hybrid")
def _block_stack_hybrid(cfg: ModelConfig) -> BlockSpec:
    """Mamba2 backbone sharded 1/p; the weight-shared attention block
    stays replicated (``replicated_keys``) and its gradient syncs through
    the bucketed lane path."""
    return BlockSpec(family="hybrid", make_body=_hybrid_stack_body,
                     replicated_keys=("shared_attn",))


# ---------------------------------------------------------------------------
# loss / train step
# ---------------------------------------------------------------------------

def loss_fn(params, cfg: ModelConfig, tokens, labels, *, extra_embeds=None,
            remat: str = "none", aux_weight: float = 0.01):
    """Next-token CE; labels = -100 are masked.  Returns scalar fp32 loss."""
    logits, aux = model_forward(params, cfg, tokens,
                                extra_embeds=extra_embeds, remat=remat)
    # VLM prefixes add vision tokens in front: loss only over text positions
    if logits.shape[1] != labels.shape[1]:
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via one-hot contraction, NOT take_along_axis: a gather
    # along the vocab dim would force GSPMD to all-gather the (B,T,V)
    # logits across the "model" axis; the masked reduction stays sharded.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    onehot = (vocab_iota == safe[..., None])
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ce = (logz - gold) * mask
    loss = ce.sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeState:
    """Functional serving state (a pytree)."""
    cache: Any                 # per-family structure, stacked over layers
    length: Any                # (B,) int32 valid lengths
    enc_kv: Any = None         # audio: per-layer cross K/V (stacked)

    def tree_flatten(self):
        return (self.cache, self.length, self.enc_kv), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    ServeState, lambda s: s.tree_flatten(),
    lambda aux, c: ServeState(*c))


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Any:
    """Stacked per-layer cache; KV seq dim is later sharded over "model"."""
    K, hd, Lr = cfg.num_kv_heads, cfg.hd(), cfg.num_layers
    kv = lambda n: {"k": jnp.zeros((n, batch, max_seq, K, hd), dtype),
                    "v": jnp.zeros((n, batch, max_seq, K, hd), dtype)}
    if cfg.family in _SCANNED_FAMILIES:
        return kv(Lr)
    if cfg.family == "ssm":
        st = S.init_mamba_state(cfg, batch)
        return jax.tree.map(
            lambda a: jnp.zeros((Lr, *a.shape), a.dtype), st)
    if cfg.family == "hybrid":
        groups, every, tail = _hybrid_split(cfg)
        st = S.init_mamba_state(cfg, batch)
        return {
            "mamba": jax.tree.map(
                lambda a: jnp.zeros((Lr, *a.shape), a.dtype), st),
            "attn": kv(groups),
        }
    raise ValueError(cfg.family)


def _attn_cached(lp, h, cfg: ModelConfig, lc, length, *, prefill: bool,
                 enc_kv=None):
    """Attention with cache read/write.  h: (B,T,d); lc: {"k","v"} (B,S,K,hd).

    prefill: writes positions [0, T) and attends within the new block.
    decode:  T == 1, writes at `length`, attends to the whole cache.
    """
    Bz, T, _ = h.shape
    Smax = lc["k"].shape[1]
    positions = (jnp.arange(T)[None] if prefill else length[:, None])
    hn = _norm(cfg, lp["ln1"], h)
    q, k, v = A.qkv(lp["attn"], hn, cfg, positions=positions, rope=True)
    if prefill:
        newk = pin_kv(lax.dynamic_update_slice_in_dim(
            lc["k"], pin_kv(k.astype(lc["k"].dtype)), 0, axis=1))
        newv = pin_kv(lax.dynamic_update_slice_in_dim(
            lc["v"], pin_kv(v.astype(lc["v"].dtype)), 0, axis=1))
        o = A.attention_xla(q, k, v, causal=True, window=cfg.sliding_window)
    else:
        # one-hot select at per-row `length` (GSPMD-safe on a sharded S dim;
        # pure select — an arithmetic blend promoted the stacked cache ys
        # to fp32 on the CPU backend)
        hot = (jnp.arange(Smax)[None, :] == length[:, None])        # (B,S)
        newk = pin_kv(jnp.where(hot[..., None, None],
                                k.astype(lc["k"].dtype), lc["k"]))
        newv = pin_kv(jnp.where(hot[..., None, None],
                                v.astype(lc["v"].dtype), lc["v"]))
        o = A.decode_attention(q, newk, newv, length + 1,
                               window=cfg.sliding_window)
    o = o.reshape(Bz, T, -1) @ lp["attn"]["wo"]
    h = h + o
    if enc_kv is not None and "xattn" in lp:
        hn = _norm(cfg, lp["lnx"], h)
        qx, _, _ = A.qkv(lp["xattn"], hn, cfg, positions=positions, rope=False)
        o = A.decode_attention(qx, enc_kv["k"], enc_kv["v"],
                               jnp.full((Bz,), enc_kv["k"].shape[1])) \
            if not prefill else \
            A.attention_xla(qx, enc_kv["k"], enc_kv["v"], causal=False)
        h = h + o.reshape(Bz, T, -1) @ lp["xattn"]["wo"]
    h, _ = _ffn(lp, h, cfg)
    return h, {"k": newk, "v": newv}


def _scan_enc_kv(params, cfg, enc_out):
    def body(_, lp):
        k, v = _cross_kv(lp["xattn"], enc_out, cfg)
        return None, {"k": k, "v": v}
    _, kv = lax.scan(body, None, params["blocks"])
    return kv


def _select_row(h, pos):
    """(B, T, d) -> (B, 1, d): row ``pos[b]`` of each batch element, with a
    traced per-row ``pos``, via one-hot select (no gather — GSPMD-safe on
    a sharded T dim; exact, since exactly one position is hot)."""
    hot = (jnp.arange(h.shape[1])[None, :] == pos[:, None])
    return jnp.sum(jnp.where(hot[..., None], h, jnp.zeros((), h.dtype)),
                   axis=1, keepdims=True).astype(h.dtype)


def prefill(params, cfg: ModelConfig, tokens, cache, *, extra_embeds=None,
            true_len=None):
    """Run the prompt; fill caches.  Returns (logits_last, state).

    ``true_len`` (scalar or (B,) int) marks the valid prompt length when
    ``tokens`` is right-padded to a bucket: the returned logits are taken
    at the LAST TRUE position (``prefix + true_len - 1``, prefix = the
    vlm vision tokens) instead of the bucket's last position — the seed
    engine conditioned the first generated token on trailing pad — and
    ``state.length`` is ``prefix + true_len``, so decode overwrites the
    pad region progressively and attention never reads past it.  Only
    meaningful for attention caches; the recurrent families (ssm/hybrid)
    fold every consumed token into their state, so their callers must
    prefill at the exact prompt length (the engine does).

    ``params["blocks"]`` may be a :class:`ShardedStack` (zero3 hosting):
    the cached layer scan then runs through ``scan_stack_cached`` with the
    same one-layer prefetch as training, and the audio cross K/V are
    computed inside the body (the encoder output is replicated; the
    per-layer projections live in the sharded stack).
    """
    sharded = isinstance(params.get("blocks"), ShardedStack)
    enc_kv = None
    enc_out = None
    if cfg.family == "audio":
        enc_out = _encoder_forward(params, cfg, extra_embeds)
        if not sharded:
            enc_kv = _scan_enc_kv(params, cfg, enc_out)
        h = L.embed(params["embed"], tokens)
    else:
        h = _embed_inputs(params, cfg, tokens, extra_embeds)
    Bz, T, _ = h.shape
    length0 = jnp.zeros((Bz,), jnp.int32)

    if sharded:
        if cfg.family == "audio":
            def body(h, lp, lc):
                k, v = _cross_kv(lp["xattn"], enc_out, cfg)
                ekv = {"k": k, "v": v}
                h, newc = _attn_cached(lp, h, cfg, lc, length0,
                                       prefill=True, enc_kv=ekv)
                return h, (newc, ekv)
            h, (newcache, enc_kv) = scan_stack_cached(
                params["blocks"], h, cache, body)
        elif cfg.family in _SCANNED_FAMILIES:
            def body(h, lp, lc):
                h, newc = _attn_cached(lp, h, cfg, lc, length0,
                                       prefill=True)
                return h, newc
            h, newcache = scan_stack_cached(params["blocks"], h, cache,
                                            body)
        elif cfg.family == "ssm":
            def body(h, lp, lc):
                hn = _norm(cfg, lp["ln1"], h)
                out, st = S.mamba2_block(lp["mamba"], hn, cfg, state=lc)
                return h + out, st
            h, newcache = scan_stack_cached(params["blocks"], h, cache,
                                            body)
        else:
            raise ValueError(
                f"family {cfg.family!r} cannot serve from a ShardedStack "
                f"(the hybrid grouped attention cache does not fit the "
                f"flat layer scan); host it replicated")
    elif cfg.family in _SCANNED_FAMILIES:
        xs = (params["blocks"], cache) if enc_kv is None else \
             (params["blocks"], cache, enc_kv)

        def body(h, lpc):
            lp, lc = lpc[0], lpc[1]
            ekv = lpc[2] if len(lpc) == 3 else None
            h, newc = _attn_cached(lp, h, cfg, lc, length0, prefill=True,
                                   enc_kv=ekv)
            return h, newc

        h, newcache = lax.scan(body, h, xs)
    elif cfg.family == "ssm":
        def body(h, lpc):
            lp, lc = lpc
            hn = _norm(cfg, lp["ln1"], h)
            out, st = S.mamba2_block(lp["mamba"], hn, cfg, state=lc)
            return h + out, st
        h, newcache = lax.scan(body, h, (params["blocks"], cache))
    elif cfg.family == "hybrid":
        h, newcache = _hybrid_cached(params, cfg, h, cache, length0,
                                     prefill=True)
    else:
        raise ValueError(cfg.family)

    h = _norm(cfg, params["final_norm"], h)
    prefix = T - tokens.shape[1]            # vlm vision tokens, else 0
    if true_len is None:
        h_last = h[:, -1:]
        length = jnp.full((Bz,), T, jnp.int32)
    else:
        tl = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32), (Bz,))
        h_last = _select_row(h, prefix + tl - 1)
        length = prefix + tl
    logits = L.unembed(params["embed"], h_last)
    state = ServeState(cache=newcache, length=length, enc_kv=enc_kv)
    return logits, state


def decode_step(params, cfg: ModelConfig, token, state: ServeState):
    """One token for every sequence.  token: (B, 1) int32.

    Like :func:`prefill`, ``params["blocks"]`` may be a
    :class:`ShardedStack`: layer i+1's 1/p weight gather is issued
    alongside layer i's cached attention (``scan_stack_cached``) — the
    decode-side incarnation of the §5 prefetch pipeline.
    """
    h = L.embed(params["embed"], token)
    length = state.length

    if isinstance(params.get("blocks"), ShardedStack):
        if cfg.family in _SCANNED_FAMILIES:
            xs = state.cache if state.enc_kv is None else \
                (state.cache, state.enc_kv)

            def body(h, lp, xrow):
                lc, ekv = (xrow, None) if state.enc_kv is None else xrow
                h, newc = _attn_cached(lp, h, cfg, lc, length,
                                       prefill=False, enc_kv=ekv)
                return h, newc
            h, newcache = scan_stack_cached(params["blocks"], h, xs, body)
        elif cfg.family == "ssm":
            def body(h, lp, lc):
                hn = _norm(cfg, lp["ln1"], h)
                out, st = S.mamba2_block(lp["mamba"], hn, cfg, state=lc)
                return h + out, st
            h, newcache = scan_stack_cached(params["blocks"], h,
                                            state.cache, body)
        else:
            raise ValueError(
                f"family {cfg.family!r} cannot serve from a ShardedStack "
                f"(the hybrid grouped attention cache does not fit the "
                f"flat layer scan); host it replicated")
    elif cfg.family in _SCANNED_FAMILIES:
        xs = (params["blocks"], state.cache) if state.enc_kv is None else \
             (params["blocks"], state.cache, state.enc_kv)

        def body(h, lpc):
            lp, lc = lpc[0], lpc[1]
            ekv = lpc[2] if len(lpc) == 3 else None
            h, newc = _attn_cached(lp, h, cfg, lc, length, prefill=False,
                                   enc_kv=ekv)
            return h, newc
        h, newcache = lax.scan(body, h, xs)
    elif cfg.family == "ssm":
        def body(h, lpc):
            lp, lc = lpc
            hn = _norm(cfg, lp["ln1"], h)
            out, st = S.mamba2_block(lp["mamba"], hn, cfg, state=lc)
            return h + out, st
        h, newcache = lax.scan(body, h, (params["blocks"], state.cache))
    elif cfg.family == "hybrid":
        h, newcache = _hybrid_cached(params, cfg, h, state.cache, length,
                                     prefill=False)
    else:
        raise ValueError(cfg.family)

    h = _norm(cfg, params["final_norm"], h)
    logits = L.unembed(params["embed"], h)
    new_state = ServeState(cache=newcache, length=length + 1,
                           enc_kv=state.enc_kv)
    return logits, new_state


def _hybrid_cached(params, cfg: ModelConfig, h, cache, length, *, prefill):
    groups, every, tail = _hybrid_split(cfg)
    shared = params["shared_attn"]
    head = _tree_first(params["blocks"], groups * every)
    head = jax.tree.map(lambda a: a.reshape(groups, every, *a.shape[1:]), head)
    mcache_head = _tree_first(cache["mamba"], groups * every)
    mcache_head = jax.tree.map(
        lambda a: a.reshape(groups, every, *a.shape[1:]), mcache_head)

    def mamba_body(h, lpc):
        lp, lc = lpc
        hn = _norm(cfg, lp["ln1"], h)
        out, st = S.mamba2_block(lp["mamba"], hn, cfg, state=lc)
        return h + out, st

    def group_body(h, gx):
        gp, gmc, gac = gx
        h, newac = _attn_cached_shared(shared, h, cfg, gac, length,
                                       prefill=prefill)
        h, newmc = lax.scan(mamba_body, h, (gp, gmc))
        return h, (newmc, newac)

    h, (new_mc_head, new_ac) = lax.scan(
        group_body, h, (head, mcache_head, cache["attn"]))
    new_mc_head = jax.tree.map(
        lambda a: a.reshape(groups * every, *a.shape[2:]), new_mc_head)
    if tail:
        tail_p = _tree_rest(params["blocks"], groups * every)
        tail_c = _tree_rest(cache["mamba"], groups * every)
        h, new_mc_tail = lax.scan(mamba_body, h, (tail_p, tail_c))
        new_mc = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                              new_mc_head, new_mc_tail)
    else:
        new_mc = new_mc_head
    return h, {"mamba": new_mc, "attn": new_ac}


def _attn_cached_shared(shared, h, cfg, lc, length, *, prefill):
    h, newc = _attn_cached(shared, h, cfg, lc, length, prefill=prefill)
    return h, newc


# ---------------------------------------------------------------------------
# step factories (pure; jit/sharding applied by the launch layer)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, *, remat: str = "none"):
    def step(params, tokens, labels, extra_embeds=None):
        return loss_fn(params, cfg, tokens, labels,
                       extra_embeds=extra_embeds, remat=remat)
    return step


def make_prefill_step(cfg: ModelConfig):
    def step(params, tokens, cache, extra_embeds=None):
        return prefill(params, cfg, tokens, cache, extra_embeds=extra_embeds)
    return step


def make_decode_step(cfg: ModelConfig):
    def step(params, token, state):
        return decode_step(params, cfg, token, state)
    return step
