"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Layout follows the reference Mamba2: in_proj emits [z | x | B | C | dt],
a short depthwise conv over (x|B|C), SSD mixing, gated RMSNorm, out_proj.

The SSD core is the *chunked dual form*: intra-chunk attention-like matmul
plus an inter-chunk state recurrence (a scan over T/Q states of size
H×P×S).  Training/prefill use `ssd_chunked` (or the Pallas kernel via
repro.kernels.ops); decode advances an explicit (conv_state, ssm_state)
pair in O(1) per token — this is what makes the long_500k cells feasible.

TP sharding: heads shard over the "model" axis (in_proj columns for z/x/dt
are head-major), B and C are group-shared (n_groups=1 ⇒ replicated — they
are tiny), out_proj is row-parallel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import dense_init, rmsnorm, _dtype


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig) -> dict:
    """Projections are kept as SEPARATE tensors (w_z/w_x/w_B/w_C/w_dt and
    per-stream convs) rather than one fused in_proj: the head-major streams
    (z, x, dt) then shard cleanly over the "model" axis while the tiny
    group-shared B/C streams stay replicated — a fused column layout would
    slice across component boundaries."""
    d, dt_ = cfg.d_model, _dtype(cfg)
    di, S, G, W = cfg.d_inner(), cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv_width
    H = cfg.ssm_heads()
    ks = jax.random.split(key, 9)
    p = {
        "w_z": dense_init(ks[0], (d, di), dt_),
        "w_x": dense_init(ks[1], (d, di), dt_),
        "w_B": dense_init(ks[2], (d, G * S), dt_),
        "w_C": dense_init(ks[3], (d, G * S), dt_),
        "w_dt": dense_init(ks[4], (d, H), dt_),
        "conv_x_w": dense_init(ks[5], (W, di), dt_, scale=0.5),
        "conv_x_b": jnp.zeros((di,), dt_),
        "conv_B_w": dense_init(ks[6], (W, G * S), dt_, scale=0.5),
        "conv_B_b": jnp.zeros((G * S,), dt_),
        "conv_C_w": dense_init(ks[7], (W, G * S), dt_, scale=0.5),
        "conv_C_b": jnp.zeros((G * S,), dt_),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[8], (H,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(jnp.float32),
        "norm": {"scale": jnp.ones((di,), dt_)},
        "out_proj": dense_init(ks[4], (di, d), dt_),
    }
    return p


# ---------------------------------------------------------------------------
# SSD chunked scan (pure jnp — also the oracle for the Pallas kernel)
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, B, C, *, chunk: int, init_state=None):
    """SSD dual-form mixing.

    x:  (b, T, H, P)   per-head values
    dt: (b, T, H)      positive step sizes (already softplus'd + biased)
    A:  (H,)           negative decay rates (= -exp(A_log))
    B, C: (b, T, G, S) input/output projections (G groups broadcast to H)
    Returns (y (b,T,H,P), final_state (b,H,P,S)).
    """
    b, T, H, P = x.shape
    G, S = B.shape[2], B.shape[3]
    Q = min(chunk, T)
    T0 = T
    if T % Q:                        # pad tail with dt=0 ⇒ state-neutral
        pad = Q - T % Q
        z = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = z(x), z(dt), z(B), z(C)
        T = T + pad
    nc = T // Q
    rep = H // G

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = jnp.repeat(B.astype(jnp.float32), rep, axis=2)   # (b,T,H,S)
    Cf = jnp.repeat(C.astype(jnp.float32), rep, axis=2)

    # chunked views
    xc = xf.reshape(b, nc, Q, H, P)
    dtc = dtf.reshape(b, nc, Q, H)
    Bc = Bf.reshape(b, nc, Q, H, S)
    Cc = Cf.reshape(b, nc, Q, H, S)

    da = dtc * A[None, None, None, :]                     # (b,nc,Q,H) ≤ 0
    cum = jnp.cumsum(da, axis=2)                          # within-chunk
    seg_end = cum[:, :, -1, :]                            # (b,nc,H)

    # ---- intra-chunk (attention-like, causal) ----
    # L[q1,q2] = exp(cum[q1]-cum[q2]) · (q1 ≥ q2)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcqhs,bckhs->bcqkh", Cc, Bc) * Lmat
    y_intra = jnp.einsum("bcqkh,bckh,bckhp->bcqhp", scores, dtc, xc)

    # ---- chunk summaries & inter-chunk recurrence ----
    decay_to_end = jnp.exp(seg_end[:, :, None, :] - cum)  # (b,nc,Q,H)
    chunk_state = jnp.einsum("bcqhs,bcqh,bcqh,bcqhp->bchps",
                             Bc, dtc, decay_to_end, xc)   # (b,nc,H,P,S)

    s0 = (jnp.zeros((b, H, P, S), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def chunk_step(state, inp):
        cs, g = inp                                       # (b,H,P,S), (b,H)
        prev = state
        state = prev * jnp.exp(g)[:, :, None, None] + cs
        return state, prev

    (final_state, prevs) = lax.scan(
        chunk_step, s0,
        (jnp.moveaxis(chunk_state, 1, 0), jnp.moveaxis(seg_end, 1, 0)))
    prev_states = jnp.moveaxis(prevs, 0, 1)               # (b,nc,H,P,S)

    y_inter = jnp.einsum("bcqhs,bchps->bcqhp",
                         Cc * jnp.exp(cum)[..., None], prev_states)

    y = (y_intra + y_inter).reshape(b, T, H, P)[:, :T0]
    return y.astype(x.dtype), final_state


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    """One-token SSD update.

    state: (b,H,P,S); x_t: (b,H,P); dt_t: (b,H); B_t/C_t: (b,G,S).
    Returns (y_t (b,H,P), new_state).
    """
    H = x_t.shape[1]
    G = B_t.shape[1]
    rep = H // G
    Bh = jnp.repeat(B_t.astype(jnp.float32), rep, axis=1)  # (b,H,S)
    Ch = jnp.repeat(C_t.astype(jnp.float32), rep, axis=1)
    da = dt_t.astype(jnp.float32) * A[None, :]             # (b,H)
    new_state = (state * jnp.exp(da)[:, :, None, None]
                 + jnp.einsum("bh,bhs,bhp->bhps", dt_t.astype(jnp.float32),
                              Bh, x_t.astype(jnp.float32)))
    y = jnp.einsum("bhs,bhps->bhp", Ch, new_state)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# full block
# ---------------------------------------------------------------------------

def _conv1d(xBC, w, b, conv_state=None):
    """Depthwise causal conv, width W.  xBC: (B,T,C); w: (W,C).

    If conv_state (B, W-1, C) is given, it prefixes the sequence
    (decode/prefill continuation) and the updated state is returned.
    """
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    full = jnp.concatenate([pad, xBC], axis=1)            # (B, T+W-1, C)
    out = sum(full[:, i:i + xBC.shape[1]] * w[i][None, None]
              for i in range(W))
    new_state = full[:, -(W - 1):] if W > 1 else pad
    return out + b[None, None], new_state


def mamba2_block(p: dict, x, cfg: ModelConfig, *, state=None):
    """x: (B, T, d) → (B, T, d).  state: None (train) or serve-state dict."""
    Bsz, T, _ = x.shape
    H, P = cfg.ssm_heads(), cfg.ssm_head_dim
    G, S = cfg.ssm_groups, cfg.ssm_state
    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bp = x @ p["w_B"]
    Cp = x @ p["w_C"]
    dt = x @ p["w_dt"]
    cs = state if state is not None else {}
    xs, new_cx = _conv1d(xs, p["conv_x_w"], p["conv_x_b"], cs.get("conv_x"))
    Bp, new_cB = _conv1d(Bp, p["conv_B_w"], p["conv_B_b"], cs.get("conv_B"))
    Cp, new_cC = _conv1d(Cp, p["conv_C_w"], p["conv_C_b"], cs.get("conv_C"))
    xs, Bp, Cp = jax.nn.silu(xs), jax.nn.silu(Bp), jax.nn.silu(Cp)
    xs = xs.reshape(Bsz, T, H, P)
    Bp = Bp.reshape(Bsz, T, G, S)
    Cp = Cp.reshape(Bsz, T, G, S)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])

    if state is None:
        y, _ = ssd_chunked(xs, dt, A, Bp, Cp, chunk=cfg.ssm_chunk)
        new_state = None
    elif T == 1:
        y1, new_ssm = ssd_decode_step(state["ssm"], xs[:, 0], dt[:, 0],
                                      A, Bp[:, 0], Cp[:, 0])
        y = y1[:, None]
        new_state = {"conv_x": new_cx, "conv_B": new_cB, "conv_C": new_cC,
                     "ssm": new_ssm}
    else:  # prefill with state capture
        y, new_ssm = ssd_chunked(xs, dt, A, Bp, Cp, chunk=cfg.ssm_chunk,
                                 init_state=state["ssm"])
        new_state = {"conv_x": new_cx, "conv_B": new_cB, "conv_C": new_cC,
                     "ssm": new_ssm}

    y = y + xs * p["D"][None, None, :, None]          # fp32 D promotes…
    y = y.reshape(Bsz, T, cfg.d_inner()).astype(x.dtype)  # …cast back
    y = rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return y @ p["out_proj"], new_state


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    di, S, G, W = cfg.d_inner(), cfg.ssm_state, cfg.ssm_groups, cfg.ssm_conv_width
    H, P = cfg.ssm_heads(), cfg.ssm_head_dim
    return {
        "conv_x": jnp.zeros((batch, W - 1, di), dtype),
        "conv_B": jnp.zeros((batch, W - 1, G * S), dtype),
        "conv_C": jnp.zeros((batch, W - 1, G * S), dtype),
        "ssm": jnp.zeros((batch, H, P, S), jnp.float32),
    }
