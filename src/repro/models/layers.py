"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Pure-function style: ``init_*`` returns a params dict, ``apply`` functions
are stateless.  Everything is jnp-only so it works under ``jax.eval_shape``
(the dry-run path never allocates real parameters).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# activation-sharding hints (set by the launch layer, honored model-wide):
# batch axes pin the leading dim; d_axis optionally shards a trailing
# feature dim between layers (Megatron-SP-along-d — shrinks saved-for-
# backward stacks by the TP degree).
# ---------------------------------------------------------------------------
_ACT_BATCH: contextvars.ContextVar = contextvars.ContextVar(
    "act_batch_axes", default=None)
_ACT_DMODEL: contextvars.ContextVar = contextvars.ContextVar(
    "act_d_axis", default=None)
_ACT_KV: contextvars.ContextVar = contextvars.ContextVar(
    "act_kv_spec", default=None)      # (batch_entry, seq_entry) for caches


@contextlib.contextmanager
def activation_batch_axes(axes, d_axis=None, kv=None):
    tok = _ACT_BATCH.set(tuple(axes) if axes else None)
    tok2 = _ACT_DMODEL.set(d_axis)
    tok3 = _ACT_KV.set(kv)
    try:
        yield
    finally:
        _ACT_BATCH.reset(tok)
        _ACT_DMODEL.reset(tok2)
        _ACT_KV.reset(tok3)


def pin_kv(arr):
    """Pin a (B, S, K, hd) cache-shaped tensor to the serve-cache layout.

    The one-hot cache update and the prefill DUS otherwise produce full-
    cache-sized intermediates sharded on batch only — 16× the per-chip
    bytes of the (batch × seq-over-model) cache layout (verified: qwen
    prefill_32k at 55 GB temp without this pin)."""
    spec = _ACT_KV.get()
    if spec is None or arr is None:
        return arr
    b, s = spec
    return jax.lax.with_sharding_constraint(
        arr, PartitionSpec(b, s, *([None] * (arr.ndim - 2))))


def pin_act(x, *, shard_last: bool = False):
    """Constrain x to (batch_axes, None…, [d_axis]) if hints are active."""
    axes = _ACT_BATCH.get()
    if axes is None or x is None:
        return x
    d_axis = _ACT_DMODEL.get() if shard_last else None
    if x.ndim == 1:
        spec = PartitionSpec(axes)
    else:
        spec = PartitionSpec(axes, *([None] * (x.ndim - 2)), d_axis)
    return jax.lax.with_sharding_constraint(x, spec)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2, 2, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(p: dict, x, eps: float):
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 → (..., head_dim/2) angles, fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, D) with D even; positions: (B, T) or (T,)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)           # (B?, T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    while cos.ndim < x.ndim:                          # broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), dt),
         "w_down": dense_init(ks[1], (f, d), dt)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], (d, f), dt)
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def mlp(p: dict, x, cfg: ModelConfig):
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = _act(cfg.act)(x @ p["w_gate"]) * h
    else:
        h = _act(cfg.act)(h)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab_size, cfg.d_model), dt, scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed(p: dict, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, x):
    if "head" in p:
        return x @ p["head"]
    return x @ p["tok"].T
