"""Shared building blocks: norms, rotary embeddings, MLPs, embeddings.

Pure-function style: ``init_*`` returns a params dict, ``apply`` functions
are stateless.  Everything is jnp-only so it works under ``jax.eval_shape``
(the dry-run path never allocates real parameters).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# activation-sharding hints (set by the launch layer, honored model-wide):
# batch axes pin the leading dim; d_axis optionally shards a trailing
# feature dim between layers (Megatron-SP-along-d — shrinks saved-for-
# backward stacks by the TP degree).
# ---------------------------------------------------------------------------
_ACT_BATCH: contextvars.ContextVar = contextvars.ContextVar(
    "act_batch_axes", default=None)
_ACT_DMODEL: contextvars.ContextVar = contextvars.ContextVar(
    "act_d_axis", default=None)
_ACT_KV: contextvars.ContextVar = contextvars.ContextVar(
    "act_kv_spec", default=None)      # (batch_entry, seq_entry) for caches


@contextlib.contextmanager
def activation_batch_axes(axes, d_axis=None, kv=None):
    tok = _ACT_BATCH.set(tuple(axes) if axes else None)
    tok2 = _ACT_DMODEL.set(d_axis)
    tok3 = _ACT_KV.set(kv)
    try:
        yield
    finally:
        _ACT_BATCH.reset(tok)
        _ACT_DMODEL.reset(tok2)
        _ACT_KV.reset(tok3)


def pin_kv(arr):
    """Pin a (B, S, K, hd) cache-shaped tensor to the serve-cache layout.

    The one-hot cache update and the prefill DUS otherwise produce full-
    cache-sized intermediates sharded on batch only — 16× the per-chip
    bytes of the (batch × seq-over-model) cache layout (verified: qwen
    prefill_32k at 55 GB temp without this pin)."""
    spec = _ACT_KV.get()
    if spec is None or arr is None:
        return arr
    b, s = spec
    return jax.lax.with_sharding_constraint(
        arr, PartitionSpec(b, s, *([None] * (arr.ndim - 2))))


def pin_act(x, *, shard_last: bool = False):
    """Constrain x to (batch_axes, None…, [d_axis]) if hints are active."""
    axes = _ACT_BATCH.get()
    if axes is None or x is None:
        return x
    d_axis = _ACT_DMODEL.get() if shard_last else None
    if x.ndim == 1:
        spec = PartitionSpec(axes)
    else:
        spec = PartitionSpec(axes, *([None] * (x.ndim - 2)), d_axis)
    return jax.lax.with_sharding_constraint(x, spec)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, shape, dtype, scale: float = 0.02):
    return (scale * jax.random.truncated_normal(key, -2, 2, shape)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x, eps: float):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(p: dict, x, eps: float):
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions: (...,) int32 → (..., head_dim/2) angles, fp32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float):
    """x: (B, T, H, D) with D even; positions: (B, T) or (T,)."""
    d = x.shape[-1]
    ang = rope_angles(positions, d, theta)           # (B?, T, D/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    while cos.ndim < x.ndim:                          # broadcast over heads
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU or plain)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, _dtype(cfg)
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d, f), dt),
         "w_down": dense_init(ks[1], (f, d), dt)}
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[2], (d, f), dt)
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def mlp(p: dict, x, cfg: ModelConfig):
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = _act(cfg.act)(x @ p["w_gate"]) * h
    else:
        h = _act(cfg.act)(h)
    return h @ p["w_down"]


def _allgather_last(comm, x, strategy=None):
    """All-gather the LAST axis over ``comm`` in global-rank order.

    The §3 mock-ups concatenate along the leading dim, so the feature
    axis is moved to the front for the wire and moved back after —
    global rank == model rank on a model-only topology, so the
    concatenation order matches the column-slice order exactly."""
    t = jnp.moveaxis(x, -1, 0)
    g = comm.allgather(t, strategy=strategy)
    return jnp.moveaxis(g, 0, -1)


def _tp_cols(comm, w, width: int, axis: int = 1):
    """This model rank's ``width``-column block of ``w`` along ``axis``."""
    r = comm.topo.global_rank()
    return jax.lax.dynamic_slice_in_dim(w, r * width, width, axis=axis)


def mlp_tp(p: dict, x, cfg: ModelConfig, *, comm, strategy=None):
    """Tensor-parallel MLP, bit-identical to :func:`mlp` — forward AND
    per-rank gradients.

    ALL matmuls are column-parallel: each model rank computes its f/tp
    (then d/tp) output columns and an allgather over the model axis
    reassembles the full activation — pure concatenation, so every
    element is produced by exactly the same dot products as the
    replicated path (the bit-identity the TP==replicated pin asserts).

    The backward is a custom VJP (see :func:`_mlp_tp_bwd`) rather than
    plain AD: transposing the forward allgathers would hand each rank a
    tp-scaled PARTIAL cotangent (every rank's replicated loss copy
    contributes through the collective transpose), which poisons every
    upstream gradient's bit-identity.  The custom rule instead computes
    column blocks of exactly the replicated backward's einsums and
    allgathers the input cotangent full, so non-MLP grads stay bitwise
    replicated over the model axis and the zero-padded MLP weight-grad
    blocks assemble EXACTLY under one model-axis psum (adding zeros is
    exact).
    """
    tp = comm.topo.p()
    f, d = cfg.d_ff, cfg.d_model
    if f % tp or d % tp:
        raise ValueError(
            f"tensor-parallel degree {tp} must divide d_ff={f} and "
            f"d_model={d}")
    return _mlp_tp(cfg.act, comm, strategy, x, p["w_up"],
                   p.get("w_gate"), p["w_down"])


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _mlp_tp(act, comm, strategy, x, w_up, w_gate, w_down):
    y, _ = _mlp_tp_parts(act, comm, strategy, x, w_up, w_gate, w_down)
    return y


def _mlp_tp_parts(act, comm, strategy, x, w_up, w_gate, w_down):
    tp = comm.topo.p()
    f, d = w_up.shape[1], w_down.shape[1]
    h = x @ _tp_cols(comm, w_up, f // tp)
    if w_gate is not None:
        h = _act(act)(x @ _tp_cols(comm, w_gate, f // tp)) * h
    else:
        h = _act(act)(h)
    a = _allgather_last(comm, h, strategy)               # (.., f) full
    y = a @ _tp_cols(comm, w_down, d // tp)
    return _allgather_last(comm, y, strategy), a         # (.., d) full


def _mlp_tp_fwd(act, comm, strategy, x, w_up, w_gate, w_down):
    y, a = _mlp_tp_parts(act, comm, strategy, x, w_up, w_gate, w_down)
    return y, (x, a, w_up, w_gate, w_down)


def _mlp_tp_bwd(act, comm, strategy, res, dy):
    """Column blocks of the replicated backward, assembled by gathers.

    Every einsum below is a contiguous output slice of the corresponding
    replicated-AD einsum with identical contraction dims, so each block
    is bitwise equal to its slice of the replicated gradient; the input
    cotangent dx is allgathered back to full so everything upstream of
    the MLP sees exactly the replicated cotangent.
    """
    x, a, w_up, w_gate, w_down = res
    tp = comm.topo.p()
    f, d = w_up.shape[1], w_down.shape[1]
    fl, dl = f // tp, d // tp
    r = comm.topo.global_rank()

    # dh slice: replicated dh = dy @ w_down.T; rows fl of w_down give cols
    dh_loc = dy @ jax.lax.dynamic_slice_in_dim(w_down, r * fl, fl, 0).T
    h1_loc = x @ _tp_cols(comm, w_up, fl)
    if w_gate is None:
        _, evjp = jax.vjp(_act(act), h1_loc)
        (dh1_loc,) = evjp(dh_loc)
        dhg_loc = None
    else:
        hg_loc = x @ _tp_cols(comm, w_gate, fl)
        _, evjp = jax.vjp(lambda u, g: _act(act)(g) * u, h1_loc, hg_loc)
        dh1_loc, dhg_loc = evjp(dh_loc)

    bt = x.reshape(-1, x.shape[-1])                      # (B·T, d)
    def _wgrad(u, v, shape, col, width):
        blk = u.T @ v.reshape(-1, v.shape[-1])           # (in, width)
        return jax.lax.dynamic_update_slice(
            jnp.zeros(shape, blk.dtype), blk, (0, col))

    dw_up = _wgrad(bt, dh1_loc, (x.shape[-1], f), r * fl, fl)
    dw_gate = None if w_gate is None else \
        _wgrad(bt, dhg_loc, (x.shape[-1], f), r * fl, fl)
    dy_loc = jax.lax.dynamic_slice_in_dim(dy, r * dl, dl, dy.ndim - 1)
    dw_down = _wgrad(a.reshape(-1, f), dy_loc, (f, d), r * dl, dl)

    # full f-cotangents (exact concatenation of exact slices), then the
    # d-column block of dx and a final gather back to full
    dh1 = _allgather_last(comm, dh1_loc, strategy)
    up_rows = jax.lax.dynamic_slice_in_dim(w_up, r * dl, dl, 0)
    dx_loc = dh1 @ up_rows.T
    if w_gate is not None:
        dhg = _allgather_last(comm, dhg_loc, strategy)
        gate_rows = jax.lax.dynamic_slice_in_dim(w_gate, r * dl, dl, 0)
        dx_loc = dx_loc + dhg @ gate_rows.T
    dx = _allgather_last(comm, dx_loc, strategy)
    return dx, dw_up, dw_gate, dw_down


_mlp_tp.defvjp(_mlp_tp_fwd, _mlp_tp_bwd)


def mlp_tp_reduce(p: dict, x, cfg: ModelConfig, *, comm, strategy=None):
    """Megatron-style TP MLP: column-parallel up/gate, ROW-parallel down,
    one allreduce over the model axis on the output.

    Halves the activation traffic of :func:`mlp_tp` (no intermediate
    f-gather) but sums partial products across ranks, so it is equal to
    :func:`mlp` only to rounding — pinned allclose, never bit-identical.
    """
    tp = comm.topo.p()
    f = cfg.d_ff
    if f % tp:
        raise ValueError(
            f"tensor-parallel degree {tp} must divide d_ff={f}")
    fl = f // tp
    h = x @ _tp_cols(comm, p["w_up"], fl)
    if "w_gate" in p:
        h = _act(cfg.act)(x @ _tp_cols(comm, p["w_gate"], fl)) * h
    else:
        h = _act(cfg.act)(h)
    r = comm.topo.global_rank()
    down = jax.lax.dynamic_slice_in_dim(p["w_down"], r * fl, fl, axis=0)
    return comm.allreduce(h @ down, strategy=strategy)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {"tok": dense_init(k1, (cfg.vocab_size, cfg.d_model), dt, scale=1.0)}
    if not cfg.tie_embeddings:
        p["head"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), dt)
    return p


def embed(p: dict, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: dict, x):
    if "head" in p:
        return x @ p["head"]
    return x @ p["tok"].T
