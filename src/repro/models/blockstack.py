"""Family-agnostic ZeRO-3 sharded layer stack (the §5 recipe as a runtime).

The paper's §5 construction — pipeline a one-ported tree algorithm over a
payload split into blocks, lane level and node level structurally
concurrent — says nothing about what the payload *is*.  The first ZeRO-3
port nevertheless welded the machinery into ``models/transformer.py``
(``ShardedBlocks`` + ``_scan_blocks_prefetch``), so only the scanned
attention families could train with 1/p-sharded parameters; Mamba2,
hybrid and MoE configs silently fell back to replicated weights.

This module extracts the machinery into family-agnostic pieces:

  ``StackLayout``     the bucket-major 1/p flat layout of ONE stack of
                      parameters (the layer stack, or the embeddings/
                      final-norm "extras" treated as a single additional
                      layer) — flatten/unflatten, master-array shaping,
                      per-element decay mask.
  ``ShardedStack``    the traced stand-in for a sharded stack inside a
                      loss function: per-layer shard rows plus the gather
                      recipe; differentiable (the all-gather's AD
                      transpose IS the lane_zero3 reduce-scatter).
  ``scan_stack``      the layer scan: one-layer prefetch buffer (layer
                      i+1's gather structurally concurrent with layer i's
                      compute), a blocking negative control, and the
                      backward re-gather mode (the gather re-runs inside
                      a ``jax.checkpoint`` cell, so backward residuals
                      stay 1/p instead of L·D per chip).
  ``BlockSpec``       what a model family must declare to ride the stack:
                      which top-level param key is the scanned stack,
                      which keys stay replicated (the Zamba2 weight-shared
                      attention block), and how to build the per-layer
                      scan body.

Family specs register through the existing :mod:`repro.comm` registry
seam — ``@register_block_stack("ssm")`` is sugar for
``register_impl("block_stack", "ssm", ...)`` — so the set of lane-capable
families is one more derived table: the train-smoke sweep, the per-family
benchmark rows and the bench schema check all enumerate
``block_stack_families()`` instead of a hard-coded tuple.  The concrete
specs live in :mod:`repro.models.transformer` (the assembly layer that
owns the block bodies); the zero3 train step resolves them via
:func:`block_stack_spec`.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.comm.registry import get_impl, has_impl, register_impl, \
    strategies_for
from repro.core.costmodel import optimal_prefetch_blocks

__all__ = [
    "ShardedStack", "ShardedBlocks", "scan_stack", "scan_stack_cached",
    "StackLayout",
    "stack_layout", "shard_stack", "resolve_prefetch_blocks",
    "resolve_extras_prefetch_blocks", "BlockSpec",
    "register_block_stack", "block_stack_spec", "block_stack_families",
    "family_smoke_archs", "split_params",
]


# ---------------------------------------------------------------------------
# the traced stand-in + the prefetch scan
# ---------------------------------------------------------------------------

class ShardedStack:
    """Stand-in for a stacked parameter subtree when the stack is ZeRO-3
    sharded: each chip holds its 1/p stripe of every layer's flat weight
    vector plus the recipe to re-gather one layer on demand.

    shards   (L, B·s)-reshapeable array — this chip's per-layer stripe in
             the bucket-major ``zero3_param_shard`` layout.  Differentiable
             through the gather: the cotangent arriving on ``shards`` is
             the batch-summed, fully reduce-scattered layer gradient (the
             all-gather's transpose IS the lane_zero3 reduce-scatter).
    gather   shard row -> one layer's parameter tree (built by
             launch/steps.py around ``comm.prefetch_allgather`` + a
             ``StackLayout``).
    prefetch True: the layer scan carries a one-layer prefetch buffer —
             layer i+1's all-gather is issued in the same scan step as
             layer i's compute with no data dependence between them, so
             XLA may overlap gather and matmuls (verified structurally by
             ``launch.hlo_stats.collective_compute_concurrency``).
             False: blocking gather — each layer's compute consumes its
             own all-gather (the negative control).
    regather True: backward re-gather — each layer's gather runs INSIDE a
             ``jax.checkpoint`` cell together with the layer's compute, so
             the scan's backward residuals keep only (activations, 1/p
             shard row) per layer and the backward RE-RUNS the all-gather
             (the standard FSDP trick; pinned by an hlo_stats count —
             the backward HLO contains its own all-gathers).  Trades the
             forward's structural prefetch for 1/p backward memory:
             forward 1/p + 1 layer, backward 1/p + 1 layer.

    Not a pytree on purpose: it only ever exists *inside* a traced loss
    function (steps.py closes over gather and passes the shard array as
    the differentiated argument), so it must never cross a jit/grad
    boundary itself.
    """

    def __init__(self, shards, gather, *, prefetch: bool = True,
                 regather: bool = False):
        if regather and not prefetch:
            # the blocking mode exists as the prefetch proof's negative
            # control; silently lowering it as a remat'd re-gather scan
            # would invalidate the control measurement
            raise ValueError(
                "regather=True is incompatible with prefetch=False (the "
                "blocking negative control); drop one of the two")
        self.shards = shards
        self.gather = gather
        self.prefetch = prefetch
        self.regather = regather


# the name the first ZeRO-3 port exported; same class, kept importable
ShardedBlocks = ShardedStack


def scan_stack(stack: ShardedStack, h, body):
    """Layer scan over ZeRO-3 shards with a one-layer prefetch buffer.

    ``body(h, layer_params, layer_idx) -> (h', aux)`` is the ordinary
    (possibly remat'd) block body; ``layer_idx`` is the traced scan index
    (the hybrid family conditions its weight-shared attention on it,
    everyone else ignores it), ``aux`` a scalar.  Returns
    ``(h, aux_ys (L,))``.

    Prefetch mode: the carry holds the *gathered* params of the layer
    about to run — step t gathers layer t+1's weights from its shard row
    while computing layer t from the carry; within a step the all-gather
    and the dots touch disjoint values, which is exactly the structural
    concurrency the §5 pipeline needs.  The scan covers layers 0..L-2
    (xs = shard rows 1..L-1); layer L-1 runs OUTSIDE the loop on the
    final carry, so exactly L gathers execute per forward — a wrapped xs
    would re-gather layer 0 on the last trip, and XLA cannot drop work
    from a single iteration of a while loop.

    Regather mode: the gather moves inside a ``jax.checkpoint`` cell with
    the body, so each layer is re-gathered in the backward (see
    :class:`ShardedStack`).  Blocking mode: each layer's compute consumes
    its own gather (the prefetch proof's negative control).
    """
    shards, gather = stack.shards, stack.gather
    L = shards.shape[0]
    idxs = jnp.arange(L)

    if stack.regather:
        # residuals per step: (h, shard row) — the gathered weights are
        # recomputed (re-gathered) by the checkpoint cell in the backward
        cell = jax.checkpoint(lambda hh, x, i: body(hh, gather(x), i))

        def step_regather(hh, xi):
            x, i = xi
            return cell(hh, x, i)
        return lax.scan(step_regather, h, (shards, idxs))

    if not stack.prefetch:
        # blocking: layer t's dots are data-dependent on layer t's gather
        def step_blocking(hh, xi):
            x, i = xi
            return body(hh, gather(x), i)
        return lax.scan(step_blocking, h, (shards, idxs))

    w0 = gather(shards[0])                  # layer 0: unavoidably blocking
    if L == 1:
        h, a = body(h, w0, idxs[0])
        return h, jnp.asarray(a)[None]

    def step(carry, xi):
        hh, w = carry
        x_next, i = xi
        w_next = gather(x_next)             # prefetch layer i+1 (no dep on w)
        hh, a = body(hh, w, i)              # compute layer i
        return (hh, w_next), a

    (h, w_last), aux_ys = lax.scan(step, (h, w0), (shards[1:], idxs[:-1]))
    h, a_last = body(h, w_last, idxs[-1])   # layer L-1: already gathered
    return h, jnp.concatenate([jnp.atleast_1d(aux_ys),
                               jnp.asarray(a_last)[None]])


def scan_stack_cached(stack: ShardedStack, h, xs, body):
    """The serving-side layer scan: :func:`scan_stack` with per-layer
    scanned INPUTS and stacked OUTPUTS (the KV/SSM cache rows).

    ``body(h, layer_params, xs_row) -> (h', ys_row)`` where ``xs`` and
    the returned ``ys`` are pytrees whose every leaf has a leading
    stack dim L (``xs_row``/``ys_row`` are single rows of them) — the
    cached prefill/decode bodies thread (cache_in -> cache_out), and the
    audio prefill additionally emits the per-layer cross-attention K/V.
    No aux scalars, no layer index, no regather (inference has no
    backward): just the same one-layer prefetch structure — layer i+1's
    all-gather issued alongside layer i's compute, layer L-1 outside the
    loop so exactly L gathers run.  Returns ``(h, ys)``.
    """
    shards, gather = stack.shards, stack.gather
    L = shards.shape[0]

    if not stack.prefetch:
        def step_blocking(hh, sx):
            srow, xrow = sx
            return body(hh, gather(srow), xrow)
        return lax.scan(step_blocking, h, (shards, xs))

    row = lambda t, i: jax.tree.map(lambda a: a[i], t)
    w0 = gather(shards[0])                  # layer 0: unavoidably blocking
    if L == 1:
        h, y = body(h, w0, row(xs, 0))
        return h, jax.tree.map(lambda a: a[None], y)

    def step(carry, sx):
        hh, w = carry
        s_next, xrow = sx
        w_next = gather(s_next)             # prefetch layer i+1 (no dep on w)
        hh, y = body(hh, w, xrow)           # compute layer i
        return (hh, w_next), y

    xs_head = jax.tree.map(lambda a: a[:-1], xs)
    (h, w_last), ys = lax.scan(step, (h, w0), (shards[1:], xs_head))
    h, y_last = body(h, w_last, row(xs, L - 1))  # layer L-1: gathered
    ys = jax.tree.map(lambda a, b: jnp.concatenate([a, b[None]], axis=0),
                      ys, y_last)
    return h, ys


# ---------------------------------------------------------------------------
# the bucket-major 1/p flat layout of one stack
# ---------------------------------------------------------------------------

class StackLayout:
    """Flat layout of ONE stack of parameters: ``length`` rows (layers),
    each the concatenation of its leaves' flat elements in tree order.

    ``stacked=True`` trees have a leading stack dim on every leaf (the
    scanned layer stack: metas are ``shape[1:]``); ``stacked=False``
    trees are a single pseudo-layer (the embeddings/final-norm "extras"
    stack: metas are the full shapes, length 1).  ``decay`` records, per
    leaf, whether ``adamw_update`` would weight-decay it (original
    ndim >= 2) — the flat per-element decay mask derives from it.

    Derived via ``eval_shape``-compatible access (only ``.shape``/
    ``.dtype``/``.ndim`` of the leaves are read), so building a layout
    never materializes weights.
    """

    def __init__(self, metas, decay, treedef, row_elems: int, length: int,
                 stacked: bool):
        self.metas = metas              # ((row shape, dtype) per leaf)
        self.decay = decay              # (bool per leaf)
        self.treedef = treedef
        self.row_elems = row_elems      # D: unpadded flat size per row
        self.length = length            # L: rows in the stack
        self.stacked = stacked

    # names the first ZeRO-3 port used (Zero3LayerSpec compatibility)
    @property
    def layer_elems(self) -> int:
        return self.row_elems

    @property
    def num_layers(self) -> int:
        return self.length

    def unflatten_row(self, vec):
        """Padded flat fp32 row vector -> one row's parameter tree (leaves
        cast back to their stored dtypes)."""
        out, ofs = [], 0
        for shape, dtype in self.metas:
            sz = math.prod(shape)
            out.append(vec[ofs:ofs + sz].reshape(shape).astype(dtype))
            ofs += sz
        return jax.tree.unflatten(self.treedef, out)

    def flatten(self, tree, pad_to: int = 1):
        """The (L, D_pad) fp32 row matrix of ``tree`` (row-major per-leaf
        concatenation, zero-padded so D_pad % pad_to == 0)."""
        leaves = jax.tree.leaves(tree)
        L = self.length
        if self.stacked:
            flat = jnp.concatenate(
                [l.reshape(L, -1).astype(jnp.float32) for l in leaves],
                axis=1)
        else:
            flat = jnp.concatenate(
                [l.reshape(-1).astype(jnp.float32) for l in leaves])[None]
        pad = (-flat.shape[1]) % pad_to
        if pad:
            flat = jnp.concatenate(
                [flat, jnp.zeros((L, pad), flat.dtype)], axis=1)
        return flat

    def unflatten(self, mat, dtype=None):
        """Inverse of :meth:`flatten` (host- or device-side): (L, >=D)
        row matrix -> the stacked tree, leaf dtypes restored (``dtype``
        overrides them — moment trees stay fp32)."""
        out, ofs = [], 0
        for shape, leaf_dtype in self.metas:
            sz = math.prod(shape)
            cols = mat[:, ofs:ofs + sz]
            if self.stacked:
                cols = cols.reshape(self.length, *shape)
            else:
                cols = cols.reshape(shape)
            out.append(cols.astype(dtype if dtype is not None
                                   else leaf_dtype))
            ofs += sz
        return jax.tree.unflatten(self.treedef, out)

    def decay_mask(self, pad_to: int):
        """Per-element 0/1 fp32 mask over ONE flat row, padded to
        ``pad_to`` — 1 exactly where ``adamw_update`` decays (leaves of
        original ndim >= 2); padding is 0 (never decayed)."""
        parts = [jnp.full((math.prod(s),), 1.0 if d else 0.0, jnp.float32)
                 for (s, _), d in zip(self.metas, self.decay)]
        m = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)
        pad = pad_to - m.shape[0]
        if pad:
            m = jnp.concatenate([m, jnp.zeros((pad,), jnp.float32)])
        return m


def stack_layout(tree, *, stacked: bool = True) -> StackLayout:
    """Derive the :class:`StackLayout` of ``tree`` (abstract leaves OK)."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build a StackLayout over an empty tree")
    if stacked:
        metas = tuple((tuple(l.shape[1:]), l.dtype) for l in leaves)
        length = leaves[0].shape[0]
        for l in leaves:
            if l.shape[0] != length:
                raise ValueError(
                    f"stacked leaves disagree on the stack length: "
                    f"{l.shape[0]} vs {length}")
    else:
        metas = tuple((tuple(l.shape), l.dtype) for l in leaves)
        length = 1
    decay = tuple(l.ndim >= 2 for l in leaves)
    elems = sum(math.prod(s) for s, _ in metas)
    return StackLayout(metas, decay, treedef, elems, length, stacked)


def resolve_prefetch_blocks(row_elems: int, n: int, N: int,
                            override: int = 0) -> int:
    """The B every lane_zero3 call site uses (shard layout, opt-state
    size, per-layer gather pipeline).  override > 0 wins; -1 (blocking
    negative control) gathers monolithically so B degenerates to 1;
    otherwise the cost model picks B from the DCN latency/bandwidth
    crossover on the per-chip stripe.  Capped so each block keeps at
    least one row per chip."""
    p = max(n * N, 1)
    if override > 0:
        b = override
    elif override < 0:
        b = 1
    else:
        b = optimal_prefetch_blocks(row_elems * 4 / p)
    return max(1, min(b, max(1, row_elems // p)))


def resolve_extras_prefetch_blocks(row_elems: int, n: int, N: int,
                                   override: int = 0) -> int:
    """Block count for the EXTRAS pseudo-layer (embed/head/norm tree).

    The extras row is not one more layer: with a real vocab its vocab·d
    embedding makes the row's gather payload dwarf a block row, so a
    positive ``--fsdp-prefetch`` override hand-tuned for the layer
    stack must NOT be inherited here — a B sized for a ~12·d² row
    starves the much larger extras gather of pipeline depth (and a B
    sized for extras over-splits the layers).  Only the blocking
    negative control (-1) passes through; any other override defers to
    the cost model on the extras row's OWN per-chip stripe.
    """
    return resolve_prefetch_blocks(row_elems, n, N,
                                   -1 if override < 0 else 0)


def shard_stack(tree, n: int, N: int, fsdp_prefetch: int = 0, *,
                stacked: bool = True):
    """Host-side: the (L, B, n·N, s) fp32 master layout of one stack.
    Place on the mesh with ``P(None, None, (*node_axes, lane_axis),
    None)`` and each chip's local block reshapes to the (L, B·s) shard
    the train step expects.  Returns (array, B).

    ``stacked=False`` is the extras pseudo-layer: its B resolves from
    its own row payload (:func:`resolve_extras_prefetch_blocks`), never
    from a positive override tuned for the layer stack."""
    layout = stack_layout(tree, stacked=stacked)
    resolve = resolve_prefetch_blocks if stacked \
        else resolve_extras_prefetch_blocks
    B = resolve(layout.row_elems, n, N, fsdp_prefetch)
    p = max(n * N, 1)
    flat = layout.flatten(tree, pad_to=B * p)
    s = flat.shape[1] // (B * p)
    return flat.reshape(layout.length, B, p, s), B


# ---------------------------------------------------------------------------
# per-family block specs (registered through the repro.comm registry seam)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """What one model family declares to train through the sharded stack.

    stack_key        top-level params key of the scanned (L, ...) stack.
    replicated_keys  top-level keys that stay replicated on every chip
                     (the Zamba2 weight-shared attention block: it is
                     applied ``groups`` times per forward, so sharding it
                     would re-gather the same weights repeatedly); their
                     gradients sync through the bucketed ``lane`` path.
                     Every OTHER key (embed, final_norm, vis_proj,
                     encoder, ...) becomes the "extras" pseudo-layer:
                     1/p-sharded like one more stack row, gathered once
                     per step.
    make_body        ``make_body(cfg, params, *, positions, enc_out,
                     remat) -> body(h, layer_params, layer_idx) ->
                     (h', aux)`` — the per-layer scan body
                     :func:`scan_stack` drives (``params`` carries the
                     replicated/extras trees the body may close over,
                     e.g. the hybrid shared block).
    needs_extra_embeds
                     the family's forward requires an extra_embeds input
                     (vlm patches / audio frames) the training driver
                     does not synthesize — such families are excluded
                     from driver-level sweeps but still covered by the
                     layout/gather conformance grid.
    """
    family: str
    make_body: Callable
    stack_key: str = "blocks"
    replicated_keys: tuple = ()
    needs_extra_embeds: bool = False


def register_block_stack(family: str, **kw):
    """Sugar for ``register_impl("block_stack", family, auto_ok=False)``
    on a zero-arg-or-cfg spec factory ``fn(cfg) -> BlockSpec``."""
    return register_impl("block_stack", family, auto_ok=False, **kw)


def block_stack_spec(cfg) -> BlockSpec:
    """The registered :class:`BlockSpec` for ``cfg.family`` (imports the
    model assembly module so its registrations ran)."""
    import repro.models.transformer  # noqa: F401 - registers the specs
    if not has_impl("block_stack", cfg.family):
        raise ValueError(
            f"model family {cfg.family!r} has no registered block_stack "
            f"spec, so it cannot train through the lane_zero3 sharded "
            f"stack; registered families: {block_stack_families()}")
    return get_impl("block_stack", cfg.family).fn(cfg)


def block_stack_families() -> tuple:
    """Every lane-capable family, in registration order (the derived
    table the train-smoke sweep and the bench schema check enumerate)."""
    import repro.models.transformer  # noqa: F401 - registers the specs
    return strategies_for("block_stack")


# stable per-family smoke-arch preference: keeps the train-smoke sweep
# and the bench family_results "arch" column comparable across PRs even
# as new archs register (a family absent here falls back to the
# smallest-by-params smoke arch of that family)
_PREFERRED_SMOKE_ARCHS = {
    "dense": "llama3.2-3b",
    "moe": "granite-moe-3b-a800m",
    "ssm": "mamba2-780m",
    "hybrid": "zamba2-7b",
    "vlm": "llava-next-mistral-7b",
    "audio": "whisper-large-v3",
}


def family_smoke_archs(*, driver_trainable_only: bool = False) -> dict:
    """family -> smoke arch id.  The FAMILY list derives from the
    block-stack registry ("the registry IS the requirement": a family
    registration without a runnable model fails loudly); the arch per
    family follows ``_PREFERRED_SMOKE_ARCHS`` when valid — pinned so the
    bench trajectory's arch column stays comparable across PRs — and
    otherwise falls back to the family's smallest-by-params smoke arch.
    ``driver_trainable_only`` drops families whose BlockSpec declares
    ``needs_extra_embeds`` (the training driver cannot synthesize
    vlm patches / audio frames)."""
    from repro.configs import all_archs, resolve
    by_family: dict = {}
    for arch in all_archs():
        cfg = resolve(arch, smoke=True)
        cur = by_family.get(cfg.family)
        if cur is None or cfg.param_count() < cur[1]:
            by_family[cfg.family] = (arch, cfg.param_count())
    missing = [f for f in block_stack_families() if f not in by_family]
    if missing:
        raise ValueError(
            f"block_stack families with no registered arch: {missing}")
    registered = set(all_archs())
    out = {}
    for fam in block_stack_families():
        arch = _PREFERRED_SMOKE_ARCHS.get(fam)
        if arch not in registered:
            arch = by_family[fam][0]
        cfg = resolve(arch, smoke=True)
        if cfg.family != fam:
            raise ValueError(
                f"preferred smoke arch {arch!r} is family "
                f"{cfg.family!r}, not {fam!r}")
        spec = get_impl("block_stack", fam).fn(cfg)
        if driver_trainable_only and spec.needs_extra_embeds:
            continue
        out[fam] = arch
    return out


def split_params(spec: BlockSpec, params: dict):
    """Split a replicated params dict into (stack, extras, replicated)
    sub-dicts per the family spec.  ``extras`` is everything that is
    neither the stack nor explicitly replicated — the embeddings/
    final-norm tree the zero3 step shards as one more pseudo-layer."""
    if spec.stack_key not in params:
        raise ValueError(
            f"params have no {spec.stack_key!r} stack (keys: "
            f"{sorted(params)})")
    stack = params[spec.stack_key]
    repl = {k: params[k] for k in spec.replicated_keys if k in params}
    extras = {k: v for k, v in params.items()
              if k != spec.stack_key and k not in spec.replicated_keys}
    return stack, extras, repl
