"""Mixture-of-Experts layer: token-choice top-k routing, capacity dispatch.

Dispatch is done per batch row (sort/scatter along the sequence dim only),
so the token dimension never crosses the data-parallel sharding — routing
is local to each data shard and GSPMD inserts no collectives for it.
Expert FFN weights are tensor-parallel over the "model" axis on d_ff
(works for any expert count — no divisibility constraint between E and the
mesh, which matters for granite-moe's 40 experts on a 16-way axis).

Compute cost is E·C·d·f per row with E·C = K·T·capacity_factor — the
honest ~K-experts-per-token FLOPs (×cf slack), unlike a dense-all-experts
formulation which would inflate HLO FLOPs by E/K.

An expert-parallel all-to-all variant (the paper's `Alltoall_lane` target)
lives in `moe_block_ep` and is exercised by the dbrx hillclimb.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import dense_init, _act, _dtype, pin_act


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.num_experts, _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), dt),
        "w_up": dense_init(ks[1], (E, d, f), dt),
        "w_down": dense_init(ks[2], (E, f, d), dt),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[3], (E, d, f), dt)
    return p


def _route(p, x, cfg: ModelConfig):
    """x: (B, T, d) → (probs (B,T,K), experts (B,T,K), aux_loss scalar)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = (x @ p["router"]).astype(jnp.float32)        # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                    # (B,T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E · Σ_e f_e · P_e
    density = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                       axis=(1, 2))                       # (B,E) token frac
    p_mean = jnp.mean(probs, axis=1)                      # (B,E)
    aux = E * jnp.mean(jnp.sum(density * p_mean, axis=-1))
    return top_p, top_e, aux


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, K = cfg.num_experts, cfg.experts_per_token
    c = int(cfg.moe_capacity_factor * K * T / E)
    return max(8, -(-c // 8) * 8)                         # round up to 8


def _dispatch_buffer(p: dict, x, cfg: ModelConfig):
    """Route + slot-assign + scatter tokens into the (B, E, C, d) buffer.

    Shared verbatim between :func:`moe_block` and :func:`moe_block_ep` so
    the EP-vs-gather bit-identity pin compares only the expert-FFN data
    path, never two divergent dispatch implementations.  Returns
    ``(buf, slot, keep, top_p, aux, C)``.
    """
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, T)
    top_p, top_e, aux = _route(p, x, cfg)

    # --- slot assignment per batch row (local, no cross-shard traffic) ---
    # sort-based ranking (MegaBlocks-style): O(TK log TK) with (B,TK)
    # tensors only — the one-hot cumsum alternative materializes (B,TK,E),
    # which is 16.7 GB/device for dbrx at prefill_32k
    TK = T * K
    flat_e = top_e.reshape(B, TK)                         # expert per (tok,k)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)   # (B,TK)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    hist = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)   # (B,E)
    start = jnp.cumsum(hist, axis=1) - hist               # exclusive prefix
    pos = jnp.broadcast_to(jnp.arange(TK)[None], (B, TK))
    rank_sorted = pos - jnp.take_along_axis(start, sorted_e, axis=1)
    rank = jax.vmap(lambda si, rs: jnp.zeros((TK,), jnp.int32).at[si].set(rs)
                    )(sort_idx, rank_sorted)              # back to (t,k) order
    keep = rank < C                                       # overflow dropped
    slot = jnp.where(keep, flat_e * C + rank, E * C)      # E*C = trash slot

    # --- gather tokens into (B, E*C, d) slot buffer ---
    # pin_act: routing/dispatch tensors must stay batch-sharded — GSPMD's
    # propagation around the per-row scatters otherwise replicates them
    xe = jnp.repeat(x, K, axis=1) if K > 1 else x         # (B,TK,d)
    xe = pin_act(xe)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, xe)
    buf = pin_act(buf[:, :-1].reshape(B, E, C, d))
    return buf, slot, keep, top_p, aux, C


def _combine(y, slot, keep, top_p, x, cfg: ModelConfig):
    """Scatter the expert outputs ``y`` (B, E, C, d) back to token order,
    weighted by router prob.  Shared between gather and EP paths (the slot
    position round-trips the alltoall unchanged, so no index metadata ever
    crosses the wire)."""
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = y.shape[2]
    y = y.reshape(B, E * C, d)
    y = jnp.concatenate([y, jnp.zeros((B, 1, d), y.dtype)], axis=1)
    gathered = jax.vmap(lambda b, s: b[s])(y, slot)       # (B,TK,d)
    gathered = pin_act(gathered)
    w = (top_p.reshape(B, T * K) * keep).astype(x.dtype)
    return (gathered * w[..., None]).reshape(B, T, K, d).sum(axis=2)


def moe_block(p: dict, x, cfg: ModelConfig):
    """Capacity-based dispatch; returns (out (B,T,d), aux_loss)."""
    buf, slot, keep, top_p, aux, _ = _dispatch_buffer(p, x, cfg)

    # --- expert FFN (batched over E; d_ff sharded over "model") ---
    h = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    if "w_gate" in p:
        h = _act(cfg.act)(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * h
    else:
        h = _act(cfg.act)(h)
    h = pin_act(h, shard_last=True)                       # f over "model"
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])      # (B,E,C,d)
    y = pin_act(y)
    return _combine(y, slot, keep, top_p, x, cfg), aux


def _nested_fold(parts, n: int, N: int):
    """Sum per-source partials in the zero3 reduce-scatter's association.

    The flat gather-path gradient sync is RS(node) then psum_scatter(lane)
    — per element that is an ascending fold over node ranks inside each
    lane, then an ascending fold over lanes (XLA CPU all-reduce is an
    ascending left-fold; pinned empirically by the EP bit-identity test).
    ``parts`` is indexed by global rank s = lane·n + node.
    """
    lanes = []
    for l in range(N):
        a = parts[l * n]
        for j in range(1, n):
            a = a + parts[l * n + j]
        lanes.append(a)
    tot = lanes[0]
    for l in range(1, N):
        tot = tot + lanes[l]
    return tot


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def _ep_ffn(act: str, topo, z, w_up, w_gate, w_down):
    """Local expert FFN over received tokens, z: (s, e, b, c, d).

    Forward is the plain einsum path (contractions over d/f only — every
    output element bitwise matches :func:`moe_block`'s FFN).  The custom
    backward exists purely for BIT-identity of the weight grads with the
    gather path under ``lane_zero3``: plain AD would contract (s, b, c)
    in one dot, while the gather path computes a per-chip partial and
    reduce-scatters — a different summation association.  The backward
    therefore computes one partial einsum per source chip and folds them
    with :func:`_nested_fold`.
    """
    h = jnp.einsum("sebcd,edf->sebcf", z, w_up)
    if w_gate is not None:
        a = _act(act)(jnp.einsum("sebcd,edf->sebcf", z, w_gate)) * h
    else:
        a = _act(act)(h)
    return jnp.einsum("sebcf,efd->sebcd", a, w_down)


def _ep_ffn_fwd(act, topo, z, w_up, w_gate, w_down):
    return _ep_ffn(act, topo, z, w_up, w_gate, w_down), \
        (z, w_up, w_gate, w_down)


def _ep_ffn_bwd(act, topo, res, dy):
    z, w_up, w_gate, w_down = res
    n, N = topo.n(), topo.N()
    S = n * N
    fa = _act(act)
    h1 = jnp.einsum("sebcd,edf->sebcf", z, w_up)
    if w_gate is None:
        a, elem_vjp = jax.vjp(fa, h1)
    else:
        hg = jnp.einsum("sebcd,edf->sebcf", z, w_gate)
        # jax.vjp of the exact gating expression reproduces the same
        # elementwise cotangent formulas the gather path's AD emits
        a, elem_vjp = jax.vjp(lambda u, g: fa(g) * u, h1, hg)
    da = jnp.einsum("sebcd,efd->sebcf", dy, w_down)
    if w_gate is None:
        (dh1,) = elem_vjp(da)
        dhg = None
    else:
        dh1, dhg = elem_vjp(da)

    def _acc(u, v, spec):
        return _nested_fold(
            [jnp.einsum(spec, u[s], v[s]) for s in range(S)], n, N)

    dw_up = _acc(z, dh1, "ebcd,ebcf->edf")
    dw_gate = None if w_gate is None else _acc(z, dhg, "ebcd,ebcf->edf")
    dw_down = _acc(a, dy, "ebcf,ebcd->efd")
    dz = jnp.einsum("sebcf,edf->sebcd", dh1, w_up)
    if w_gate is not None:
        dz = dz + jnp.einsum("sebcf,edf->sebcd", dhg, w_gate)
    return dz, dw_up, dw_gate, dw_down


_ep_ffn.defvjp(_ep_ffn_fwd, _ep_ffn_bwd)


def moe_block_ep(p: dict, x, cfg: ModelConfig, *, comm, experts=None,
                 ep_blocks: int = 1, strategy=None):
    """Expert-parallel MoE block: the paper's decomposed alltoall applied
    over the expert axis (§3.5 alltoall mock-up on the dispatch/combine
    hops).

    Chip with global rank r owns the contiguous expert block
    ``[r·E/p, (r+1)·E/p)``; the (B, E, C, d) dispatch buffer is exchanged
    dst-major through ``comm.moe_route`` (an alltoall resolved through the
    ``("moe_route", strategy)`` registry cells), each chip runs the FFN
    for its OWN experts over every source chip's tokens, and a second
    moe_route returns the outputs — two alltoalls of 1/E-expert payload
    replacing the full expert-weight gather.  The slot buffer layout is
    byte-identical to :func:`moe_block`'s, so with ``ep_blocks=1`` the
    forward is bit-identical to the gather path (the einsums contract
    over d/f only; b, c, s are batch dims).

    ``experts``: dict of expert weights (w_up/w_down[/w_gate]) whose
    leading dim is either E (replicated masters — this chip's block is
    dynamic-sliced out) or E/p (a never-gathered ZeRO-3-style expert
    master, already local).  ``None`` reads them from ``p``.

    ``ep_blocks > 1`` software-pipelines the capacity dimension: the
    dispatch alltoall of block j+1 is issued before the expert FFN of
    block j, so routing communication overlaps expert compute (pinned by
    the ``collective_compute_concurrency`` HLO proof).  Requires
    ``ep_blocks | C``.
    """
    B, T, d = x.shape
    E = cfg.num_experts
    topo = comm.topo
    psz = topo.p()
    if E % max(psz, 1):
        raise ValueError(
            f"expert-parallel requires num_experts % p == 0, got "
            f"E={E}, p={psz}")
    Eloc = E // max(psz, 1)

    buf, slot, keep, top_p, aux, C = _dispatch_buffer(p, x, cfg)
    if ep_blocks < 1 or C % ep_blocks:
        raise ValueError(
            f"ep_blocks={ep_blocks} must be >= 1 and divide capacity "
            f"C={C}")

    w = experts if experts is not None else p
    r = topo.global_rank()

    def _loc(a):
        """This chip's expert block: identity for an already-local
        (E/p, ...) master, dynamic slice for a replicated (E, ...) one."""
        if a.shape[0] == Eloc:
            return a
        return lax.dynamic_slice_in_dim(a, r * Eloc, Eloc, axis=0)

    w_up, w_down = _loc(w["w_up"]), _loc(w["w_down"])
    w_gate = _loc(w["w_gate"]) if "w_gate" in w else None

    Cb = C // ep_blocks

    def dispatch(chunk):
        # (B, E, Cb, d) dst-major (experts contiguous per owner) →
        # (p, Eloc, B, Cb, d) src-major: my experts' tokens from chip s
        t = chunk.transpose(1, 0, 2, 3).reshape(E * B * Cb, d)
        o = comm.moe_route(t, strategy=strategy)
        return o.reshape(psz, Eloc, B, Cb, d)

    def ffn(z):
        # z: (s, e, b, c, d) with e local; contraction over d/f only so
        # every output element matches moe_block's "becd,edf" bitwise;
        # custom backward keeps the WEIGHT grads bitwise too (see _ep_ffn)
        return _ep_ffn(cfg.act, topo, z, w_up, w_gate, w_down)

    def combine_route(y):
        # y's s axis IS the destination chip → already dst-major; the
        # reverse alltoall returns (r, Eloc) = global expert r·Eloc+e
        t = y.reshape(psz * Eloc * B * Cb, d)
        o = comm.moe_route(t, strategy=strategy)
        o = o.reshape(psz, Eloc, B, Cb, d)
        return o.transpose(2, 0, 1, 3, 4).reshape(B, E, Cb, d)

    chunks = [lax.slice_in_dim(buf, j * Cb, (j + 1) * Cb, axis=2)
              for j in range(ep_blocks)]
    cur = dispatch(chunks[0])
    outs = []
    for j in range(ep_blocks):
        # prefetch: next block's routing alltoall is independent of this
        # block's expert FFN — issued before it so the two can overlap
        nxt = dispatch(chunks[j + 1]) if j + 1 < ep_blocks else None
        outs.append(combine_route(ffn(cur)))
        cur = nxt
    ybuf = outs[0] if ep_blocks == 1 else jnp.concatenate(outs, axis=2)
    return _combine(ybuf, slot, keep, top_p, x, cfg), aux
