"""Mixture-of-Experts layer: token-choice top-k routing, capacity dispatch.

Dispatch is done per batch row (sort/scatter along the sequence dim only),
so the token dimension never crosses the data-parallel sharding — routing
is local to each data shard and GSPMD inserts no collectives for it.
Expert FFN weights are tensor-parallel over the "model" axis on d_ff
(works for any expert count — no divisibility constraint between E and the
mesh, which matters for granite-moe's 40 experts on a 16-way axis).

Compute cost is E·C·d·f per row with E·C = K·T·capacity_factor — the
honest ~K-experts-per-token FLOPs (×cf slack), unlike a dense-all-experts
formulation which would inflate HLO FLOPs by E/K.

An expert-parallel all-to-all variant (the paper's `Alltoall_lane` target)
lives in `moe_block_ep` and is exercised by the dbrx hillclimb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from .layers import dense_init, _act, _dtype, pin_act


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, E, dt = cfg.d_model, cfg.d_ff, cfg.num_experts, _dtype(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, E), dt),
        "w_up": dense_init(ks[1], (E, d, f), dt),
        "w_down": dense_init(ks[2], (E, f, d), dt),
    }
    if cfg.gated_mlp:
        p["w_gate"] = dense_init(ks[3], (E, d, f), dt)
    return p


def _route(p, x, cfg: ModelConfig):
    """x: (B, T, d) → (probs (B,T,K), experts (B,T,K), aux_loss scalar)."""
    E, K = cfg.num_experts, cfg.experts_per_token
    logits = (x @ p["router"]).astype(jnp.float32)        # (B,T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                    # (B,T,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E · Σ_e f_e · P_e
    density = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32),
                       axis=(1, 2))                       # (B,E) token frac
    p_mean = jnp.mean(probs, axis=1)                      # (B,E)
    aux = E * jnp.mean(jnp.sum(density * p_mean, axis=-1))
    return top_p, top_e, aux


def _capacity(cfg: ModelConfig, T: int) -> int:
    E, K = cfg.num_experts, cfg.experts_per_token
    c = int(cfg.moe_capacity_factor * K * T / E)
    return max(8, -(-c // 8) * 8)                         # round up to 8


def moe_block(p: dict, x, cfg: ModelConfig):
    """Capacity-based dispatch; returns (out (B,T,d), aux_loss)."""
    B, T, d = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = _capacity(cfg, T)
    top_p, top_e, aux = _route(p, x, cfg)

    # --- slot assignment per batch row (local, no cross-shard traffic) ---
    # sort-based ranking (MegaBlocks-style): O(TK log TK) with (B,TK)
    # tensors only — the one-hot cumsum alternative materializes (B,TK,E),
    # which is 16.7 GB/device for dbrx at prefill_32k
    TK = T * K
    flat_e = top_e.reshape(B, TK)                         # expert per (tok,k)
    sort_idx = jnp.argsort(flat_e, axis=1, stable=True)   # (B,TK)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=1)
    hist = jax.vmap(lambda e: jnp.bincount(e, length=E))(flat_e)   # (B,E)
    start = jnp.cumsum(hist, axis=1) - hist               # exclusive prefix
    pos = jnp.broadcast_to(jnp.arange(TK)[None], (B, TK))
    rank_sorted = pos - jnp.take_along_axis(start, sorted_e, axis=1)
    rank = jax.vmap(lambda si, rs: jnp.zeros((TK,), jnp.int32).at[si].set(rs)
                    )(sort_idx, rank_sorted)              # back to (t,k) order
    keep = rank < C                                       # overflow dropped
    slot = jnp.where(keep, flat_e * C + rank, E * C)      # E*C = trash slot

    # --- gather tokens into (B, E*C, d) slot buffer ---
    # pin_act: routing/dispatch tensors must stay batch-sharded — GSPMD's
    # propagation around the per-row scatters otherwise replicates them
    xe = jnp.repeat(x, K, axis=1) if K > 1 else x         # (B,TK,d)
    xe = pin_act(xe)
    buf = jnp.zeros((B, E * C + 1, d), x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].set(v))(buf, slot, xe)
    buf = pin_act(buf[:, :-1].reshape(B, E, C, d))

    # --- expert FFN (batched over E; d_ff sharded over "model") ---
    h = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    if "w_gate" in p:
        h = _act(cfg.act)(jnp.einsum("becd,edf->becf", buf, p["w_gate"])) * h
    else:
        h = _act(cfg.act)(h)
    h = pin_act(h, shard_last=True)                       # f over "model"
    y = jnp.einsum("becf,efd->becd", h, p["w_down"])      # (B,E,C,d)
    y = pin_act(y)

    # --- scatter back, weighted by router prob ---
    y = y.reshape(B, E * C, d)
    y = jnp.concatenate([y, jnp.zeros((B, 1, d), y.dtype)], axis=1)
    gathered = jax.vmap(lambda b, s: b[s])(y, slot)       # (B,TK,d)
    gathered = pin_act(gathered)
    w = (top_p.reshape(B, T * K) * keep).astype(x.dtype)
    out = (gathered * w[..., None]).reshape(B, T, K, d).sum(axis=2)
    return out, aux
