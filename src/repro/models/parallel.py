"""ParallelContext — trace-time routing for the third parallelism axis.

The lane step builders (launch/steps.py, serve/steps.py) enter a
:func:`parallel_context` INSIDE the step function, at trace time, so
every layer body the step traces — including scan and remat bodies —
sees the same tensor-parallel / expert-parallel configuration without
threading extra arguments through every family's signature.  The model
code (``transformer._ffn``, ``_scanned_stack_body``) consults
:func:`parallel_ctx` and routes to :func:`repro.models.layers.mlp_tp`
or :func:`repro.models.moe.moe_block_ep` when an axis is active.

This mirrors the ``activation_batch_axes`` contextvar idiom in
``models/layers.py``: the context is pure trace-time Python state, so it
costs nothing in the lowered HLO and composes with ``jax.checkpoint``
(remat replays happen inside the same trace, hence the same context).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Any, Optional

__all__ = ["ParallelContext", "parallel_ctx", "parallel_context"]


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    """The active parallelism axes beyond data-parallel.

    tp / tp_comm: tensor-parallel degree and the model-axis LaneComm the
        TP activation collectives resolve through (``tp <= 1`` or
        ``tp_comm is None`` disables TP routing).
    tp_variant: ``"gather"`` (all-column-parallel + allgathers —
        bit-identical to the replicated MLP) or ``"reduce"`` (Megatron
        row-parallel down projection + allreduce).
    tp_strategy / ep_strategy: explicit ``(collective, strategy)`` cell
        override; None lets the communicator's config (``auto``) decide.
    ep / ep_comm: expert-parallel token routing over ``ep_comm``'s
        node×lane decomposition (the batch axes — every chip is an
        expert owner).
    ep_blocks: capacity-dim software pipelining depth of the routing
        alltoall (1 = sequential, bit-identity mode).
    ep_experts: ``lane_zero3`` only — the stacked (L, E/p, ...) local
        expert tree injected per layer into the scan body (replicated
        layouts slice their full expert masters by rank instead).
    """
    tp: int = 1
    tp_comm: Optional[Any] = None
    tp_variant: str = "gather"
    tp_strategy: Optional[str] = None
    ep: bool = False
    ep_comm: Optional[Any] = None
    ep_blocks: int = 1
    ep_strategy: Optional[str] = None
    ep_experts: Optional[Any] = None


_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "parallel_ctx", default=ParallelContext())


def parallel_ctx() -> ParallelContext:
    """The active context (the all-defaults instance when none entered)."""
    return _CTX.get()


@contextlib.contextmanager
def parallel_context(**kw):
    """Enter a fresh :class:`ParallelContext` built from ``kw``."""
    tok = _CTX.set(ParallelContext(**kw))
    try:
        yield
    finally:
        _CTX.reset(tok)
