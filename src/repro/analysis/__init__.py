"""repro.analysis — lanelint: static communication-invariant analysis.

Two layers over one diagnostics/baseline spine (DESIGN.md §12):

* ``footprint`` — the shared HLO parse/accounting core (moved up from
  ``launch/hlo_stats``) plus the per-level communication footprint:
  every collective op classified node/lane/global/mixed with
  trip-corrected wire bytes.
* ``rules`` — R1 level-disjointness, R2 payload conservation, R3
  guideline consistency, R4 overlap shape, over every registered
  ``(collective, strategy)`` cell and the composed step builders.
* ``astlint`` — A1 raw-collective containment, A2 no user-facing bare
  asserts, A3 seeded-determinism hygiene, A4 priced-or-opted-out
  registry cells.
* ``lint`` — the CLI (``python -m repro.analysis.lint``, ``make
  lint``): exit 0 clean / 1 findings / 2 internal error.

Import cost: this package root is jax-free; the HLO layer imports jax
lazily AFTER installing the host-device XLA flags.
"""
from .baseline import (apply_baseline, default_baseline_path,
                       load_baseline, save_baseline)
from .diagnostics import ERROR, WARNING, Finding, format_findings
from .footprint import (CollOp, CommFootprint, analyze,
                        collective_compute_concurrency,
                        collective_concurrency, collective_kind_counts,
                        comm_footprint, group_info, parse_hlo,
                        permute_edges, replica_groups,
                        scan_carried_concurrency)

__all__ = [
    "Finding", "ERROR", "WARNING", "format_findings",
    "load_baseline", "save_baseline", "apply_baseline",
    "default_baseline_path",
    "CollOp", "CommFootprint", "comm_footprint", "analyze",
    "collective_kind_counts", "collective_concurrency",
    "collective_compute_concurrency", "scan_carried_concurrency",
    "group_info", "parse_hlo", "replica_groups", "permute_edges",
]
