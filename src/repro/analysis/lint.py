"""lanelint CLI — ``python -m repro.analysis.lint``.

Runs both layers (HLO footprint rules R1–R4 over every registry cell
and the composed step builders; AST rules A1–A4 over ``src/repro/**``),
applies the baseline-suppression file, and reports:

  exit 0   no unsuppressed findings (stale baseline entries warn)
  exit 1   unsuppressed findings (printed, errors first)
  exit 2   the lint itself failed (bad baseline, lowering crash, …)

Flags:
  --ast-only / --hlo-only   run a single layer
  --baseline PATH           baseline file (default: repo-root
                            lint_baseline.json)
  --no-baseline             ignore the baseline entirely
  --update-baseline         write the current findings to the baseline
                            (preserving existing reasons) and exit 0
  -v / --verbose            per-cell/per-step footprint progress

The HLO layer needs 8 host devices; the CLI installs the XLA host-
device flags itself BEFORE the first jax import — no environment
setup required at the call site (``make lint`` just works).
"""
from __future__ import annotations

import argparse
import sys


def _parse(argv):
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="static communication-invariant analysis "
                    "(lanelint): HLO footprint rules + AST rules")
    layer = ap.add_mutually_exclusive_group()
    layer.add_argument("--ast-only", action="store_true",
                       help="run only the A1-A4 AST rules (no jax)")
    layer.add_argument("--hlo-only", action="store_true",
                       help="run only the R1-R4 HLO footprint rules")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="baseline suppression file (default: repo-root "
                         "lint_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file")
    ap.add_argument("--update-baseline", action="store_true",
                    help="write current findings to the baseline "
                         "(existing reasons preserved) and exit 0")
    ap.add_argument("-v", "--verbose", action="store_true")
    return ap.parse_args(argv)


def _collect(args) -> list:
    findings = []
    if not args.ast_only:
        # host-device flags MUST land before the first jax import
        from repro.tuning.backend import apply_backend_setup
        apply_backend_setup("cpu", host_device_count=8)
        from .rules import run_hlo_rules, run_step_rules
        if args.verbose:
            print("== HLO footprint rules (R1-R4): registry cells ==",
                  flush=True)
        findings += run_hlo_rules(verbose=args.verbose)
        if args.verbose:
            print("== HLO footprint rules (R1): step builders ==",
                  flush=True)
        findings += run_step_rules(verbose=args.verbose)
    if not args.hlo_only:
        from .astlint import run_ast_rules
        if args.verbose:
            print("== AST rules (A1-A4): src/repro/** ==", flush=True)
        findings += run_ast_rules()
    return findings


def main(argv=None) -> int:
    args = _parse(argv if argv is not None else sys.argv[1:])
    from .baseline import (apply_baseline, default_baseline_path,
                           load_baseline, save_baseline)
    from .diagnostics import format_findings
    try:
        findings = _collect(args)
        if args.update_baseline:
            path = save_baseline(findings, args.baseline)
            print(f"lanelint: wrote {len(findings)} suppression(s) to "
                  f"{path}")
            return 0
        baseline = {} if args.no_baseline \
            else load_baseline(args.baseline)
    except Exception as e:  # noqa: BLE001 — exit-code contract
        print(f"lanelint: internal error: {type(e).__name__}: {e}",
              file=sys.stderr)
        if args.verbose:
            import traceback
            traceback.print_exc()
        return 2
    unsuppressed, stale = apply_baseline(findings, baseline)
    for key in stale:
        print(f"WARNING stale baseline entry {key} — the finding no "
              f"longer occurs; delete it from "
              f"{args.baseline or default_baseline_path()}")
    if unsuppressed:
        print(format_findings(unsuppressed))
        print(f"lanelint: {len(unsuppressed)} finding(s) "
              f"({len(findings) - len(unsuppressed)} suppressed, "
              f"{len(stale)} stale suppression(s))")
        return 1
    print(f"lanelint: clean ({len(findings)} suppressed, "
          f"{len(stale)} stale suppression(s))" if findings or stale
          else "lanelint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
