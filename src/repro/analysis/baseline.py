"""Baseline suppression file for lanelint.

The baseline records findings that are UNDERSTOOD and accepted — each
entry must carry a ``reason`` (enforced on load), so the file doubles as
the justification log the ISSUE asks for ("near-empty, with each
remaining entry justified").  Matching is by ``Finding.key``
(``rule:target``, no line numbers), so suppressions survive unrelated
edits but never mask a new cell/file violating the same rule.

Format (JSON, sorted, diff-stable):

    {"version": 1,
     "entries": [{"rule": "A1", "target": "src/...#lax.psum",
                  "reason": "why this one is fine"}]}

``apply_baseline`` also returns the STALE entries (suppressions whose
finding no longer occurs): the lint CLI reports them as warnings so the
file cannot silently rot.
"""
from __future__ import annotations

import json
import os
from typing import Iterable, Optional

from .diagnostics import Finding

__all__ = ["load_baseline", "save_baseline", "apply_baseline",
           "default_baseline_path", "BASELINE_VERSION"]

BASELINE_VERSION = 1
_DEFAULT_NAME = "lint_baseline.json"


def default_baseline_path() -> str:
    """``lint_baseline.json`` at the repo root (… /src/repro/analysis/
    baseline.py → repo root is four parents up)."""
    here = os.path.abspath(os.path.dirname(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, _DEFAULT_NAME)


def load_baseline(path: Optional[str] = None) -> dict:
    """{key: entry-dict} from a baseline file; {} when the file does not
    exist (an empty baseline is the healthy steady state).  Malformed
    files and entries without a reason raise — a baseline that cannot be
    audited must not silently suppress anything."""
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path}: unsupported format "
                         f"{doc.get('version') if isinstance(doc, dict) else doc!r}")
    out: dict = {}
    for i, e in enumerate(doc.get("entries", [])):
        try:
            rule, target = str(e["rule"]), str(e["target"])
            reason = str(e["reason"])
        except (KeyError, TypeError) as exc:
            raise ValueError(f"baseline {path}: entry {i} malformed: {exc}")
        if not reason.strip():
            raise ValueError(f"baseline {path}: entry {i} "
                             f"({rule}:{target}) has no reason — every "
                             f"suppression must be justified")
        out[f"{rule}:{target}"] = {"rule": rule, "target": target,
                                   "reason": reason}
    return out


def save_baseline(findings: Iterable[Finding],
                  path: Optional[str] = None, *,
                  reason: str = "TODO: justify this suppression") -> str:
    """Write a baseline suppressing ``findings`` (sorted, deterministic).
    Existing reasons at the same key are preserved; new entries get the
    placeholder ``reason`` for the author to edit."""
    path = path or default_baseline_path()
    keep = {}
    if os.path.exists(path):
        keep = load_baseline(path)
    entries = []
    for f in sorted(findings, key=lambda f: f.key):
        prev = keep.get(f.key)
        entries.append({"rule": f.rule, "target": f.target,
                        "reason": prev["reason"] if prev else reason})
    doc = {"version": BASELINE_VERSION, "entries": entries}
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def apply_baseline(findings: Iterable[Finding], baseline: dict) -> tuple:
    """(unsuppressed findings, stale baseline keys).

    A finding whose key appears in the baseline is suppressed; baseline
    entries matching NO current finding are stale and should be deleted
    (reported, so the file cannot accumulate dead weight)."""
    findings = list(findings)
    hit = {f.key for f in findings} & set(baseline)
    unsup = [f for f in findings if f.key not in baseline]
    stale = sorted(set(baseline) - hit)
    return unsup, stale
