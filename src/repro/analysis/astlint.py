"""lanelint layer 2 — architectural AST rules over ``src/repro/**``.

Where layer 1 proves the LOWERED communication is the paper's, this
layer keeps the SOURCE honest about how it gets there:

  A1  no raw collectives outside the communication layers — every
      ``lax.psum``/``ppermute``/``all_gather``/…/``shard_map`` call site
      must live in ``comm/``, ``core/``, ``testing/`` or the explicit
      whitelist below.  Everything else goes through ``LaneComm`` so the
      registry/dispatch/lint machinery sees it.
  A2  no user-facing control flow on bare ``assert`` — ``python -O``
      strips asserts, so input validation must raise.  (Trace-time shape
      checks in the reference layer and test harnesses are exempt.)
  A3  no wall-clock or unseeded randomness in the seeded-determinism
      modules (``serve/sampling``, ``runtime/faults``, ``data/``):
      ``time.time*``, legacy ``numpy.random.*`` globals and a zero-arg
      ``default_rng()`` all break replay.
  A4  every ``register_impl`` cell is priced or explicitly opts out:
      the call must pass ``cost=`` or a literal ``auto_ok=False`` —
      an unpriced auto-eligible cell would silently never win (or worse,
      win by registration-order accident) in auto dispatch.

Pure stdlib ``ast`` — no jax import, so the AST leg runs anywhere in
milliseconds.
"""
from __future__ import annotations

import ast
import os
from typing import Iterable, Optional

from .diagnostics import Finding

__all__ = ["run_ast_rules", "iter_source_files", "lint_file",
           "RAW_COLLECTIVES", "A1_ALLOWED_DIRS", "A1_FILE_WHITELIST",
           "A2_EXEMPT", "A3_SCOPE"]

#: jax.lax (and jax.) names A1 treats as raw collective machinery
RAW_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "ppermute", "pshuffle",
    "psum_scatter", "pbroadcast", "all_gather", "all_to_all",
    "axis_index", "shard_map",
})

#: directories (relative to the repro package) allowed raw collectives
A1_ALLOWED_DIRS = ("comm", "core", "testing")

#: file → why it may touch raw collectives / shard_map
A1_FILE_WHITELIST = {
    "compat.py": "jax version shim: re-exports shard_map itself",
    "launch/steps.py": "step assembly: shard_map wrapping and the "
                       "scalar loss/grad-norm reductions of the step "
                       "skeleton (payload comm goes through LaneComm)",
    "launch/train.py": "driver: wraps the built step in shard_map",
    "launch/sharding.py": "sharding audit: reads axis_index to label "
                          "placements, moves no payload",
    "optim/gradsync.py": "gradient-sync stage library: the node/lane "
                         "stage primitives the registry cells compose",
    "runtime/straggler.py": "quorum machinery: masked psum votes are "
                            "the fault-detection protocol itself",
    "serve/steps.py": "serving step assembly: shard_map wrapping only",
    "tuning/probe.py": "probe harness: shard_map wrapping of registry "
                       "cells under measurement",
    "analysis/rules.py": "the lint's own cell-lowering harness",
    "analysis/steps.py": "the lint's own step-lowering harness",
}

#: files/dirs exempt from A2 (bare asserts fine: never ships user input)
A2_EXEMPT = ("testing", "core/ref.py", "analysis")

#: seeded-determinism scope for A3
A3_SCOPE = ("serve/sampling.py", "runtime/faults.py", "data")

_TIME_BANNED = frozenset({"time", "time_ns"})


def _pkg_root() -> str:
    """Absolute path of the ``repro`` package directory."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _under(rel: str, prefixes: Iterable[str]) -> bool:
    for p in prefixes:
        if rel == p or rel.startswith(p.rstrip("/") + "/"):
            return True
    return False


def iter_source_files(root: Optional[str] = None):
    """(abs_path, package-relative posix path) of every repro module."""
    root = root or _pkg_root()
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = sorted(d for d in dirnames if d != "__pycache__")
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            ap = os.path.join(dirpath, fn)
            yield ap, os.path.relpath(ap, root).replace(os.sep, "/")


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('jax.lax.psum', …)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return ".".join(reversed(parts))


def _lax_imported_names(tree: ast.Module) -> set:
    """Collective names this module imported DIRECTLY from jax.lax /
    jax (``from jax.lax import psum`` / ``from jax import shard_map``),
    so bare-name calls can be attributed."""
    out: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) \
                and node.module in ("jax.lax", "jax"):
            for a in node.names:
                if a.name in RAW_COLLECTIVES:
                    out.add(a.asname or a.name)
    return out


def _check_a1(tree: ast.Module, rel: str, target_file: str) -> list:
    if _under(rel, A1_ALLOWED_DIRS) or rel in A1_FILE_WHITELIST:
        return []
    bare = _lax_imported_names(tree)
    hits: dict = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = None
        if isinstance(node.func, ast.Attribute):
            dotted = _dotted(node.func)
            head, _, leaf = dotted.rpartition(".")
            if leaf in RAW_COLLECTIVES and (
                    head in ("lax", "jax", "jax.lax")
                    or head.endswith(".lax")):
                name = leaf
        elif isinstance(node.func, ast.Name) and node.func.id in bare:
            name = node.func.id
        if name:
            hits.setdefault(name, []).append(node.lineno)
    return [
        Finding("A1", f"{target_file}#{name}",
                f"raw collective `{name}` called at line(s) "
                f"{sorted(lines)} outside comm/core/testing and the "
                f"whitelist — route it through LaneComm so dispatch, "
                f"tuning and lanelint all see it")
        for name, lines in sorted(hits.items())]


def _check_a2(tree: ast.Module, rel: str, target_file: str) -> list:
    if _under(rel, A2_EXEMPT):
        return []
    lines = [n.lineno for n in ast.walk(tree)
             if isinstance(n, ast.Assert)]
    if not lines:
        return []
    return [Finding(
        "A2", f"{target_file}#assert",
        f"bare assert at line(s) {sorted(lines)} — `python -O` strips "
        f"asserts, so validation that guards user-facing behavior must "
        f"raise (ValueError/RuntimeError) instead")]


def _check_a3(tree: ast.Module, rel: str, target_file: str) -> list:
    if not _under(rel, A3_SCOPE):
        return []
    hits: dict = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        head, _, leaf = dotted.rpartition(".")
        if head == "time" and leaf in _TIME_BANNED:
            hits.setdefault(dotted, []).append(node.lineno)
            continue
        # jax.random is SEEDED functional randomness — exactly right;
        # the ban is the stdlib global RNG and numpy's legacy globals
        legacy = head in ("np.random", "numpy.random", "random")
        if legacy and leaf == "default_rng":
            if not node.args and not node.keywords:
                hits.setdefault(dotted + "()", []).append(node.lineno)
        elif legacy:
            hits.setdefault(dotted, []).append(node.lineno)
    return [
        Finding("A3", f"{target_file}#{name}",
                f"`{name}` at line(s) {sorted(lines)} in a "
                f"seeded-determinism module — wall-clock/unseeded "
                f"randomness breaks replay; thread an explicit seed or "
                f"clock through the call")
        for name, lines in sorted(hits.items())]


def _check_a4(tree: ast.Module, rel: str, target_file: str) -> list:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted.rpartition(".")[2] != "register_impl":
            continue
        cell = "/".join(
            a.value for a in node.args[:2]
            if isinstance(a, ast.Constant) and isinstance(a.value, str))
        kw = {k.arg: k.value for k in node.keywords if k.arg}
        priced = "cost" in kw
        opted_out = isinstance(kw.get("auto_ok"), ast.Constant) \
            and kw["auto_ok"].value is False
        if not (priced or opted_out):
            out.append(Finding(
                "A4", f"{target_file}#{cell or 'register_impl'}",
                f"register_impl({cell or '?'}) at line {node.lineno} "
                f"has neither cost= nor a literal auto_ok=False — an "
                f"unpriced auto-eligible cell wins or loses dispatch by "
                f"registration-order accident"))
    return out


def lint_file(abs_path: str, rel: str, *, src_prefix: str) -> list:
    with open(abs_path, encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=abs_path)
    except SyntaxError as e:
        return [Finding("A0", f"{src_prefix}{rel}",
                        f"unparseable module: {e}")]
    target_file = f"{src_prefix}{rel}"
    return (_check_a1(tree, rel, target_file)
            + _check_a2(tree, rel, target_file)
            + _check_a3(tree, rel, target_file)
            + _check_a4(tree, rel, target_file))


def run_ast_rules(root: Optional[str] = None) -> list:
    """A1–A4 over every module of the repro package."""
    findings = []
    for abs_path, rel in iter_source_files(root):
        findings += lint_file(abs_path, rel, src_prefix="src/repro/")
    return findings
