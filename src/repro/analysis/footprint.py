"""Shared HLO analysis core: parsing, trip-corrected accounting, structural
concurrency, and the per-level communication footprint behind lanelint.

This module is the home of what used to live in ``launch/hlo_stats.py``
(which now re-exports from here for back-compat):

  * exact-ish HLO accounting — dot FLOPs, HBM-traffic bytes, collective
    bytes, with while-loop bodies multiplied by their known trip counts
    (``analyze``, ``collective_kind_counts``);
  * structural concurrency proofs for the §5 pipelines
    (``collective_concurrency``, ``collective_compute_concurrency``);

plus the **communication footprint** layer the static lint rules run on:
``comm_footprint`` walks a lowered module and returns every executed
collective op classified by *communication level* under the repo's
device-id convention (``global_rank = lane_rank·n + node_rank``):

  ``"node"``    every member of the replica group lives in one pod
                (ICI traffic) — a §3 node-communicator op;
  ``"lane"``    the group holds at most one member per pod (DCN
                traffic) — a lane-communicator op;
  ``"global"``  the group covers every device (the native/whole-machine
                collective, or a rooted-collective psum emulation);
  ``"mixed"``   anything else — a group that straddles pods without
                covering the machine.  This is exactly the shape the R1
                level-disjointness rule forbids: some of its edges are
                intra-pod and some cross-pod, so the node and lane
                communicators would share an edge.

Footprint wire-byte conventions (per op, per execution, ring algorithms,
g = group size) differ deliberately from the legacy ``analyze`` model in
one place and are the closed forms ``comm/costs.py:lowered_wire_volumes``
is written against:

  all-reduce       2·(g−1)/g · result_bytes
  all-gather         (g−1)/g · result_bytes   (result = the gathered buf)
  reduce-scatter     (g−1)   · result_bytes   (result = one SHARD — each
                                               chip forwards g−1 shard-
                                               sized partials)
  all-to-all         (g−1)/g · result_bytes
  collective-permute           result_bytes   (one hop, whole buffer)

``analyze`` keeps its original reduce-scatter convention ((g−1)/g of the
result) untouched — perf-regression baselines pin those totals.

Why trip correction: ``compiled.cost_analysis()`` counts every while body
exactly once (verified empirically — a 10-iteration scan reports 1
iteration of FLOPs).  XLA:CPU annotates optimized while ops with
``backend_config={"known_trip_count":{"n":...}}``, so executed totals are
reconstructed by walking the call graph:

  flops(comp)  = Σ own dot/conv flops + Σ_child mult(child) · flops(child)
  mult = trip count for while bodies, 1 for fusions/calls/branches
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# type may be a tuple containing /*index=N*/ comments (hence '=') — match
# lazily up to the first ')' that is followed by the op name.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\(")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=)%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_ALL_RE = re.compile(r"replica_groups=\{((?:\{[\d,]*\},?)*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(d) if d else _DTYPE_BYTES[dt]
               for dt, d in _dims(type_str))


def _elems_of(type_str: str) -> int:
    return sum(math.prod(d) if d else 1 for dt, d in _dims(type_str))


class Instr:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name, type_str, op, line):
        self.name, self.type_str, self.op, self.line = name, type_str, op, line


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[Instr] = []
        self.table: dict[str, str] = {}     # instr name -> type str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_START_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            cur.instrs.append(Instr(name, type_str, op, line))
            cur.table[name] = type_str
    comps["__entry__"] = comps.get(entry) if entry else None
    return comps


def _operand_names(inst: Instr) -> list[str]:
    """Raw operand names of one HLO instruction, in order.

    Handles both operand dialects: bare ``op(%a, %b)`` and the typed
    ``op(f32[8]{0} %a, f32[8]{0} %b)`` form compiled dumps use.  Only the
    operand parenthesis group is scanned (balanced — tuple types nest), so
    attribute refs like ``to_apply=%add`` are never picked up.
    """
    line = inst.line
    try:
        start = line.index(inst.op + "(") + len(inst.op)
    except ValueError:
        return []
    seg = line[start:]
    depth = 0
    for k, ch in enumerate(line[start:], start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                seg = line[start:k + 1]
                break
    names = re.findall(r"%([\w.\-]+)", seg)
    if not names:
        # bare dialect: comma-split, strip types, keep name-ish tokens
        names = [t.split()[-1] for t in seg.strip("()").split(",")
                 if t.strip()]
    return names


def _dot_flops(inst: Instr, table: dict[str, str]) -> float:
    out_elems = _elems_of(inst.type_str)
    mc = _CONTRACT_RE.search(inst.line)
    k = 1
    if mc:
        cdims = [int(x) for x in mc.group(1).split(",") if x]
        names = _operand_names(inst)
        lhs_t = table.get(names[0]) if names else None
        if lhs_t:
            d = _dims(lhs_t)
            if d:
                shape = d[0][1]
                for c in cdims:
                    if c < len(shape):
                        k *= shape[c]
    return 2.0 * out_elems * k


def _conv_flops(inst: Instr, table: dict[str, str]) -> float:
    # flops ≈ 2 · out_elems · (kernel spatial · in_channels); approximate
    # via rhs (kernel) element count / out_channels
    out_elems = _elems_of(inst.type_str)
    names = _operand_names(inst)
    k = 1
    if len(names) >= 2 and names[1] in table:
        d = _dims(table[names[1]])
        if d:
            k = max(1, math.prod(d[0][1]))
    return 2.0 * out_elems * k


def _operand_bytes(inst: Instr, table: dict[str, str]) -> int:
    return sum(_bytes_of(table[nm]) for nm in _operand_names(inst)
               if nm in table)


def group_info(line: str, pod_size: int):
    """(group_size, crosses_pod) from replica_groups, exact for both the
    explicit {{...}} and the iota [G,S]<=[dims]T(perm) forms."""
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return len(ids), len({i // pod_size for i in ids}) > 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        rows = ids.reshape(g, s) // pod_size
        return s, bool((rows.max(axis=1) != rows.min(axis=1)).any())
    return 2, False


def replica_groups(line: str,
                   num_devices: Optional[int] = None) -> Optional[list]:
    """EVERY replica group of one instruction line as id tuples, or None
    when the line carries no ``replica_groups=`` attribute at all.

    Handles the explicit ``{{0,1},{2,3}}`` form, the iota
    ``[G,S]<=[dims]T(perm)`` form, and the degenerate ``{}`` (all devices
    in one group — requires ``num_devices``; returns ``[()]`` when the
    machine size is unknown so callers can still see "one global group").
    """
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        return [tuple(int(x) for x in row) for row in ids.reshape(g, s)]
    m = _GROUPS_ALL_RE.search(line)
    if m is None:
        return None
    inner = m.group(1)
    if not inner.strip():
        # replica_groups={}: one group of the whole machine
        if num_devices:
            return [tuple(range(num_devices))]
        return [()]
    return [tuple(int(x) for x in grp.split(",") if x)
            for grp in re.findall(r"\{([\d,]*)\}", inner)]


def permute_edges(line: str) -> Optional[list]:
    """collective-permute ``source_target_pairs`` as (src, dst) tuples."""
    mp = _PAIRS_RE.search(line)
    if not mp:
        return None
    return [(int(a), int(b)) for a, b in
            re.findall(r"\{(\d+),(\d+)\}", mp.group(1))]


def _collective(inst: Instr, pod_size: int):
    kind = inst.op.replace("-start", "")
    if kind not in _COLL_KINDS:
        return None
    b = _bytes_of(inst.type_str)
    g, dcn = group_info(inst.line, pod_size)
    if kind == "collective-permute":
        # source-target pairs, not groups: DCN iff ANY pair crosses pods
        # (the braces nest — match the whole {{a,b},{c,d},...} list, not
        # just up to the first '}')
        pairs = permute_edges(inst.line)
        if pairs:
            dcn = any(a // pod_size != b2 // pod_size for a, b2 in pairs)
    if kind == "all-reduce":
        wire = 2 * (g - 1) / g * b
    elif kind in ("all-gather", "all-to-all", "reduce-scatter"):
        wire = (g - 1) / g * b
    else:
        wire = float(b)
    return {"kind": kind, "bytes": float(b), "wire": wire, "group": g,
            "dcn": dcn}


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "call",
                   "after-all", "add-dependency"}

# ops whose HBM traffic is a function of the RESULT (or update) size, not
# the full operand buffers: a dynamic-slice of an (L, d, f) stacked weight
# reads one layer's slice, not the whole stack — counting operands would
# overcount loop-heavy models by ~L×.
_RESULT_BYTES_OPS = {
    "dynamic-slice": 2,      # read slice + write result
    "slice": 2,
    "gather": 2,
    "reshape": 2,
    "copy": 2,
    "transpose": 2,
    "convert": 2,
    "broadcast": 1,          # reads a much smaller operand
    "iota": 1,
    "reverse": 2,
    "pad": 2,
    "concatenate": 2,
}


def _instr_bytes(inst: "Instr", table: dict[str, str]) -> float:
    if inst.op in _RESULT_BYTES_OPS:
        return _RESULT_BYTES_OPS[inst.op] * _bytes_of(inst.type_str)
    if inst.op == "dynamic-update-slice":
        # aliased in place: read+write the update operand only
        names = _operand_names(inst)
        if len(names) >= 2 and names[1] in table:
            return 2.0 * _bytes_of(table[names[1]])
        return 2.0 * _bytes_of(inst.type_str)
    return _bytes_of(inst.type_str) + _operand_bytes(inst, table)


def analyze(text: str, *, pod_size: int = 256) -> dict:
    """Trip-corrected totals + per-loop-depth byte attribution.

    ``bytes_depth`` maps while-nesting depth → HBM bytes.  Depth ≥ 3 in a
    train step (µbatch × layer × attention-block scans) is the traffic a
    fused Pallas kernel keeps in VMEM — the §Perf memory-term lever.
    """
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    memo: dict[str, dict] = {}

    def walk(comp: Computation, depth: int = 0) -> dict:
        if (comp.name, depth) in memo:
            return memo[(comp.name, depth)]
        res = {"flops": 0.0, "bytes": 0.0, "bytes_depth": {},
               "coll": {}, "coll_wire": 0.0, "dcn_wire": 0.0,
               "ici_wire": 0.0, "coll_count": 0}
        memo[(comp.name, depth)] = res  # cycle guard (HLO is acyclic)
        def add_depth(d, b):
            res["bytes_depth"][d] = res["bytes_depth"].get(d, 0.0) + b

        for inst in comp.instrs:
            if inst.op == "dot":
                res["flops"] += _dot_flops(inst, comp.table)
            elif inst.op == "convolution":
                res["flops"] += _conv_flops(inst, comp.table)
            c = _collective(inst, pod_size)
            if c:
                k = c["kind"]
                rec = res["coll"].setdefault(k, {"count": 0, "bytes": 0.0,
                                                 "wire_bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += c["bytes"]
                rec["wire_bytes"] += c["wire"]
                res["coll_wire"] += c["wire"]
                res["coll_count"] += 1
                if c["dcn"]:
                    res["dcn_wire"] += c["wire"]
                else:
                    res["ici_wire"] += c["wire"]
            if inst.op not in _SKIP_BYTES_OPS:
                b = _instr_bytes(inst, comp.table)
                res["bytes"] += b
                add_depth(depth, b)
            # recurse
            mult = 1
            depth_child = depth
            children = []
            if inst.op == "while":
                mt = _TRIP_RE.search(inst.line)
                mult = int(mt.group(1)) if mt else 1
                depth_child = depth + 1
                mb = re.search(r"body=%?([\w.\-]+)", inst.line)
                if mb:
                    children = [mb.group(1)]
            elif inst.op in ("fusion", "call", "map", "reduce",
                             "reduce-window", "sort", "scatter",
                             "select-and-scatter", "all-reduce"):
                children = _CALLED_RE.findall(inst.line)
            elif inst.op == "conditional":
                mb = _BRANCHES_RE.search(inst.line)
                if mb:
                    children = [c.strip().lstrip("%")
                                for c in mb.group(1).split(",")]
            for ch in children:
                if ch in comps:
                    sub = walk(comps[ch], depth_child)
                    if inst.op == "fusion":
                        # fusion: count internal dot flops (they execute)
                        res["flops"] += mult * sub["flops"]
                        # bytes already counted at the call site
                    else:
                        res["flops"] += mult * sub["flops"]
                        res["bytes"] += mult * sub["bytes"]
                        for d, b in sub["bytes_depth"].items():
                            add_depth(d, mult * b)
                    for k, rec in sub["coll"].items():
                        dst = res["coll"].setdefault(
                            k, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
                        dst["count"] += mult * rec["count"]
                        dst["bytes"] += mult * rec["bytes"]
                        dst["wire_bytes"] += mult * rec["wire_bytes"]
                    res["coll_wire"] += mult * sub["coll_wire"]
                    res["dcn_wire"] += mult * sub["dcn_wire"]
                    res["ici_wire"] += mult * sub["ici_wire"]
                    res["coll_count"] += mult * sub["coll_count"]
        return res

    if entry is None:
        raise ValueError("no ENTRY computation found")
    out = dict(walk(entry))
    out["computations"] = len(comps)
    return out


def collective_kind_counts(text: str, *, pod_size: int = 256) -> dict:
    """Trip-corrected executed-op counts per collective kind for the
    whole module (``{"all-gather": 12, ...}``; absent kinds are 0 via
    ``.get``).  The backward re-gather and hybrid single-gather-per-layer
    pins compare these counts across lowerings: a remat cell that
    accidentally recomputes a weight gather, or a backward that is
    SUPPOSED to re-gather, both show up as an all-gather count delta."""
    res = analyze(text, pod_size=pod_size)
    return {k: int(v["count"]) for k, v in res["coll"].items()}


# ---------------------------------------------------------------------------
# communication footprint: every executed collective, classified by level
# ---------------------------------------------------------------------------

#: footprint wire-byte conventions (see module docstring) — per op, per
#: execution, as a function of (group size, RESULT bytes)
def _footprint_wire(kind: str, g: int, result_bytes: float) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if kind == "all-gather":
        return (g - 1) / g * result_bytes
    if kind == "reduce-scatter":
        return float(g - 1) * result_bytes
    if kind == "all-to-all":
        return (g - 1) / g * result_bytes
    return float(result_bytes)             # collective-permute


def classify_group(ids, *, n: int, num_devices: Optional[int] = None) -> str:
    """Communication level of one replica group under the lane-major
    device convention (pod of device g is ``g // n``).

    "node" = one pod; "lane" = at most one member per pod; "global" =
    the whole machine; "mixed" = straddles pods without covering them —
    the R1-forbidden shape.  Single-member groups are "node" (no wire).
    """
    ids = tuple(ids)
    if not ids:                             # replica_groups={} placeholder
        return "global"
    if len(ids) <= 1:
        return "node"
    pods = {d // n for d in ids}
    if len(pods) == 1:
        return "node"
    if num_devices is not None and len(ids) == num_devices:
        return "global"
    if len(pods) == len(ids):
        return "lane"
    return "mixed"


def _classify_edges(pairs, *, n: int) -> str:
    """Level of a collective-permute from its edges: all intra-pod →
    node, all cross-pod → lane, a mix → mixed."""
    kinds = {"node" if a // n == b // n else "lane" for a, b in pairs}
    if kinds == {"node"}:
        return "node"
    if kinds == {"lane"}:
        return "lane"
    return "mixed"


@dataclasses.dataclass(frozen=True)
class CollOp:
    """One executed collective site in a lowered module.

    ``count`` is the trip-corrected executed multiplicity (a collective
    inside a B-trip scan body appears once with count == B·outer trips);
    ``result_bytes``/``wire_bytes`` are PER EXECUTION, so executed totals
    are ``count · wire_bytes``.
    """
    kind: str                # all-reduce | all-gather | ...
    level: str               # node | lane | global | mixed
    group_size: int
    count: int
    result_bytes: float
    wire_bytes: float
    computation: str
    name: str                # instruction name (diagnostics)

    @property
    def total_wire(self) -> float:
        return self.count * self.wire_bytes


class CommFootprint:
    """The collective ops of one lowered module, with per-level totals."""

    LEVELS = ("node", "lane", "global", "mixed")

    def __init__(self, ops, *, n: int, num_devices: Optional[int] = None):
        self.ops: tuple = tuple(ops)
        self.n = int(n)
        self.num_devices = num_devices

    def __len__(self) -> int:
        return len(self.ops)

    def wire(self, level: Optional[str] = None) -> float:
        """Total executed wire bytes, optionally restricted to a level."""
        return sum(o.total_wire for o in self.ops
                   if level is None or o.level == level)

    def by_level(self) -> dict:
        return {lv: self.wire(lv) for lv in self.LEVELS}

    def kind_counts(self, level: Optional[str] = None) -> dict:
        out: dict = {}
        for o in self.ops:
            if level is None or o.level == level:
                out[o.kind] = out.get(o.kind, 0) + o.count
        return out

    def mixed(self) -> tuple:
        """The R1-violating ops (straddle pods without covering all)."""
        return tuple(o for o in self.ops if o.level == "mixed")

    def levels(self) -> tuple:
        return tuple(lv for lv in self.LEVELS if any(
            o.level == lv for o in self.ops))


def _coll_level(inst: Instr, *, n: int,
                num_devices: Optional[int]) -> tuple:
    """(level, group_size) of one collective instruction."""
    pairs = permute_edges(inst.line)
    if inst.op.replace("-start", "") == "collective-permute" and pairs:
        return _classify_edges(pairs, n=n), 2
    groups = replica_groups(inst.line, num_devices)
    if not groups:
        return "global", (num_devices or 2)
    levels = {classify_group(g, n=n, num_devices=num_devices)
              for g in groups}
    sizes = {len(g) for g in groups if g}
    gsize = max(sizes) if sizes else (num_devices or 2)
    # groups of one op are symmetric shards of the same partition; if ANY
    # of them straddles (or they disagree on level) the op is mixed
    if len(levels - {"node"}) > 1 or "mixed" in levels:
        return "mixed", gsize
    for lv in ("global", "lane", "node"):
        if lv in levels:
            return lv, gsize
    return "node", gsize


def comm_footprint(text: str, *, n: int,
                   num_devices: Optional[int] = None) -> CommFootprint:
    """Walk a lowered/optimized module and return its
    :class:`CommFootprint`: every collective op, trip-corrected, with its
    communication level under pod size ``n``.

    ``num_devices`` (p = n·N) lets degenerate ``replica_groups={}`` and
    whole-machine groups be recognized as "global"; when omitted it is
    inferred as 1 + the largest device id any group mentions.
    """
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found")

    if num_devices is None:
        seen = 0
        for comp in comps.values():
            for inst in comp.instrs:
                for grp in (replica_groups(inst.line) or []):
                    seen = max(seen, max(grp, default=0) + 1)
                for a, b in (permute_edges(inst.line) or []):
                    seen = max(seen, a + 1, b + 1)
        num_devices = seen or None

    memo: dict[str, list] = {}

    def walk(comp: Computation) -> list:
        if comp.name in memo:
            return memo[comp.name]
        memo[comp.name] = []                # cycle guard (HLO is acyclic)
        out: list = []
        for inst in comp.instrs:
            kind = inst.op.replace("-start", "")
            if kind in _COLL_KINDS:
                level, gsize = _coll_level(inst, n=n,
                                           num_devices=num_devices)
                rb = float(_bytes_of(inst.type_str))
                out.append((CollOp(kind=kind, level=level,
                                   group_size=gsize, count=1,
                                   result_bytes=rb,
                                   wire_bytes=_footprint_wire(kind, gsize,
                                                              rb),
                                   computation=comp.name,
                                   name=inst.name), 1))
            mult = 1
            children = []
            if inst.op == "while":
                mt = _TRIP_RE.search(inst.line)
                mult = int(mt.group(1)) if mt else 1
                mb = re.search(r"body=%?([\w.\-]+)", inst.line)
                if mb:
                    children = [mb.group(1)]
            elif inst.op in ("fusion", "call", "map", "reduce",
                             "reduce-window", "sort", "scatter",
                             "select-and-scatter", "all-reduce"):
                children = _CALLED_RE.findall(inst.line)
            elif inst.op == "conditional":
                mb = _BRANCHES_RE.search(inst.line)
                if mb:
                    children = [c.strip().lstrip("%")
                                for c in mb.group(1).split(",")]
            for ch in children:
                if ch in comps:
                    for op, cnt in walk(comps[ch]):
                        out.append((op, cnt * mult))
        memo[comp.name] = out
        return out

    ops = [dataclasses.replace(op, count=cnt) for op, cnt in walk(entry)]
    return CommFootprint(ops, n=n, num_devices=num_devices)


# ---------------------------------------------------------------------------
# structural concurrency: can the lane (DCN) hop and a node (ICI)
# collective of one pipeline step run at the same time?
# ---------------------------------------------------------------------------

def _instr_operands(inst: Instr, table: dict[str, str]) -> list[str]:
    """Operand instruction names resolvable in the same computation."""
    return [nm for nm in _operand_names(inst) if nm in table]


def _ancestor_fn(comp: Computation):
    """Memoized transitive-ancestor query over one computation's def-use
    graph.  Edges follow every operand reference, so dependence chains
    routed through tuple / get-tuple-element / bitcast plumbing are
    ancestors too (they are ordinary instructions with operands)."""
    ops_of = {i.name: _instr_operands(i, comp.table) for i in comp.instrs}
    anc_memo: dict[str, frozenset] = {}

    def ancestors(name: str) -> frozenset:
        if name in anc_memo:
            return anc_memo[name]
        out: set[str] = set()
        stack = list(ops_of.get(name, ()))
        while stack:                           # iterative: HLO chains
            cur = stack.pop()                  # can exceed Py recursion
            if cur in out:
                continue
            out.add(cur)
            if cur in anc_memo:
                out |= anc_memo[cur]
            else:
                stack.extend(ops_of.get(cur, ()))
        anc_memo[name] = frozenset(out)
        return anc_memo[name]

    return ancestors


def _independent(ancestors, a: str, b: str) -> bool:
    """True iff neither instruction is a def-use ancestor of the other."""
    return a not in ancestors(b) and b not in ancestors(a)


def collective_concurrency(text: str, *, pod_size: int = 256) -> dict:
    """Verify, per computation, that a cross-pod (DCN) collective and an
    intra-pod (ICI) collective exist with NO data dependence in either
    direction — the structural precondition for the §5 pipeline's overlap
    (XLA's scheduler cannot be forced, but absent a dependence edge it is
    free to run both at once; present one, it never can).

    Returns {"concurrent": bool, "pairs": [...], "per_computation": {...}}
    where each pair is (computation, dcn_instr, dcn_kind, ici_instr,
    ici_kind).  A scan-based pipeline puts both ops in the while-body
    computation; an unrolled bucket schedule puts them straight in the
    entry — both are covered because every computation is examined.
    """
    comps = parse_hlo(text)
    comps.pop("__entry__", None)
    pairs = []
    per_comp: dict[str, dict] = {}
    for cname, comp in comps.items():
        if comp is None:
            continue
        colls = []
        for inst in comp.instrs:
            c = _collective(inst, pod_size)
            if c:
                colls.append((inst, c))
        if not colls:
            continue
        dcn = [(i, c) for i, c in colls if c["dcn"]]
        ici = [(i, c) for i, c in colls if not c["dcn"]]
        per_comp[cname] = {"dcn": len(dcn), "ici": len(ici), "pairs": 0}
        if not dcn or not ici:
            continue
        ancestors = _ancestor_fn(comp)
        for di, dc in dcn:
            for ni, nc in ici:
                if _independent(ancestors, di.name, ni.name):
                    pairs.append((cname, di.name, dc["kind"],
                                  ni.name, nc["kind"]))
                    per_comp[cname]["pairs"] += 1
    return {"concurrent": bool(pairs), "pairs": pairs,
            "per_computation": per_comp}


_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_GTE_INDEX_RE = re.compile(r"index=(\d+)")


def scan_carried_concurrency(text: str, *, pod_size: int = 256) -> dict:
    """Cross-ITERATION overlap inside while-loop (scan) bodies.

    ``collective_concurrency`` demands a dependence-free DCN×ICI pair
    within one computation — the right test when both phases of one
    block are meant to run at once.  A software pipeline can instead
    overlap ACROSS iterations: block b's DCN hop is in flight while
    block b+1's ICI phase runs.  Textually that shape is serial inside
    the body (the DCN op consumes the ICI result), but legitimate
    iff the ICI op never reads the carry element the DCN op produces —
    then iteration t+1's ICI phase needs nothing from iteration t's DCN
    hop and the scheduler may run them concurrently.

    For every while body: for each DCN collective D and ICI collective I
    (direct body instructions), compute the root-tuple positions D
    transitively feeds and the parameter get-tuple-element indices in
    I's ancestry.  Disjoint sets → a scan-carried concurrent pair.  A
    non-tuple carry is treated as a single position 0 (conservative).

    Returns {"concurrent": bool, "pairs": [(body, dcn, dcn_kind, ici,
    ici_kind)]}.
    """
    comps = parse_hlo(text)
    comps.pop("__entry__", None)
    bodies = set()
    for comp in comps.values():
        if comp is None:
            continue
        for inst in comp.instrs:
            if inst.op == "while":
                m = _BODY_RE.search(inst.line)
                if m:
                    bodies.add(m.group(1))
    pairs = []
    for bname in sorted(bodies):
        comp = comps.get(bname)
        if comp is None:
            continue
        colls = [(i, _collective(i, pod_size)) for i in comp.instrs]
        colls = [(i, c) for i, c in colls if c]
        dcn = [(i, c) for i, c in colls if c["dcn"]]
        ici = [(i, c) for i, c in colls if not c["dcn"]]
        if not dcn or not ici:
            continue
        root = next((i for i in comp.instrs if "ROOT" in i.line), None)
        if root is None:
            continue
        params = {i.name for i in comp.instrs if i.op == "parameter"}
        ancestors = _ancestor_fn(comp)

        def carry_positions(name: str) -> set:
            if root.op != "tuple":
                return {0}
            out = set()
            for pos, op_name in enumerate(_operand_names(root)):
                if op_name == name or name in ancestors(op_name):
                    out.add(pos)
            return out

        def gte_indices(name: str) -> set:
            out: set = set()
            for anc in ancestors(name) | {name}:
                inst = next((i for i in comp.instrs if i.name == anc),
                            None)
                if inst is None:
                    continue
                if inst.op == "get-tuple-element" \
                        and set(_operand_names(inst)) & params:
                    m = _GTE_INDEX_RE.search(inst.line)
                    out.add(int(m.group(1)) if m else 0)
                elif inst.op != "get-tuple-element" \
                        and set(_instr_operands(inst, comp.table)) \
                        & params:
                    return set(range(10 ** 6))   # raw param read: all
            return out

        for di, dc in dcn:
            d_pos = carry_positions(di.name)
            for ni, nc in ici:
                if not (gte_indices(ni.name) & d_pos):
                    pairs.append((bname, di.name, dc["kind"],
                                  ni.name, nc["kind"]))
    return {"concurrent": bool(pairs), "pairs": pairs}


# ---------------------------------------------------------------------------
# structural concurrency, collective vs COMPUTE: can the ZeRO-3 prefetch
# all-gather of layer i+1 run under layer i's dot FLOPs?
# ---------------------------------------------------------------------------

def _called_comps(line: str) -> list[str]:
    """Every computation a line references: calls=/condition=/body=/
    to_apply= AND conditional branch_computations={...}."""
    out = _CALLED_RE.findall(line)
    mb = _BRANCHES_RE.search(line)
    if mb:
        out += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
    return out


def _carrier_comps(comps: dict, direct) -> set:
    """Names of computations that transitively contain an instruction for
    which ``direct(inst)`` is true — through while bodies, fusions, calls
    and conditional branches alike."""
    memo: dict[str, bool] = {}

    def has(name: str) -> bool:
        if name in memo:
            return memo[name]
        memo[name] = False                     # cycle guard (HLO is acyclic)
        comp = comps.get(name)
        if comp is None:
            return False
        for inst in comp.instrs:
            if direct(inst) or any(has(ch)
                                   for ch in _called_comps(inst.line)):
                memo[name] = True
                break
        return memo[name]

    return {n for n in comps if n != "__entry__" and has(n)}


_CALLER_OPS = ("while", "fusion", "call", "conditional", "map")


def collective_compute_concurrency(text: str, *, pod_size: int = 256,
                                   coll_kinds=None) -> dict:
    """Verify, per computation, that a collective and a FLOP-carrying
    instruction coexist with NO data dependence in either direction — the
    structural precondition for hiding a ZeRO-3 weight-prefetch
    all-gather under a layer's matmuls (multi-core cluster model: overlap
    must be provable on the graph, not inferred from CPU wall-clock,
    which cannot show the win on shared-memory host devices).

    An instruction "carries" a collective/FLOPs either directly (an
    all-gather / a dot) or by calling into a computation that transitively
    contains one (a fusion of dots; the inner while loop of the pipelined
    per-layer gather).  That nesting matters: the layer scan's body holds
    the prefetch gather as a ``while`` instruction (the AG pipeline) next
    to the current layer's dot fusions — def-use-independent, so XLA may
    overlap them.  A BLOCKING gather chains every dot behind its own
    all-gather, so no independent pair survives — the negative control.

    ``coll_kinds`` restricts which collective kinds count (default: the
    gather-shaped kind the prefetch path is built from).

    Returns {"concurrent": bool, "pairs": [...], "per_computation": {...}}
    with pairs (computation, coll_instr, coll_kind_or_op, compute_instr,
    compute_op).
    """
    if coll_kinds is None:
        coll_kinds = ("all-gather",)
    comps = parse_hlo(text)
    comps.pop("__entry__", None)

    def direct_coll(inst):
        c = _collective(inst, pod_size)
        return bool(c and c["kind"] in coll_kinds)

    def direct_flops(inst):
        return inst.op in ("dot", "convolution")

    coll_comps = _carrier_comps(comps, direct_coll)
    flop_comps = _carrier_comps(comps, direct_flops)

    def carriers(comp, direct, carrier_set):
        out = []
        for inst in comp.instrs:
            if direct(inst):
                out.append(inst)
            elif inst.op in _CALLER_OPS and any(
                    ch in carrier_set
                    for ch in _called_comps(inst.line)):
                out.append(inst)
        return out

    pairs = []
    per_comp: dict[str, dict] = {}
    for cname, comp in comps.items():
        if comp is None:
            continue
        colls = carriers(comp, direct_coll, coll_comps)
        if not colls:
            continue
        compute = carriers(comp, direct_flops, flop_comps)
        per_comp[cname] = {"colls": len(colls), "compute": len(compute),
                           "pairs": 0}
        if not compute:
            continue
        ancestors = _ancestor_fn(comp)
        for ci in colls:
            ckind = (_collective(ci, pod_size) or {}).get("kind", ci.op)
            for fi in compute:
                if fi.name == ci.name:
                    continue                   # one instr carrying both
                if _independent(ancestors, ci.name, fi.name):
                    pairs.append((cname, ci.name, ckind, fi.name, fi.op))
                    per_comp[cname]["pairs"] += 1
    return {"concurrent": bool(pairs), "pairs": pairs,
            "per_computation": per_comp}
