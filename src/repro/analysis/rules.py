"""lanelint layer 1 — the R1–R4 footprint rules over the registry.

Every registered communication cell (``(collective, strategy)`` pair) is
lowered through ``jax.shard_map`` on a small host-device topology grid,
its compiled HLO walked into a :class:`~repro.analysis.footprint.
CommFootprint`, and four static invariants checked (DESIGN.md §12):

  R1  level-disjointness — node-level and lane-level replica groups never
      share an edge: no group may straddle pods without covering the
      whole machine, and a decomposed (lane*) strategy may not fall back
      to whole-machine collectives at all (scalar-sized ops exempt).
  R2  payload conservation — executed wire bytes per level equal the
      closed-form algebra of the registered lowering
      (``comm/costs.py:lowered_wire_volumes``), trip counts included.
  R3  guideline consistency — the volumes the matching cost function
      charges (``comm/costs.py:assumed_volumes``) agree with the lowered
      volumes within the cell's documented consistency bound.  A cost
      model that under- or over-counts its own HLO would rank dispatch
      with fiction.
  R4  overlap shape — pipelined cells must show the §5 scan-carried
      DCN×ICI concurrency structure; the blocking negative control must
      NOT (if it did, the rule would be vacuous — so that is a finding
      against the RULE, reported as ``R4`` on the control cell).

The sweep additionally lowers the train/serve step builders and runs R1
over them (steps compose many cells; their per-level volumes are owned
by the per-cell checks).

Everything jax-touching imports lazily: importing this module must stay
cheap and device-free (the CLI sets up the 8-host-device backend before
any jax import — see ``repro.analysis.lint``).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Optional

from . import footprint as fp
from .diagnostics import ERROR, WARNING, Finding

__all__ = [
    "CellCase", "GRID", "iter_cell_cases", "lower_cell", "check_cell",
    "run_hlo_rules", "check_step_footprint", "run_step_rules",
    "R2_REL_TOL", "R2_ABS_TOL", "SMALL_GLOBAL_BYTES",
]

#: (n, N) topologies every cell is swept over — both factorizations of
#: the 8 host devices with n ≥ 2 AND N ≥ 2 so node and lane levels are
#: both non-degenerate
GRID = ((4, 2), (2, 4))

#: per-chip payload: 1024 f32 elements = 4 KiB — divides every K·n·N
#: split on the grid, so no cell pads and R2 algebra is exact
LOCAL_ELEMS = 1024

#: bucket/block count for cells that take one (explicit, so R2's closed
#: forms see the same K/B the lowering uses)
SWEEP_BLOCKS = 4

R2_REL_TOL = 0.02          # XLA may CSE/fold a few percent of traffic
R2_ABS_TOL = 512.0         # scalar side-channels (quorum denominator)
SMALL_GLOBAL_BYTES = 1024  # R1 scalar exemption (loss pmean, gnorm psum)

#: the communication collectives the cell sweep drives (the registry also
#: carries step/model builders — block_stack, train_step, serve_step —
#: which are swept as STEPS, not cells)
COMM_COLLECTIVES = ("allreduce", "reduce_scatter", "allgather", "alltoall",
                    "moe_route", "scan", "bcast", "reduce", "gather",
                    "scatter", "grad_sync", "prefetch_allgather",
                    "kv_splice")

#: cells that must prove the §5 overlap structure (R4 positive)
PIPELINED_CELLS = frozenset({
    ("allreduce", "lane_pipelined"), ("grad_sync", "lane_pipelined"),
    ("bcast", "lane_pipelined"), ("reduce", "lane_pipelined"),
    ("prefetch_allgather", "lane_pipelined"),
})

#: negative controls that must FAIL the overlap check (pins R4 itself)
R4_CONTROL_CELLS = frozenset({("prefetch_allgather", "blocking")})


@dataclasses.dataclass(frozen=True)
class CellCase:
    """One (collective, strategy) cell at one grid topology."""
    collective: str
    strategy: str
    n: int
    N: int
    payload_bytes: int
    kw: tuple = ()           # sorted kwargs items (hashable)

    @property
    def kwargs(self) -> dict:
        return dict(self.kw)

    @property
    def target(self) -> str:
        return f"{self.collective}/{self.strategy}@n{self.n}xN{self.N}"


def _cell_kwargs(collective: str, strategy: str) -> dict:
    if collective == "grad_sync":
        return {"num_buckets": SWEEP_BLOCKS}
    if collective == "prefetch_allgather" or strategy == "lane_pipelined":
        return {"num_blocks": SWEEP_BLOCKS}
    return {}


def iter_cell_cases(grid: tuple = GRID) -> Iterable[CellCase]:
    """Every registered communication cell × every grid topology."""
    from repro.comm.registry import iter_impls, registered_collectives
    for n, N in grid:
        for coll in registered_collectives():
            if coll not in COMM_COLLECTIVES:
                continue
            for e in iter_impls(coll):
                kw = _cell_kwargs(coll, e.strategy)
                payload = LOCAL_ELEMS * 4
                if coll == "kv_splice":
                    payload = _KV_SMALL_ELEMS * 4
                yield CellCase(coll, e.strategy, n, N, payload,
                               tuple(sorted(kw.items())))


# ---------------------------------------------------------------------------
# lowering one cell to compiled HLO
# ---------------------------------------------------------------------------

_KV_SHAPE = (2, "batch", 128)    # (leaf, slot-sharded batch, feature)
_KV_SMALL_ELEMS = 2 * 1 * 128


def _mesh_topo(n: int, N: int):
    import jax
    from repro.core.lane import LaneTopology
    mesh = jax.make_mesh((N, n), ("pod", "data"))
    return mesh, LaneTopology(node_axes=("data",), lane_axis="pod")


def _sum_leaves(out):
    """One local scalar keeping every array leaf live (no collective is
    dead-code-eliminated; adds zero communication)."""
    import jax
    import jax.numpy as jnp
    acc = jnp.float32(0)
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "dtype"):
            acc = acc + jnp.sum(leaf.astype(jnp.float32))
    return acc


def lower_cell(mesh, topo, case: CellCase) -> str:
    """Compiled (optimized) HLO text of one cell under shard_map."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.comm import CommConfig, LaneComm
    comm = LaneComm(topo, CommConfig(record_selections=False), mesh=mesh)
    p = case.n * case.N
    spec = P((topo.lane_axis, *topo.node_axes))

    if case.collective == "kv_splice":
        L, d = _KV_SHAPE[0], _KV_SHAPE[2]
        big = jax.ShapeDtypeStruct((L, p, d), jnp.float32)
        small = jax.ShapeDtypeStruct((L, 1, d), jnp.float32)

        def f(b, s):
            out = comm.kv_splice(b, small=s, slot=min(3, p - 1),
                                 strategy=case.strategy, **case.kwargs)
            return _sum_leaves(out)

        sm = jax.shard_map(
            f, mesh=mesh,
            in_specs=(P(None, (topo.lane_axis, *topo.node_axes), None),
                      P(None, None, None)),
            out_specs=P(), check_vma=False)
        return jax.jit(sm).lower(big, small).compile().as_text()

    x = jax.ShapeDtypeStruct((LOCAL_ELEMS * p,), jnp.float32)

    def f(v):
        out = getattr(comm, case.collective)(v, strategy=case.strategy,
                                             **case.kwargs)
        return _sum_leaves(out)

    sm = jax.shard_map(f, mesh=mesh, in_specs=spec, out_specs=P(),
                       check_vma=False)
    return jax.jit(sm).lower(x).compile().as_text()


# ---------------------------------------------------------------------------
# the rules
# ---------------------------------------------------------------------------

def _is_decomposed(strategy: str) -> bool:
    return strategy != "native"


def check_r1(case_target: str, foot: fp.CommFootprint, *,
             decomposed: bool,
             small_global_bytes: float = SMALL_GLOBAL_BYTES) -> list:
    """Level-disjointness findings for one footprint."""
    out = []
    for op in foot.mixed():
        if op.result_bytes <= small_global_bytes:
            # scalar control traffic (loss pmean / global-norm psum over
            # the batch product, quorum denominator): latency-only, the
            # bandwidth decomposition R1 protects is not at stake
            continue
        out.append(Finding(
            "R1", case_target,
            f"{op.kind} (comp {op.computation}, {op.name}) straddles pods "
            f"without covering the machine: group_size={op.group_size}, "
            f"{op.result_bytes:.0f}B — node and lane communicators share "
            f"an edge"))
    if decomposed:
        for op in foot.ops:
            if op.level == "global" \
                    and op.result_bytes > small_global_bytes:
                out.append(Finding(
                    "R1", case_target,
                    f"decomposed strategy lowers a whole-machine {op.kind} "
                    f"({op.result_bytes:.0f}B, comp {op.computation}) — "
                    f"the decomposition fell back to a global collective"))
    return out


def _vol_mismatch(got: float, want: float, *, rel: float,
                  abs_tol: float) -> bool:
    return abs(got - want) > max(rel * max(got, want), abs_tol)


def check_r2(case: CellCase, foot: fp.CommFootprint) -> list:
    """Payload conservation: lowered per-level wire == closed-form."""
    from repro.comm.costs import lowered_wire_volumes
    want = lowered_wire_volumes(
        case.collective, case.strategy, n=case.n, N=case.N,
        payload_bytes=case.payload_bytes, **case.kwargs)
    if want is None:
        return []
    got = foot.by_level()
    out = []
    for level in ("node", "lane", "global"):
        w = float(want.get(level, 0.0))
        g = float(got.get(level, 0.0))
        if _vol_mismatch(g, w, rel=R2_REL_TOL, abs_tol=R2_ABS_TOL):
            out.append(Finding(
                "R2", case.target,
                f"{level}-level wire bytes: lowered {g:.0f}, closed-form "
                f"{w:.0f} (payload {case.payload_bytes}B, "
                f"kw {dict(case.kw)}) — the lowering does not move what "
                f"the §3/§5 algebra says it moves"))
    return out


def check_r3(case: CellCase, foot: fp.CommFootprint) -> list:
    """Guideline consistency: cost-model volumes vs lowered volumes."""
    from repro.comm.costs import assumed_volumes
    assumed = assumed_volumes(
        case.collective, case.strategy, n=case.n, N=case.N,
        payload_bytes=case.payload_bytes, **case.kwargs)
    if assumed is None:
        return []                       # cell has no cost model — nothing
    vols, bound = assumed
    got = foot.by_level()
    out = []
    for level, w in vols.items():
        g = (foot.wire() if level == "total"
             else float(got.get(level, 0.0)))
        if w <= 0:
            continue
        if g <= 0:
            out.append(Finding(
                "R3", case.target,
                f"cost model charges {w:.0f}B at the {level} level but "
                f"the lowering moves nothing there — the model prices a "
                f"phase that does not exist"))
            continue
        ratio = max(g / w, w / g)
        if ratio > bound:
            out.append(Finding(
                "R3", case.target,
                f"{level}-level: cost model assumes {w:.0f}B, lowering "
                f"moves {g:.0f}B (ratio {ratio:.2f} > bound {bound:.2f}) "
                f"— dispatch would rank this cell with fiction"))
    return out


def check_r4(case: CellCase, hlo: str, *, expect_overlap: bool) -> list:
    """Overlap shape: §5 pipelined cells must show a DCN×ICI pair that
    can run concurrently — either def-use-independent within one
    computation (both phases of one block at once) or scan-carried (the
    next block's ICI phase is independent of the in-flight DCN hop);
    blocking controls must show neither."""
    within = fp.collective_concurrency(hlo, pod_size=case.n)
    carried = fp.scan_carried_concurrency(hlo, pod_size=case.n)
    concurrent = within["concurrent"] or carried["concurrent"]
    if expect_overlap and not concurrent:
        return [Finding(
            "R4", case.target,
            "pipelined cell shows NO concurrent DCN×ICI collective pair "
            "(neither within-body independence nor scan-carried) — the "
            "§5 overlap structure is gone; every lane hop serializes "
            "behind a node phase")]
    if not expect_overlap and concurrent:
        n_pairs = len(within["pairs"]) + len(carried["pairs"])
        return [Finding(
            "R4", case.target,
            f"blocking negative control shows {n_pairs} concurrent "
            f"DCN×ICI pair(s) — the R4 rule would be vacuous; the "
            f"control must stay strictly serial")]
    return []


def check_cell(case: CellCase, hlo: str) -> list:
    """All applicable rules for one lowered cell."""
    foot = fp.comm_footprint(hlo, n=case.n, num_devices=case.n * case.N)
    findings = []
    findings += check_r1(case.target, foot,
                         decomposed=_is_decomposed(case.strategy))
    findings += check_r2(case, foot)
    findings += check_r3(case, foot)
    key = (case.collective, case.strategy)
    if key in PIPELINED_CELLS:
        findings += check_r4(case, hlo, expect_overlap=True)
    elif key in R4_CONTROL_CELLS:
        findings += check_r4(case, hlo, expect_overlap=False)
    return findings


def run_hlo_rules(grid: tuple = GRID, *, verbose: bool = False) -> list:
    """Lower and check every registered cell over the grid."""
    import repro.comm.impls  # noqa: F401  — populate the registry
    findings = []
    for n, N in grid:
        mesh, topo = _mesh_topo(n, N)
        for case in iter_cell_cases(((n, N),)):
            hlo = lower_cell(mesh, topo, case)
            cf = check_cell(case, hlo)
            findings += cf
            if verbose:
                foot = fp.comm_footprint(hlo, n=n, num_devices=n * N)
                lv = {k: round(v, 1)
                      for k, v in foot.by_level().items() if v}
                print(f"  {case.target:42s} {lv} "
                      f"{'FAIL ' + str(len(cf)) if cf else 'ok'}",
                      flush=True)
    return findings


# ---------------------------------------------------------------------------
# step builders: R1 over the composed train/serve lowerings
# ---------------------------------------------------------------------------

def check_step_footprint(name: str, hlo: str, *, n: int,
                         num_devices: int) -> list:
    """R1 over a full step lowering.  Steps compose many cells, so only
    disjointness is checked here (volumes are owned by the cell sweep);
    scalar whole-machine ops (loss pmean, global-norm psum, quorum
    denominator) ride the small-payload exemption."""
    foot = fp.comm_footprint(hlo, n=n, num_devices=num_devices)
    return check_r1(name, foot, decomposed=True)


def run_step_rules(*, verbose: bool = False) -> list:
    """Lower the lane train step and the serve prefill/decode steps on
    the host mesh and run R1 over each."""
    from .steps import iter_step_hlo
    findings = []
    for name, n, p, hlo in iter_step_hlo():
        sf = check_step_footprint(name, hlo, n=n, num_devices=p)
        findings += sf
        if verbose:
            foot = fp.comm_footprint(hlo, n=n, num_devices=p)
            lv = {k: round(v, 1) for k, v in foot.by_level().items() if v}
            print(f"  {name:42s} {lv} "
                  f"{'FAIL ' + str(len(sf)) if sf else 'ok'}", flush=True)
    return findings
