"""Step-builder lowerings for the lanelint step sweep.

The per-cell sweep (``rules.iter_cell_cases``) proves each registered
collective in isolation; this module lowers the COMPOSED surfaces — the
lane train step and the zero3 serving decode/splice — and hands their
compiled HLO to the R1 level-disjointness check.  Volumes are owned by
the cell sweep (a step is a sum of cells), so only disjointness is
checked here; the scalar control traffic a step adds on top of its
cells (loss pmean over the batch product, global-norm psum, the quorum
denominator) rides the small-payload exemption.

Everything is lowered AOT (``.lower(...).compile()``) — nothing runs.
The mesh is the conformance grid's (pod=2, data=2, model=2) host
topology: lane axis "pod", node axes ("data", "model"-adjacent), so the
footprint classifier sees n = 4 chips per pod on 8 devices.
"""
from __future__ import annotations

from typing import Iterable, Tuple

__all__ = ["iter_step_hlo", "train_step_hlo", "serve_step_hlo"]

_ARCH = "llama3.2-3b"
_MESH_SHAPE = (2, 2, 2)
_MESH_AXES = ("pod", "data", "model")


def _mesh():
    import jax
    return jax.make_mesh(_MESH_SHAPE, _MESH_AXES)


def train_step_hlo(gradsync: str) -> Tuple[str, int, int]:
    """(compiled HLO, n, p) of one lane train-step flavor on the host
    grid — built exactly the way launch/train.py builds it, lowered from
    the lane state's own specs."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.configs import resolve
    from repro.configs.base import SHAPES, RunConfig
    from repro.launch.steps import build_train_step_lane, \
        init_lane_train_state
    from repro.models import init_model
    from repro.optim import AdamWConfig

    cfg = resolve(_ARCH, smoke=True)
    mesh = _mesh()
    run = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync=gradsync)
    opt = AdamWConfig()
    step, comm = build_train_step_lane(cfg, run, opt, mesh, None)
    params = init_model(jax.random.PRNGKey(0), cfg)
    st = init_lane_train_state(cfg, run, mesh, params, comm=comm)
    dspec = P(("pod", "data"))
    sm = jax.shard_map(step, mesh=mesh,
                      in_specs=(st.pspecs, st.ospecs, dspec, dspec, None),
                      out_specs=(P(), st.pspecs, st.ospecs),
                      check_vma=False)
    shape = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    toks = jax.ShapeDtypeStruct((8, 8), jnp.int32)
    hlo = jax.jit(sm).lower(shape(st.params), shape(st.opt_state),
                            toks, toks, None).compile().as_text()
    p = _MESH_SHAPE[0] * _MESH_SHAPE[1] * _MESH_SHAPE[2]
    return hlo, p // _MESH_SHAPE[0], p


def serve_step_hlo() -> Iterable[Tuple[str, str, int, int]]:
    """(name, compiled HLO, n, p) of the zero3 serving surfaces via the
    hosting's own ``debug_lower`` AOT hook."""
    import jax

    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve.steps import build_serve_step

    cfg = resolve(_ARCH, smoke=True)
    mesh = _mesh()
    step = build_serve_step(cfg, max_seq=64, slots=8,
                            hosting="lane_zero3", mesh=mesh)
    params = init_model(jax.random.PRNGKey(0), cfg)
    p = _MESH_SHAPE[0] * _MESH_SHAPE[1] * _MESH_SHAPE[2]
    n = p // _MESH_SHAPE[0]
    for name, hlo in sorted(step.debug_lower(params).items()):
        yield f"serve_step/lane_zero3:{name}", hlo, n, p


def iter_step_hlo() -> Iterable[Tuple[str, int, int, str]]:
    """Every swept step lowering as (target, n, num_devices, hlo)."""
    for gradsync in ("lane_pipelined", "lane_zero3"):
        hlo, n, p = train_step_hlo(gradsync)
        yield f"train_step/{gradsync}", n, p, hlo
    for name, hlo, n, p in serve_step_hlo():
        yield name, n, p, hlo
