"""Structured lint diagnostics: one Finding per violated rule instance.

A finding's identity (``key``) is ``rule:target`` — deliberately free of
line numbers and volatile details, so a baseline entry written once keeps
suppressing the same architectural fact across unrelated edits, while a
NEW violation of the same rule in a different cell/file is never masked.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Finding", "ERROR", "WARNING", "format_findings"]

ERROR = "error"
WARNING = "warning"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    rule:     catalog id ("R1".."R4" footprint rules, "A1".."A4" AST
              rules — DESIGN.md §12).
    severity: "error" fails the lint run; "warning" is reported only.
    target:   stable identity of WHERE — a registry cell
              ("allreduce/lane@n4xN2"), a file-scoped symbol
              ("src/repro/foo.py#lax.psum"), or a step builder.  Never
              contains line numbers (those go in the message) so baseline
              suppressions survive unrelated edits.
    message:  human-readable what/why, with the measured numbers.
    """
    rule: str
    target: str
    message: str
    severity: str = ERROR

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.target}"

    def render(self) -> str:
        return f"{self.severity.upper()} {self.rule} {self.target}: " \
               f"{self.message}"


def format_findings(findings) -> str:
    """Deterministic multi-line report (sorted by key, errors first)."""
    order = sorted(findings,
                   key=lambda f: (f.severity != ERROR, f.key))
    return "\n".join(f.render() for f in order)
