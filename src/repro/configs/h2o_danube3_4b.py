"""h2o-danube-3-4b — dense, llama+mistral mix with sliding-window attention.

[arXiv:2401.16818; unverified]  24L d_model=3840 32H (GQA kv=8)
d_ff=10240 vocab=32000.  SWA ⇒ sub-quadratic ⇒ long_500k runs.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        num_layers=24, d_model=3840, num_heads=32, num_kv_heads=8,
        d_ff=10240, vocab_size=32000, sliding_window=4096,
        rope_theta=10000.0, gated_mlp=True, act="silu")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, sliding_window=16,
        dtype="float32")


register("h2o-danube-3-4b", full, smoke)
