"""qwen1.5-110b — dense GQA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=49152
vocab=152064.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b", family="dense",
        num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=49152, vocab_size=152064, qkv_bias=True,
        rope_theta=1_000_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-110b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=192, vocab_size=256, qkv_bias=True, dtype="float32")


register("qwen1.5-110b", full, smoke)
