"""zamba2-7b — hybrid: Mamba2 backbone + one weight-SHARED attention block.

[arXiv:2411.15242; unverified]  81L d_model=3584 32H (kv=32, i.e. full MHA
in the shared block) d_ff=14336 vocab=32000, ssm_state=64.  The shared
attention+MLP block is applied every 6 Mamba2 layers (13 applications,
3 trailing Mamba2 layers) — weight sharing as published; the concatenated
residual-input trick of the original is simplified to standard residual
insertion (DESIGN.md §4).
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
        hybrid_attn_every=6)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b-smoke", family="hybrid",
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
        hybrid_attn_every=3, dtype="float32")


register("zamba2-7b", full, smoke)
