"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  32L d_model=1536 24H
(GQA kv=8) d_ff=512 vocab=49155, MoE 40e top-8.  40 experts do not divide
the 16-way model axis — the TP-expert layout (d_ff column-sharded) handles
this with no padding experts (DESIGN.md §4).
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155,
        num_experts=40, experts_per_token=8)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=32, vocab_size=256,
        num_experts=10, experts_per_token=3, dtype="float32")


register("granite-moe-3b-a800m", full, smoke)
