"""Config registry: importing this package registers all assigned archs."""
from .base import (ModelConfig, ShapeConfig, RunConfig, SHAPES, resolve,
                   all_archs, cells, register)

# one module per assigned architecture (import = register)
from . import h2o_danube3_4b    # noqa: F401
from . import granite_34b       # noqa: F401
from . import qwen15_110b       # noqa: F401
from . import llama32_3b        # noqa: F401
from . import zamba2_7b         # noqa: F401
from . import dbrx_132b         # noqa: F401
from . import granite_moe_3b    # noqa: F401
from . import mamba2_780m       # noqa: F401
from . import llava_next_mistral_7b  # noqa: F401
from . import whisper_large_v3  # noqa: F401

__all__ = ["ModelConfig", "ShapeConfig", "RunConfig", "SHAPES", "resolve",
           "all_archs", "cells", "register"]
