"""granite-34b — dense code model, GPT-BigCode-style MQA (kv=1).

[arXiv:2405.04324; hf]  88L d_model=6144 48H (GQA kv=1) d_ff=24576
vocab=49152.  LayerNorm + plain-GELU MLP; rotary used in place of the
original learned absolute positions (simplification noted in DESIGN.md).
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-34b", family="dense",
        num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1,
        d_ff=24576, vocab_size=49152,
        norm="layernorm", gated_mlp=False, act="gelu")


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-34b-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
        d_ff=256, vocab_size=256,
        norm="layernorm", gated_mlp=False, act="gelu", dtype="float32")


register("granite-34b", full, smoke)
