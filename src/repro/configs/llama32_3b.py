"""llama3.2-3b — small llama3 dense GQA with tied embeddings.

[hf:meta-llama/Llama-3.2-1B; unverified]  28L d_model=3072 24H (GQA kv=8)
d_ff=8192 vocab=128256.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        d_ff=8192, vocab_size=128256, rope_theta=500_000.0,
        tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, tie_embeddings=True, dtype="float32")


register("llama3.2-3b", full, smoke)
