"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L d_model=6144 48H (GQA kv=8)
d_ff=10752 vocab=100352, MoE 16e top-4.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=10752, vocab_size=100352,
        num_experts=16, experts_per_token=4, rope_theta=500_000.0)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=96, vocab_size=256,
        num_experts=8, experts_per_token=2, dtype="float32")


register("dbrx-132b", full, smoke)
