"""Config system: model configs, input-shape presets, run configs, registry.

Every assigned architecture registers a `ModelConfig` (exact published
numbers) plus a reduced `smoke()` variant of the same family.  Shapes are
the four assigned input-shape presets.  `resolve(arch)` backs the `--arch`
flag of every launcher/benchmark entry point.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                 # 0 for attention-free
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads
    # attention
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention; >0 = SWA width
    rope_theta: float = 10000.0
    # mlp
    gated_mlp: bool = True         # SwiGLU vs plain GELU MLP
    act: str = "silu"
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # ssm (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_conv_width: int = 4
    ssm_groups: int = 1
    # hybrid (Zamba2): one *shared* attention block applied every k layers
    hybrid_attn_every: int = 0
    # encoder-decoder (Whisper): frontend stubbed to frame embeddings
    encoder_layers: int = 0
    encoder_seq: int = 0
    cross_attention: bool = False
    # vlm: stub patch-embedding prefix of this many tokens
    vision_tokens: int = 0
    # numerics
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def ssm_heads(self) -> int:
        return self.d_inner() // self.ssm_head_dim

    # -- parameter count (analytic, for roofline MODEL_FLOPS) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, L = self.d_model, self.d_ff, self.num_layers
        hd, H, K = self.hd(), self.num_heads, self.num_kv_heads
        total = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                  # lm head
        per_attn = d * H * hd + 2 * d * K * hd + H * hd * d
        if self.qkv_bias:
            per_attn += (H + 2 * K) * hd
        per_mlp = (3 if self.gated_mlp else 2) * d * f
        if self.family == "moe":
            E = self.experts_per_token if active_only else self.num_experts
            per_mlp = (3 if self.gated_mlp else 2) * d * f * E + d * self.num_experts
        per_norms = 2 * d
        if self.family == "ssm":
            di, S, Hs = self.d_inner(), self.ssm_state, self.ssm_heads()
            G = self.ssm_groups
            per_layer = (d * (2 * di + 2 * G * S + Hs)    # in_proj
                         + self.ssm_conv_width * (di + 2 * G * S)
                         + 3 * Hs + di                    # A, D, dt_bias, norm
                         + di * d + d)                    # out_proj + ln
            total += L * per_layer
        elif self.family == "hybrid":
            di, S, Hs = self.d_inner(), self.ssm_state, self.ssm_heads()
            G = self.ssm_groups
            per_m = (d * (2 * di + 2 * G * S + Hs)
                     + self.ssm_conv_width * (di + 2 * G * S)
                     + 3 * Hs + di + di * d + d)
            total += L * per_m
            total += per_attn + per_mlp + per_norms       # one shared block
        else:
            total += L * (per_attn + per_mlp + per_norms)
            if self.encoder_layers:
                total += self.encoder_layers * (per_attn + per_mlp + per_norms)
                if self.cross_attention:                  # decoder cross-attn
                    total += L * (per_attn + d)
        total += d                                        # final norm
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """One training/serving cell.

    The communication fields (``gradsync``/``gradsync_buckets``/
    ``fsdp_prefetch``) are the legacy knobs behind
    ``repro.comm.CommConfig.from_run`` — the valid ``gradsync`` values
    are whatever the repro.comm registry has registered (this docstring
    is completed from the registry at import time, so new registrations
    are self-documenting):

    gradsync strategies: {gradsync_strategies}

    ``plan`` names a dry-run sharding PLAN ("default" | "tp0" — which
    axes join the batch product, launch/dryrun.py), NOT a gradsync
    strategy; the two used to share the ``gradsync`` field, which
    bypassed the registry validation below.
    """
    model: ModelConfig
    shape: ShapeConfig
    fsdp: bool = False             # shard params over the data axis too
    remat: str = "none"            # none | full | dots
    # valid values derive from the repro.comm registry — see the class
    # docstring (filled from strategies_for("grad_sync") at import) —
    # and are VALIDATED at construction (__post_init__): an unknown
    # strategy fails here, not three layers down inside a step builder
    gradsync: str = "native"
    # dry-run sharding plan name (launch/dryrun.py); free-form tag, the
    # dryrun layer owns its meaning
    plan: str = "default"
    # gradient-sync bucket count; 0 = cost-model auto (§5 latency/bandwidth
    # crossover, core.costmodel.optimal_num_buckets)
    gradsync_buckets: int = 0
    # lane_zero3 per-layer weight-gather pipeline blocks:
    #   0  = cost-model auto (core.costmodel.optimal_prefetch_blocks)
    #   >0 = that many AG(lane)→AG(node) blocks, one-layer prefetch
    #   -1 = BLOCKING gather (no prefetch; the negative control — layer i's
    #        compute depends on its own all-gather)
    fsdp_prefetch: int = 0
    # lane_zero3 backward re-gather: re-run each layer's weight gather in
    # the backward under remat so backward residuals stay 1/p + 1 layer
    # instead of L·D per chip (models/blockstack.ShardedStack.regather)
    fsdp_regather: bool = False
    scan_layers: bool = True
    microbatch: int = 0            # 0 = no grad accumulation
    # microbatch gradient-accumulation precision (honored by the GSPMD
    # dryrun step AND the lane step builders): "float32" is parity-exact,
    # "bfloat16" halves the accumulator's HBM residency
    accum_dtype: str = "float32"
    # tensor parallelism over the mesh's "model" axis: MLP activation
    # collectives (allgather fwd+bwd) run through (collective, strategy)
    # cells of a model-axis LaneComm (models/layers.mlp_tp); 1 = off.
    # The mesh's "model" axis size must equal this degree.
    model_parallel: int = 1
    # expert parallelism for MoE families: token routing dispatch/combine
    # as the paper's decomposed alltoall over the BATCH axes ("moe_route"
    # cells) — every chip owns E/p experts; under lane_zero3 the expert
    # weights live in a never-gathered (L, E/p, ...) local master
    expert_parallel: bool = False
    # capacity-dim software pipelining depth of the routing alltoall
    # (moe_block_ep): >1 splits the C dim so block j+1's dispatch
    # alltoall overlaps block j's expert FFN; 1 = sequential
    ep_blocks: int = 1
    # serving
    decode_seq_shard: bool = True  # shard KV cache seq dim over model axis

    def __post_init__(self):
        if self.accum_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"accum_dtype must be 'float32' or 'bfloat16', got "
                f"{self.accum_dtype!r}")
        # registry-derived validation: dryrun used to smuggle plan names
        # through this field, silently skipping the check every other
        # consumer relied on.  Union of the grad_sync and train_step
        # tables (a strategy may register only a step builder); "auto"
        # is meta — it dispatches per call, so it has no grad_sync cell
        from repro.comm import strategies_for
        valid = dict.fromkeys((*strategies_for("grad_sync"),
                               *strategies_for("train_step"), "auto"))
        if self.gradsync not in valid:
            raise ValueError(
                f"unknown gradsync strategy {self.gradsync!r}; registered "
                f"strategies: {tuple(valid)} (plan names belong in "
                f"RunConfig.plan)")
        if self.model_parallel < 1:
            raise ValueError(
                f"model_parallel must be >= 1, got {self.model_parallel}")
        if self.ep_blocks < 1:
            raise ValueError(
                f"ep_blocks must be >= 1, got {self.ep_blocks}")
        if self.model_parallel > 1 \
                and self.gradsync in ("lane_zero1", "lane_quorum"):
            # zero1's bucket-major flat shard has no model-axis assembly
            # mask, and the quorum rescale math assumes batch-only axes
            raise ValueError(
                f"model_parallel > 1 is not supported with gradsync="
                f"{self.gradsync!r} (use native/lane/lane_zero3)")
        if self.expert_parallel:
            if getattr(self.model, "num_experts", 0) < 1:
                raise ValueError(
                    f"expert_parallel needs a MoE model (family "
                    f"{self.model.family!r} has no experts)")
            if self.gradsync == "lane_quorum":
                # a masked pod still sits on the routing alltoall's wire;
                # degraded-quorum EP routing is future work
                raise ValueError(
                    "expert_parallel is not supported with "
                    "gradsync='lane_quorum'")


def _fill_rundoc() -> None:
    """Complete RunConfig's docstring from the live registry (satellite:
    valid-strategy lists are DERIVED, never hard-coded)."""
    if not RunConfig.__doc__:        # stripped under python -OO
        return
    from repro.comm import strategies_for
    names = " | ".join((*strategies_for("grad_sync"), "auto"))
    RunConfig.__doc__ = RunConfig.__doc__.format(gradsync_strategies=names)


_fill_rundoc()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_SMOKE: dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str, full: Callable[[], ModelConfig],
             smoke: Callable[[], ModelConfig]) -> None:
    _REGISTRY[arch_id] = full
    _SMOKE[arch_id] = smoke


def resolve(arch_id: str, smoke: bool = False) -> ModelConfig:
    import repro.configs as _  # ensure arch modules imported  # noqa: F401
    table = _SMOKE if smoke else _REGISTRY
    if arch_id not in table:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(table)}")
    return table[arch_id]()


def all_archs() -> list[str]:
    import repro.configs as _  # noqa: F401
    return sorted(_REGISTRY)


def cells(arch_id: str) -> list[str]:
    """The shape presets this arch runs (long_500k only if sub-quadratic)."""
    cfg = resolve(arch_id)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out
