"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres patch prefix.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  32L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=32000.  The vision tower is a STUB per spec:
input_specs() supplies precomputed patch embeddings (576 tokens, one
24×24 CLIP grid) which pass through a learned projector; seq_len counts
the full backbone sequence (vision prefix + text).
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, vision_tokens=576)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b-smoke", family="vlm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=256, vision_tokens=8, dtype="float32")


register("llava-next-mistral-7b", full, smoke)
