"""mamba2-780m — pure SSM (attention-free), SSD dual form.

[arXiv:2405.21060; unverified]  48L d_model=1536 (attn-free) d_ff=0
vocab=50280, ssm_state=128.  d_inner = 2·d = 3072, head_dim 64 ⇒ 48 heads.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
        tie_embeddings=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=256,
        ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=8,
        tie_embeddings=True, dtype="float32")


register("mamba2-780m", full, smoke)
