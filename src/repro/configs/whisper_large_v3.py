"""whisper-large-v3 — encoder-decoder audio backbone, conv frontend STUB.

[arXiv:2212.04356; unverified]  Decoder 32L d_model=1280 20H (kv=20, MHA)
d_ff=5120 vocab=51866; encoder 32L over 1500 stub frame embeddings
(the conv1d+log-mel frontend is stubbed per spec — input_specs() provides
precomputed frame embeddings).  LayerNorm + GELU, QKV bias, cross-attn in
every decoder layer.  Decode shapes lower the DECODER serve_step.
"""
from .base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3", family="audio",
        num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
        d_ff=5120, vocab_size=51866, qkv_bias=True,
        norm="layernorm", gated_mlp=False, act="gelu",
        encoder_layers=32, encoder_seq=1500, cross_attention=True)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        d_ff=128, vocab_size=256, qkv_bias=True,
        norm="layernorm", gated_mlp=False, act="gelu",
        encoder_layers=2, encoder_seq=16, cross_attention=True,
        dtype="float32")


register("whisper-large-v3", full, smoke)
