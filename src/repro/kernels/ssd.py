"""Pallas TPU kernel for the Mamba2 SSD chunked scan (arXiv:2405.21060).

Grid: (batch, head_block, chunk) with the chunk dimension sequential
("arbitrary") — the (heads_blk, P, S) recurrent state lives in VMEM scratch
across chunks, so HBM sees each x/B/C element exactly once (the kernel is
bandwidth-optimal; the lax reference rematerializes inter-chunk states
through HBM).  Within a chunk the intra-chunk quadratic term runs on the
MXU per head with (Q × Q) tiles.

Layout: head-major (B, H, T, P) / (B, T, S) with Q (chunk length) a
multiple of 8 sublanes and P, S multiples of 128 lanes where possible.

Validated with interpret=True against kernels/ref.py::ssd_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_s, *,
            nheads_blk: int, chunk: int, nchunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    x = x_ref[0].astype(jnp.float32)          # (hb, Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (hb, Q)
    A = a_ref[0].astype(jnp.float32)          # (hb,)
    Bm = b_ref[0].astype(jnp.float32)         # (Q, S)   (group-shared)
    Cm = c_ref[0].astype(jnp.float32)         # (Q, S)

    da = dt * A[:, None]                      # (hb, Q), ≤ 0
    cum = jnp.cumsum(da, axis=1)              # within-chunk decay
    seg_end = cum[:, -1]                      # (hb,)

    # intra-chunk: scores[h,q,t] = (C[q]·B[t]) e^{cum_q - cum_t} dt_t (q≥t)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    diff = cum[:, :, None] - cum[:, None, :]                       # (hb,Q,Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where((qi >= ti)[None], jnp.exp(diff), 0.0)            # (hb,Q,Q)
    scores = cb[None] * L * dt[:, None, :]                         # (hb,Q,Q)
    y = jax.lax.dot_general(scores, x, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)    # (hb,Q,P)

    # inter-chunk: y += (C[q] · state_prev) e^{cum_q}
    state = state_s[...]                                           # (hb,P,S)
    yin = jax.lax.dot_general(Cm, state, (((1,), (2,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (Q,hb,P)
    y = y + jnp.transpose(yin, (1, 0, 2)) * jnp.exp(cum)[:, :, None]

    # state update: S' = e^{seg_end} S + Σ_t e^{seg_end - cum_t} dt_t x_t B_t
    w = jnp.exp(seg_end[:, None] - cum) * dt                       # (hb,Q)
    xw = x * w[:, :, None]                                         # (hb,Q,P)
    upd = jax.lax.dot_general(xw, Bm, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (hb,P,S)
    state_s[...] = state * jnp.exp(seg_end)[:, None, None] + upd

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_tpu(x, dt, A, B, C, *, chunk: int = 64, heads_blk: int = 8,
            interpret: bool = False):
    """x: (b, H, T, P); dt: (b, H, T); A: (H,); B, C: (b, T, S) (G=1).

    Returns y: (b, H, T, P).  T must divide by `chunk`, H by `heads_blk`.
    """
    b, H, T, P = x.shape
    S = B.shape[-1]
    if T % chunk or H % heads_blk:
        raise ValueError(
            f"seq len {T} must divide by chunk={chunk} and heads {H} by "
            f"heads_blk={heads_blk}")
    nc = T // chunk
    nhb = H // heads_blk

    # reshape for blocking: x (b, nhb, hb, nc, Q, P) via index maps instead
    kernel = functools.partial(_kernel, nheads_blk=heads_blk, chunk=chunk,
                               nchunks=nc)
    dt3 = dt.reshape(b, H, T)
    return pl.pallas_call(
        kernel,
        grid=(b, nhb, nc),
        in_specs=[
            pl.BlockSpec((1, heads_blk, chunk, P),
                         lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, heads_blk, chunk),
                         lambda bi, hi, ci: (bi, hi, ci)),
            pl.BlockSpec((1, heads_blk), lambda bi, hi, ci: (0, hi)),
            pl.BlockSpec((1, chunk, S), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, S), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, heads_blk, chunk, P),
                               lambda bi, hi, ci: (bi, hi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b, H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((heads_blk, P, S), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt3, A[None], B, C)
