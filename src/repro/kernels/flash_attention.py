"""Pallas TPU flash attention (causal / sliding-window / GQA).

TPU-native design (not a CUDA port): the kernel is gridded
(batch, q_head, q_block, k_block) with the k_block dimension marked
"arbitrary" (sequential) so the online-softmax state lives in VMEM scratch
across k steps; q/k/v tiles are stage d through VMEM by BlockSpecs sized to
the MXU (block_q × head_dim and block_k × head_dim tiles, 128-aligned).
Out-of-band (causal / window) k blocks are skipped with @pl.when before any
MXU work — the FLOP savings the lax path can't express.

Validated on CPU with interpret=True against kernels/ref.py; on TPU call
through kernels/ops.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
            scale: float, causal: bool, window: int,
            block_q: int, block_k: int, nk: int, seq_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q_start = qi * block_q
    k_start = ki * block_k
    # whole-block skip: out-of-band (causal future / pre-window) k blocks
    # never touch the MXU — the structural FLOP win over the lax path
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_k > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window:
            mask = jnp.logical_and(mask, kpos >= qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_s[:, 0]                               # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_cur[:, None])
        corr = jnp.exp(m_prev - m_cur)
        l_new = l_s[:, 0] * corr + p.sum(axis=1)
        l_s[...] = jnp.broadcast_to(l_new[:, None], l_s.shape)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_s[...] = acc_s[...] * corr[:, None] + pv
        m_s[...] = jnp.broadcast_to(m_cur[:, None], m_s.shape)

    @pl.when(ki == nk - 1)
    def _final():
        l = l_s[:, 0]
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False):
    """q: (B, H, Tq, hd); k, v: (B, K, Tk, hd); H = K·G.  Returns like q.

    Head-major layout (B, H, T, hd) so each grid cell owns one (head,
    q-block) tile — the natural TPU layout (lane dim = hd, sublane = seq).
    """
    B, H, Tq, hd = q.shape
    K, Tk = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)

    def _divisor(T, target):
        b = min(target, T)
        while T % b:        # Pallas clamps out-of-range blocks (index
            b -= 1          # remapping would corrupt position masking)
        return b

    block_q = _divisor(Tq, block_q)
    block_k = _divisor(Tk, block_k)
    nq = Tq // block_q
    nk = Tk // block_k

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk, seq_k=Tk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 128), jnp.float32),   # running max
            pltpu.VMEM((block_q, 128), jnp.float32),   # running denom
            pltpu.VMEM((block_q, hd), jnp.float32),    # accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
