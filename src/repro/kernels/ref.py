"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive softmax attention.  q: (B,H,Tq,hd); k,v: (B,K,Tk,hd)."""
    B, H, Tq, hd = q.shape
    K, Tk = k.shape[1], k.shape[2]
    G = H // K
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) / math.sqrt(hd),
                   kf)
    qpos = jnp.arange(Tq)[:, None]
    kpos = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos >= qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return out.astype(q.dtype)


def ssd_ref(x, dt, A, B, C):
    """Sequential SSD recurrence (the definitionally-correct form).

    x: (b,H,T,P); dt: (b,H,T); A: (H,); B,C: (b,T,S).  Returns (b,H,T,P).
    state_t = e^{dt_t A} state_{t-1} + dt_t x_t ⊗ B_t;  y_t = C_t · state_t
    """
    b, H, T, P = x.shape
    S = B.shape[-1]

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs            # (b,H,P), (b,H), (b,S), (b,S)
        decay = jnp.exp(dtt * A[None, :])   # (b,H)
        state = (state * decay[..., None, None]
                 + jnp.einsum("bh,bhp,bs->bhps", dtt, xt, Bt))
        y = jnp.einsum("bs,bhps->bhp", Ct, state)
        return state, y

    xs = (jnp.moveaxis(x, 2, 0), jnp.moveaxis(dt, 2, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    state0 = jnp.zeros((b, H, P, S), jnp.float32)
    _, ys = jax.lax.scan(step, state0,
                         jax.tree.map(lambda a: a.astype(jnp.float32), xs))
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)       # (b,H,T,P)
