"""jit'd dispatch wrappers: Pallas kernel on TPU, lax/jnp path elsewhere.

The model code (models/attention.py, models/ssm.py) computes through the
portable lax formulations by default; set REPRO_USE_PALLAS=1 on a TPU
runtime (or =interpret for CPU correctness runs) to route the hot paths
through the kernels.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_tpu
from .ssd import ssd_tpu
from . import ref


def _mode() -> str:
    v = os.environ.get("REPRO_USE_PALLAS", "0").lower()
    if v in ("1", "true", "tpu"):
        return "tpu"
    if v == "interpret":
        return "interpret"
    return "off"


def use_pallas() -> bool:
    m = _mode()
    if m == "tpu":
        return jax.default_backend() == "tpu"
    return m == "interpret"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B,H,Tq,hd); k,v: (B,K,Tk,hd) — head-major convention."""
    if use_pallas():
        return flash_attention_tpu(q, k, v, causal=causal, window=window,
                                   interpret=_mode() == "interpret")
    return ref.attention_ref(q, k, v, causal=causal, window=window)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, B, C, *, chunk: int = 64):
    """x: (b,H,T,P); dt: (b,H,T); A: (H,); B,C: (b,T,S)."""
    if use_pallas():
        hb = 8 if x.shape[1] % 8 == 0 else 1
        return ssd_tpu(x, dt, A, B, C, chunk=chunk, heads_blk=hb,
                       interpret=_mode() == "interpret")
    return ref.ssd_ref(x, dt, A, B, C)
