"""tune-smoke CI leg: probe → persist → round-trip → fit → report.

Runs the whole tuning loop end-to-end on the host-platform 2×4 mesh
(the same topology every other smoke leg uses): probes the auto-
eligible grad_sync/allreduce cells at the reduced ladder, commits the
TimingTable to ``tuning_cache.json`` (the artifact the gradsync bench
and the driver consume), verifies the cache round-trips BIT-identically
through save → load → save, fits HW constants, and writes the
decomposed-vs-native guideline report to ``BENCH_tuning.json``.

Exit status is the CI verdict: nonzero on a guideline violation above
tolerance, a broken round-trip, or a failed fit.  Schema validation of
the emitted document is the Makefile's next command
(``benchmarks/check_bench_schema.py --tuning-file``), keeping one
schema checker for every BENCH artifact.

Usage: python -m repro.tuning.tune_smoke [--cache PATH] [--out PATH]
           [--reps R] [--tolerance X] [--full-ladder]
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

from repro.tuning.backend import apply_backend_setup

# flags BEFORE the first jax import (see tuning.backend) — 8 host
# devices, factored 2 pods x 4 chips like every other smoke leg
apply_backend_setup("cpu", host_device_count=8)

import jax  # noqa: E402

from repro.core.lane import LaneTopology  # noqa: E402
from repro.tuning.fit import fit_hw  # noqa: E402
from repro.tuning.guideline_report import (  # noqa: E402
    DEFAULT_TOLERANCE, build_report,
)
from repro.tuning.probe import (  # noqa: E402
    DEFAULT_LADDER, SMOKE_LADDER, probe_cells,
)
from repro.tuning.store import (  # noqa: E402
    load_timing_table, save_timing_table,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cache", default="tuning_cache.json",
                    help="timing-cache artifact to write")
    ap.add_argument("--out", default="BENCH_tuning.json",
                    help="guideline report to write")
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    ap.add_argument("--full-ladder", action="store_true",
                    help="probe the full payload ladder (default: smoke)")
    args = ap.parse_args(argv)

    mesh = jax.make_mesh((2, 4), ("pod", "data"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    ladder = DEFAULT_LADDER if args.full_ladder else SMOKE_LADDER
    table = probe_cells(mesh, topo, ladder=ladder, reps=args.reps)
    print(f"probed {len(table)} cells on {table.signatures()}")

    # persist + the bit-identical round-trip check: the cache is a pure
    # function of its entries, so save(load(save(T))) == save(T)
    cache = save_timing_table(args.cache, table)
    restored = load_timing_table(cache)
    if restored.to_doc() != table.to_doc():
        print("FAIL: cache round-trip changed the table", flush=True)
        return 1
    second = pathlib.Path(str(cache) + ".roundtrip")
    save_timing_table(second, restored)
    same_bytes = second.read_bytes() == cache.read_bytes()
    second.unlink()
    if not same_bytes:
        print("FAIL: cache bytes not reproducible across save/load/save",
              flush=True)
        return 1
    print(f"cache committed: {cache} ({os.path.getsize(cache)} B, "
          f"round-trip bit-identical)")

    fit = fit_hw(table)
    print(f"fit: alpha_ici={fit.params['alpha_ici']:.3e}s "
          f"alpha_dcn={fit.params['alpha_dcn']:.3e}s "
          f"ici_bw={fit.hw.ici_bw:.3e}B/s dcn_bw={fit.hw.dcn_bw:.3e}B/s "
          f"residual_rms={fit.residual_rms_us:.1f}us "
          f"over {fit.num_cells} cells")

    report = build_report(table, tolerance=args.tolerance, fit=fit)
    pathlib.Path(args.out).write_text(json.dumps(report, indent=2))
    for c in report["cells"]:
        mark = "OK " if c["status"] == "ok" else "VIOLATION"
        print(f"  {mark} {c['collective']:10s} {c['payload_bytes']:>9d}B "
              f"native={c['native_us']:9.1f}us best "
              f"{c['best_strategy']:15s}={c['best_decomposed_us']:9.1f}us "
              f"ratio={c['ratio']:.2f}")
    print(f"wrote {args.out}: {len(report['cells'])} cells, "
          f"{report['violations']} violation(s), ok={report['ok']}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
