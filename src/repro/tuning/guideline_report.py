"""Guideline report: measured decomposed-vs-native verdicts per cell.

The paper's self-consistent performance guidelines say when a
decomposed (lane) algorithm should beat the native one.  With a
measured TimingTable in hand we can stop asserting that from the model
and simply CHECK it: for every (collective, payload-bucket) cell that
has both a native measurement and at least one decomposed measurement,
compare the best decomposed median against the native median.

A cell is a **violation** when the best decomposed time exceeds
``tolerance ×`` native — i.e. decomposition did not just fail to win,
it actively cost more than the tolerance allows.  On a shared-memory
CPU backend the decomposed algorithms pay real pure overhead (there is
no second network level to exploit), so the smoke leg runs with a
loose tolerance; on real multi-NIC topologies the tolerance should be
≈1.  ``beats_native`` records the paper's headline direction per cell.

The emitted document (BENCH_tuning.json) also carries the fitted
HW constants + residuals (:mod:`repro.tuning.fit`) so the report is a
self-contained answer to "what did the machine measure, what constants
explain it, and do the guidelines hold there".
"""
from __future__ import annotations

from typing import Optional

from .fit import FitResult, fit_hw
from .table import TimingTable

__all__ = ["build_report", "DEFAULT_TOLERANCE"]

# CPU smoke default: decomposed emulation overhead on a shared-memory
# "topology" is real but bounded; 4× headroom keeps the CI leg about
# structure (nothing pathological) without pretending a host has lanes.
DEFAULT_TOLERANCE = 4.0


def _cells(table: TimingTable, tolerance: float) -> list:
    by_cell: dict = {}
    for e in table.entries():
        by_cell.setdefault((e.collective, e.topo_sig, e.bucket), []) \
            .append(e)
    cells = []
    for (coll, sig, bucket), entries in sorted(by_cell.items()):
        native = next((e for e in entries if e.strategy == "native"), None)
        decomposed = [e for e in entries if e.strategy != "native"]
        if native is None or not decomposed:
            continue        # nothing to compare in this cell
        best = min(decomposed, key=lambda e: e.median_us)
        ratio = best.median_us / max(native.median_us, 1e-9)
        cells.append({
            "collective": coll,
            "topo_sig": sig,
            "payload_bytes": native.payload_bytes,
            "native_us": round(native.median_us, 2),
            "best_decomposed_us": round(best.median_us, 2),
            "best_strategy": best.strategy,
            "ratio": round(ratio, 4),
            "beats_native": bool(best.median_us < native.median_us),
            "status": "ok" if ratio <= tolerance else "violation",
        })
    return cells


def build_report(table: TimingTable, *,
                 tolerance: float = DEFAULT_TOLERANCE,
                 fit: Optional[FitResult] = None) -> dict:
    """The BENCH_tuning.json document for a measured table.

    ``fit`` defaults to fitting the table in place; pass an existing
    FitResult to avoid refitting (or after installing it via set_hw).
    ``ok`` is the CI verdict: no violations above tolerance.
    """
    if fit is None:
        fit = fit_hw(table)
    cells = _cells(table, tolerance)
    violations = [c for c in cells if c["status"] == "violation"]
    return {
        "topology": list(table.signatures()),
        "tolerance": tolerance,
        "measured_cells": len(table),
        "cells": cells,
        "violations": len(violations),
        "fit": {
            "alpha_ici_s": fit.params["alpha_ici"],
            "alpha_dcn_s": fit.params["alpha_dcn"],
            "ici_bw_Bps": fit.hw.ici_bw,
            "dcn_bw_Bps": fit.hw.dcn_bw,
            "residual_rms_us": round(fit.residual_rms_us, 2),
            "residual_max_us": round(fit.residual_max_us, 2),
            "num_cells": fit.num_cells,
        },
        "ok": not violations,
    }
