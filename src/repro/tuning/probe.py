"""Probe harness: time registered (collective, strategy) cells in place.

Walks the :mod:`repro.comm` registry — the probe grid IS the dispatch
grid: exactly the probe-eligible cells (``ImplEntry.probe_eligible``:
the auto-ranked costed set, plus cells that opt in with
``probe_ok=True`` such as the blocking prefetch negative control) — and
times each one under ``jax.shard_map`` on the live mesh at a ladder of
payload sizes, producing :class:`~repro.tuning.table.TimingTable`
entries keyed the way dispatch will look them up (LOCAL per-chip
payload bytes, the trace-time ``_payload_bytes`` quantity).

Measurement reuses the guideline discipline of
:mod:`repro.core.guidelines`: seeded payloads, warmup discarded,
repetitions separated by ``block_until_ready``; the cache records the
MEDIAN (robust to scheduler hiccups) plus the paper's headline minimum.

Cells already present in the table are skipped — the "once" half of
measure-once-then-commit: a fleet restoring its cache from the
checkpoint directory re-probes only what it has never measured (e.g.
after an elastic restart changed (n, N) and the old signatures went
stale).  ``probe_worklist`` drives the same machinery from a
``Tuner.misses`` list: payloads dispatch actually asked for but the
cache could not answer.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import CommConfig, LaneComm, iter_impls
from repro.core.guidelines import median_us, time_fn_samples

from .table import TimingEntry, TimingTable, payload_bucket, \
    topology_signature

__all__ = ["probe_cells", "probe_worklist", "probeable_collectives",
           "DEFAULT_LADDER", "SMOKE_LADDER"]

# local per-chip payload bytes; the non-smoke top rung (2 MiB) is the
# full gradsync bench's per-chip stripe, the 32 KiB rung its smoke one
DEFAULT_LADDER = (1 << 12, 1 << 15, 1 << 18, 1 << 21)
SMOKE_LADDER = (1 << 12, 1 << 15, 1 << 18)

# out_specs per probeable collective: "local" = each chip keeps its own
# distinct block (reassemble over the axes), "repl" = every chip ends
# with the identical buffer (P() output)
_PROBE_OUT = {
    "grad_sync": "repl",
    "allreduce": "repl",
    "allgather": "repl",
    "reduce_scatter": "local",
    "prefetch_allgather": "repl",
}


def probeable_collectives() -> tuple:
    """The collectives this harness knows how to drive (a subset of the
    registry chosen for having a uniform array→array call shape)."""
    return tuple(_PROBE_OUT)


def _build_cell(mesh, topo, collective: str, strategy: str,
                local_elems: int, cfg: CommConfig):
    """(jitted fn, device payload) timing one cell at one payload."""
    comm = LaneComm(topo, cfg, mesh=mesh)
    n, N = topo.sizes(mesh)
    p = max(n * N, 1)
    spec = P((topo.lane_axis, *topo.node_axes))
    out_spec = spec if _PROBE_OUT[collective] == "local" else P()

    def f(x):
        return getattr(comm, collective)(x, strategy=strategy)

    fn = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=spec,
                               out_specs=out_spec, check_vma=False))
    rng = np.random.default_rng(0)          # seeded payloads, per protocol
    x = rng.normal(size=(local_elems * p,)).astype(np.float32)
    arr = jax.device_put(x, NamedSharding(mesh, spec))
    return fn, arr


def _round_local_elems(local_bytes: int, p: int) -> int:
    """Round the per-chip payload up to a p² multiple of elements so
    every lane/node split divides evenly (the same divisibility
    dispatch's feasible() gates on)."""
    unit = p * p
    return max(unit, (local_bytes // 4 + unit - 1) // unit * unit)


def _probe_one(mesh, topo, e, local_bytes: int, *, table: TimingTable,
               sig: str, cfg: CommConfig, reps: int, warmup: int,
               verbose: bool) -> None:
    """Measure one cell at one ladder rung into ``table`` (idempotent:
    measured and infeasible cells are skipped)."""
    n, N = topo.sizes(mesh)
    p = max(n * N, 1)
    local_elems = _round_local_elems(local_bytes, p)
    payload = local_elems * 4
    if e.feasible is not None and not e.feasible(n, N, local_elems):
        return
    if table.get(e.collective, e.strategy, sig,
                 payload_bucket(payload)) is not None:
        return                  # measured once already — committed
    fn, arr = _build_cell(mesh, topo, e.collective, e.strategy,
                          local_elems, cfg)
    samples = time_fn_samples(fn, arr, reps=reps, warmup=warmup)
    entry = TimingEntry(e.collective, e.strategy, sig, payload,
                        median_us(samples), min(samples), reps)
    table.put(entry)
    if verbose:
        print(f"probe {e.collective:14s} {e.strategy:15s} "
              f"{payload:>9d}B  median={entry.median_us:9.1f}us"
              f"  min={entry.min_us:9.1f}us", flush=True)


def probe_cells(mesh, topo, *, collectives: Optional[tuple] = None,
                ladder: Optional[tuple] = None, reps: int = 5,
                warmup: int = 2, table: Optional[TimingTable] = None,
                verbose: bool = True) -> TimingTable:
    """Time every probe-eligible registered cell of ``collectives`` at
    each ``ladder`` payload (local per-chip bytes) on ``(mesh, topo)``,
    into ``table`` (fresh one by default).  Already-measured cells are
    skipped (measure-once); infeasible cells (divisibility) are skipped
    exactly as dispatch would skip them.  Returns the table."""
    if collectives is None:
        collectives = ("grad_sync", "allreduce", "prefetch_allgather")
    if ladder is None:
        ladder = DEFAULT_LADDER
    if table is None:
        table = TimingTable()
    n, N = topo.sizes(mesh)
    sig = topology_signature(n, N)
    cfg = CommConfig(record_selections=False)
    for coll in collectives:
        if coll not in _PROBE_OUT:
            raise ValueError(
                f"don't know how to probe {coll!r}; probeable: "
                f"{probeable_collectives()}")
        for e in iter_impls(coll):
            if not e.probe_eligible:
                continue
            for local_bytes in ladder:
                _probe_one(mesh, topo, e, local_bytes, table=table,
                           sig=sig, cfg=cfg, reps=reps, warmup=warmup,
                           verbose=verbose)
    return table


def probe_worklist(mesh, topo, misses, *, table: TimingTable,
                   reps: int = 5, warmup: int = 2,
                   verbose: bool = True) -> int:
    """Probe exactly the cells a :class:`~repro.tuning.table.Tuner`
    recorded as cache misses — ``(collective, strategy, n, N,
    payload_bytes)`` tuples, the payloads dispatch actually asked for.

    Misses recorded at a different topology than ``(mesh, topo)``'s are
    skipped (they cannot be measured here), as are collectives the
    harness cannot drive.  Returns the number of cells probed."""
    from repro.comm import has_impl
    from repro.comm.registry import get_impl
    n, N = topo.sizes(mesh)
    sig = topology_signature(n, N)
    cfg = CommConfig(record_selections=False)
    before = len(table)
    for coll, strategy, mn, mN, payload_bytes in dict.fromkeys(
            tuple(m) for m in misses):
        if (int(mn), int(mN)) != (n, N):
            continue            # stale topology — not measurable here
        if coll not in _PROBE_OUT or not has_impl(coll, strategy):
            continue
        _probe_one(mesh, topo, get_impl(coll, strategy),
                   int(payload_bytes), table=table, sig=sig, cfg=cfg,
                   reps=reps, warmup=warmup, verbose=verbose)
    return len(table) - before
