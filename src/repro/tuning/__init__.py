"""repro.tuning — self-tuning dispatch: measured costs over spec sheets.

The §3/§5 cost model's STRUCTURE is the paper's analysis; its CONSTANTS
(``core.costmodel.HW``) were a spec sheet, and BENCH_gradsync showed the
gap (a 68 µs prediction for a 394 µs path).  This subsystem closes the
loop in four parts (DESIGN.md §11):

  probe   (:mod:`.probe`)   time registered (collective, strategy)
                            cells on the live topology → TimingTable
  store   (:mod:`.store`)   JSON+crc32 cache beside the checkpoints;
                            measure once, commit, restore on relaunch
  dispatch (:mod:`.table`)  ``Tuner`` behind ``CommConfig.tuner``:
                            measured cells outrank modelled ones in
                            ``LaneComm.select``; unmeasured cells fall
                            back to the closed form
  fit     (:mod:`.fit`)     least-squares HW constants from the table
                            (ranking forms unchanged, constants real),
                            with residuals in the guideline report
                            (:mod:`.guideline_report` → BENCH_tuning)

:mod:`.backend` owns the per-backend XLA knobs every timing entry point
must apply before its first jax import.
"""
from __future__ import annotations

from .backend import (
    GPU_XLA_FLAGS, HOST_DEVICE_COUNT_FLAG, apply_backend_setup,
    merge_xla_flags, xla_flags_for,
)
from .fit import FitResult, design_row, fit_hw, predicted_us
from .guideline_report import DEFAULT_TOLERANCE, build_report
from .probe import (
    DEFAULT_LADDER, SMOKE_LADDER, probe_cells, probe_worklist,
    probeable_collectives,
)
from .store import (
    DEFAULT_CACHE_NAME, TuningCacheError, load_misses, load_timing_table,
    load_timing_table_or_none, save_timing_table,
)
from .table import (
    TimingEntry, TimingTable, Tuner, parse_topology_signature,
    payload_bucket, topology_signature,
)

__all__ = [
    # table / tuner
    "TimingEntry", "TimingTable", "Tuner", "payload_bucket",
    "topology_signature", "parse_topology_signature",
    # store
    "TuningCacheError", "save_timing_table", "load_timing_table",
    "load_timing_table_or_none", "load_misses", "DEFAULT_CACHE_NAME",
    # probe
    "probe_cells", "probe_worklist", "probeable_collectives",
    "DEFAULT_LADDER", "SMOKE_LADDER",
    # fit
    "FitResult", "fit_hw", "design_row", "predicted_us",
    # report
    "build_report", "DEFAULT_TOLERANCE",
    # backend
    "apply_backend_setup", "xla_flags_for", "merge_xla_flags",
    "GPU_XLA_FLAGS", "HOST_DEVICE_COUNT_FLAG",
]
