"""TimingTable / Tuner — measured collective costs behind auto-dispatch.

The paper's self-consistent performance guidelines are only as honest as
the numbers they are checked against, and ``core/costmodel.py:HW`` runs
on spec-sheet constants (BENCH_gradsync recorded an ``auto`` row
predicting 68 µs for a path that measured 394 µs).  This module is the
data layer of the fix:

  ``TimingTable``  measured medians keyed by
                   ``(collective, strategy, topology-signature,
                   payload-bucket)`` — the probe harness
                   (:mod:`repro.tuning.probe`) fills it, the store
                   (:mod:`repro.tuning.store`) persists it alongside
                   checkpoints, the fitter (:mod:`repro.tuning.fit`)
                   regresses HW constants from it.
  ``Tuner``        the ``CommConfig.tuner`` hook: per-candidate measured
                   cost in seconds, or None for an unmeasured cell so
                   ``LaneComm.select`` falls back to the closed-form
                   model (measure-once-then-commit: misses are recorded
                   so a later probe pass measures exactly what dispatch
                   asked for).

Payloads are keyed on the LOCAL per-chip byte size — the same quantity
``LaneComm._dispatch`` computes at trace time (``_payload_bytes``) — and
bucketed to the enclosing power of two; lookups between probed sizes
interpolate log-log, lookups beyond the probed ladder (past a 2× margin)
miss.  The topology signature folds in platform, device kind and the
(n, N) factorization, so a cache probed on one topology is automatically
stale on another: signatures simply stop matching and dispatch falls
back to the model (no explicit invalidation pass needed).

Everything here is device-free (jax is imported only to default the
platform fields of a signature), so the table/tuner logic is exercised
by plain single-device tier-1 tests.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Iterable, Optional

__all__ = [
    "TimingEntry", "TimingTable", "Tuner", "payload_bucket",
    "topology_signature", "parse_topology_signature",
]

_SIG_RE = re.compile(r"n(\d+)xN(\d+)$")


def payload_bucket(payload_bytes: int) -> int:
    """The enclosing power-of-two bucket of a payload byte size."""
    b = max(int(payload_bytes), 1)
    return 1 << (b - 1).bit_length()


def topology_signature(n: int, N: int, *, platform: Optional[str] = None,
                       device_kind: Optional[str] = None) -> str:
    """``<platform>/<device_kind>/n<n>xN<N>`` — the cache key's topology
    part.  platform/device_kind default to the live jax backend (read
    lazily, so pure table handling never touches a device); a cache
    probed on a different backend or (n, N) factorization therefore
    never matches and dispatch falls back to the closed-form model."""
    if platform is None or device_kind is None:
        import jax
        d = jax.devices()[0]
        platform = platform or d.platform
        device_kind = device_kind or getattr(d, "device_kind", d.platform)
    dk = str(device_kind).replace(" ", "_").replace("/", "_")
    return f"{platform}/{dk}/n{int(n)}xN{int(N)}"


def parse_topology_signature(sig: str) -> tuple:
    """(n, N) back out of a signature (the fitter needs the geometry its
    design rows are built from)."""
    m = _SIG_RE.search(sig)
    if not m:
        raise ValueError(f"malformed topology signature {sig!r}")
    return int(m.group(1)), int(m.group(2))


@dataclasses.dataclass(frozen=True)
class TimingEntry:
    """One measured cell: a (collective, strategy) pair timed at one
    payload size on one topology.  ``payload_bytes`` is the probed LOCAL
    per-chip size (== what ``LaneComm._dispatch`` sees at trace time);
    the cache key buckets it to the enclosing power of two."""
    collective: str
    strategy: str
    topo_sig: str
    payload_bytes: int
    median_us: float
    min_us: float
    reps: int

    @property
    def bucket(self) -> int:
        return payload_bucket(self.payload_bytes)

    @property
    def key(self) -> tuple:
        return (self.collective, self.strategy, self.topo_sig, self.bucket)


class TimingTable:
    """Measured medians keyed by (collective, strategy, topo_sig,
    payload_bucket).  ``put`` keeps the FIRST measurement of a cell
    (measure-once-then-commit — re-probing a committed cell would make
    two runs of the same cache rank differently); ``merge`` folds a
    freshly-probed table into a restored one under the same rule."""

    def __init__(self, entries: Iterable[TimingEntry] = ()):
        self._entries: dict = {}
        for e in entries:
            self.put(e)

    def __len__(self) -> int:
        return len(self._entries)

    def put(self, entry: TimingEntry, *, replace: bool = False) -> bool:
        """Insert one cell; returns False when the cell was already
        measured and ``replace`` is not set (measure-once)."""
        if entry.key in self._entries and not replace:
            return False
        self._entries[entry.key] = entry
        return True

    def get(self, collective: str, strategy: str, topo_sig: str,
            bucket: int) -> Optional[TimingEntry]:
        return self._entries.get((collective, strategy, topo_sig, bucket))

    def entries(self) -> tuple:
        """All cells, deterministically ordered by key."""
        return tuple(self._entries[k] for k in sorted(self._entries))

    def merge(self, other: "TimingTable") -> int:
        """Fold ``other`` in (existing cells win); returns cells added."""
        return sum(self.put(e) for e in other.entries())

    def signatures(self) -> tuple:
        return tuple(sorted({e.topo_sig for e in self._entries.values()}))

    # -- the lookup dispatch prices candidates with -----------------------
    def lookup_us(self, collective: str, strategy: str, topo_sig: str,
                  payload_bytes: int) -> Optional[float]:
        """Median µs for a payload, or None (unmeasured → model fallback).

        Exact probed sizes return their median; sizes between two probed
        points interpolate log-log (collective times are near power laws
        in payload, so log-log linear is the right family); sizes within
        a 2× margin beyond either end scale linearly in bytes off the
        nearest probed point; anything further out is a miss — a cache
        probed at KBs must not be trusted to price GBs.
        """
        pts = sorted(
            (e.payload_bytes, e.median_us)
            for e in self._entries.values()
            if e.collective == collective and e.strategy == strategy
            and e.topo_sig == topo_sig)
        if not pts:
            return None
        b = float(max(int(payload_bytes), 1))
        lo, hi = pts[0], pts[-1]
        if b < lo[0]:
            return lo[1] * b / lo[0] if b >= lo[0] / 2 else None
        if b > hi[0]:
            return hi[1] * b / hi[0] if b <= hi[0] * 2 else None
        for (b0, t0), (b1, t1) in zip(pts, pts[1:]):
            if b0 <= b <= b1:
                if b0 == b1 or b == b0:
                    return t0
                if b == b1:             # exact probed size: verbatim
                    return t1
                w = (math.log(b) - math.log(b0)) \
                    / (math.log(b1) - math.log(b0))
                return math.exp((1 - w) * math.log(max(t0, 1e-9))
                                + w * math.log(max(t1, 1e-9)))
        return pts[0][1]        # single point, b == its payload

    # -- canonical (de)serialization used by the store --------------------
    def to_doc(self) -> list:
        """Key-sorted list of plain dicts — canonical, so the JSON the
        store writes is byte-identical across save→load→save."""
        return [{"collective": e.collective, "strategy": e.strategy,
                 "topo_sig": e.topo_sig, "payload_bytes": e.payload_bytes,
                 "median_us": e.median_us, "min_us": e.min_us,
                 "reps": e.reps} for e in self.entries()]

    @classmethod
    def from_doc(cls, doc: list) -> "TimingTable":
        if not isinstance(doc, list):
            raise ValueError(f"timing-table doc must be a list, got "
                             f"{type(doc).__name__}")
        entries = []
        for i, row in enumerate(doc):
            try:
                entries.append(TimingEntry(
                    collective=str(row["collective"]),
                    strategy=str(row["strategy"]),
                    topo_sig=str(row["topo_sig"]),
                    payload_bytes=int(row["payload_bytes"]),
                    median_us=float(row["median_us"]),
                    min_us=float(row["min_us"]),
                    reps=int(row["reps"])))
            except (KeyError, TypeError, ValueError) as e:
                raise ValueError(f"timing-table row {i} malformed: {e}")
        return cls(entries)


class Tuner:
    """The ``CommConfig.tuner`` hook: measured cost per dispatch cell.

    ``measured_cost`` returns seconds from the timing table or None for
    an unmeasured cell — ``LaneComm.select`` falls back to the §3/§5
    closed form on None, and the miss is recorded on ``self.misses`` so
    a follow-up probe pass (the measure-once-then-commit loop's
    "measure" half) times exactly the cells dispatch actually asked
    for.  A broken or stale table must never take dispatch down, so
    lookups swallow their own errors into a miss.

    platform/device_kind pin the signature side of the key at
    construction (None = read off the live backend on first use); n/N
    arrive per query from the dispatching communicator, which is what
    makes a cache probed at one topology silently stale at another.
    """

    def __init__(self, table: TimingTable, *,
                 platform: Optional[str] = None,
                 device_kind: Optional[str] = None):
        self.table = table
        self._platform = platform
        self._device_kind = device_kind
        self.misses: list = []

    def signature(self, n: int, N: int) -> str:
        sig = topology_signature(n, N, platform=self._platform,
                                 device_kind=self._device_kind)
        if self._platform is None or self._device_kind is None:
            # pin what the lazy default resolved to, so every query of
            # this tuner keys identically even if devices change under us
            head, _, _ = sig.rpartition("/")
            self._platform, self._device_kind = head.split("/", 1)
        return sig

    def measured_cost(self, collective: str, strategy: str, n: int, N: int,
                      payload_bytes: int) -> Optional[float]:
        """Seconds for one candidate cell, or None (unmeasured)."""
        try:
            us = self.table.lookup_us(collective, strategy,
                                      self.signature(n, N), payload_bytes)
        except Exception:
            return None         # a rotten cache must never crash dispatch
        if us is None:
            self.misses.append((collective, strategy, int(n), int(N),
                                int(payload_bytes)))
            return None
        return us * 1e-6
