"""Per-backend XLA setup — the knobs that make measured timings honest.

Timing a collective under XLA only measures what the paper measures if
the compiler is actually allowed to run collectives the way the cost
model assumes: asynchronously, with the latency-hiding scheduler free
to overlap them with compute.  On GPU those are opt-in flags; on CPU
the multi-device topology itself is a flag
(``--xla_force_host_platform_device_count``).  Scattering these across
entry points is how benchmarks silently measure the wrong thing, so
this module owns them as one tested surface: every probe/bench/test
entry point calls :func:`apply_backend_setup` BEFORE its first jax
import, and nothing else touches ``XLA_FLAGS``.

``merge_xla_flags`` is idempotent and override-last: re-running setup
in the same process (or under a harness that pre-seeds XLA_FLAGS)
keeps user-provided flags it does not own and replaces stale values of
the ones it does.
"""
from __future__ import annotations

import os
from typing import MutableMapping, Optional

__all__ = [
    "GPU_XLA_FLAGS", "xla_flags_for", "merge_xla_flags",
    "apply_backend_setup", "HOST_DEVICE_COUNT_FLAG",
]

HOST_DEVICE_COUNT_FLAG = "--xla_force_host_platform_device_count"

# async collectives + the latency-hiding scheduler are the two GPU
# prerequisites of the §5 overlap story; combine-threshold 0 keeps XLA
# from re-fusing the bucketed grad-sync back into one monolithic
# allreduce (which would erase exactly the structure being timed)
GPU_XLA_FLAGS = {
    "--xla_gpu_enable_async_collectives": "true",
    "--xla_gpu_enable_latency_hiding_scheduler": "true",
    "--xla_gpu_all_reduce_combine_threshold_bytes": "0",
}


def xla_flags_for(platform: str, *,
                  host_device_count: Optional[int] = None) -> dict:
    """The XLA flag dict this project owns for ``platform``.

    cpu: the forced host-platform device count (when requested) — the
    only way a single host presents a multi-chip topology to probe.
    gpu: the async-collective/scheduler set above.  tpu: nothing — the
    defaults already run collectives asynchronously.
    """
    platform = platform.lower()
    flags: dict = {}
    if platform == "cpu":
        if host_device_count is not None:
            flags[HOST_DEVICE_COUNT_FLAG] = str(int(host_device_count))
    elif platform == "gpu":
        flags.update(GPU_XLA_FLAGS)
    elif platform != "tpu":
        raise ValueError(f"unknown platform {platform!r} "
                         f"(expected cpu/gpu/tpu)")
    return flags


def merge_xla_flags(existing: str, flags: dict) -> str:
    """Merge ``flags`` into an XLA_FLAGS string, override-last.

    Tokens in ``existing`` whose ``--key`` is owned by ``flags`` are
    dropped (ours win); everything else is preserved in order.  Running
    the merge twice with the same flags is a no-op — entry points may
    call setup unconditionally.
    """
    owned = set(flags)
    kept = [tok for tok in existing.split()
            if tok.split("=", 1)[0] not in owned]
    kept.extend(f"{k}={v}" for k, v in flags.items())
    return " ".join(kept)


def apply_backend_setup(platform: str, *,
                        host_device_count: Optional[int] = None,
                        env: Optional[MutableMapping] = None) -> str:
    """Install this project's XLA flags for ``platform`` into
    ``env["XLA_FLAGS"]`` (default ``os.environ``) and return the final
    string.  MUST run before the process's first ``import jax`` —
    XLA_FLAGS is read once at backend initialization; changing it
    afterwards silently does nothing.
    """
    if env is None:
        env = os.environ
    merged = merge_xla_flags(
        env.get("XLA_FLAGS", ""),
        xla_flags_for(platform, host_device_count=host_device_count))
    env["XLA_FLAGS"] = merged
    return merged
