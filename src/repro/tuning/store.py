"""Persistent tuning cache: JSON + crc32, atomic commit, verified load.

Mirrors the checkpoint store's integrity discipline
(:mod:`repro.checkpoint.store`): the document embeds a crc32 of its
canonically-serialized payload, writes go through a ``.tmp`` →
``os.replace`` commit (a crash mid-write never leaves a half-written
cache where the next launch will read it), and loads re-verify the crc
before a single entry reaches dispatch.  A cache that fails ANY check
raises :class:`TuningCacheError` from the strict loader — and the
dispatch-facing :func:`load_timing_table_or_none` converts every
failure into None, because a rotted tuning cache must degrade a run to
closed-form costs, never crash it.

The canonical serialization (sorted keys, fixed separators) plus the
table's key-sorted ``to_doc`` make the file a pure function of its
entries: save → load → save reproduces the bytes exactly, which is what
lets the cache ride along in the checkpoint directory and be compared
bit-for-bit across restores.
"""
from __future__ import annotations

import json
import pathlib
import zlib
from typing import Optional, Union

from .table import TimingTable

__all__ = [
    "TuningCacheError", "save_timing_table", "load_timing_table",
    "load_timing_table_or_none", "load_misses", "DEFAULT_CACHE_NAME",
]

FORMAT_VERSION = 1
DEFAULT_CACHE_NAME = "tuning_cache.json"    # lives beside the checkpoints


class TuningCacheError(RuntimeError):
    """The tuning cache failed an integrity or schema check: missing
    file, unparseable JSON, crc32 mismatch, unknown format version, or
    a malformed entry row."""


def _canon(payload) -> str:
    return json.dumps(payload, sort_keys=True, separators=(",", ":"))


def save_timing_table(path: Union[str, pathlib.Path],
                      table: TimingTable,
                      misses=None) -> pathlib.Path:
    """Atomically write ``table`` to ``path`` (parents created).

    ``misses`` — optional iterable of ``(collective, strategy, n, N,
    payload_bytes)`` cache-miss tuples accumulated by a
    :class:`~repro.tuning.table.Tuner`; persisted (deduplicated, sorted)
    so the next ``--tune`` launch can re-probe exactly the payloads
    dispatch asked for.  The key is serialized ONLY when non-empty, so a
    miss-free save of a loaded table reproduces the original bytes
    (the byte-identity property above).
    """
    payload = {"version": FORMAT_VERSION, "entries": table.to_doc()}
    rows = sorted(dict.fromkeys(tuple(m) for m in (misses or ())))
    if rows:
        payload["misses"] = [list(r) for r in rows]
    body = _canon(payload)
    doc = {"crc32": zlib.crc32(body.encode("utf-8")), "payload": payload}
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    tmp = p.with_name(p.name + ".tmp")
    tmp.write_text(_canon(doc))
    tmp.replace(p)              # the commit point, same as the ckpt store
    return p


def load_timing_table(path: Union[str, pathlib.Path]) -> TimingTable:
    """Strict load: verify crc32 + version + row schema or raise
    :class:`TuningCacheError` (the probe/driver paths want the real
    reason; dispatch wants :func:`load_timing_table_or_none`)."""
    p = pathlib.Path(path)
    if not p.exists():
        raise TuningCacheError(f"tuning cache {p} does not exist")
    try:
        doc = json.loads(p.read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise TuningCacheError(f"tuning cache {p} unreadable: {e}")
    if not isinstance(doc, dict) or "payload" not in doc \
            or "crc32" not in doc:
        raise TuningCacheError(f"tuning cache {p} missing payload/crc32")
    payload = doc["payload"]
    want = zlib.crc32(_canon(payload).encode("utf-8"))
    if int(doc["crc32"]) != want:
        raise TuningCacheError(
            f"tuning cache {p} failed its crc32 check "
            f"(stored {doc['crc32']}, recomputed {want}) — the file "
            f"rotted or was hand-edited; delete it and re-probe")
    if payload.get("version") != FORMAT_VERSION:
        raise TuningCacheError(
            f"tuning cache {p} has format version "
            f"{payload.get('version')!r}, this build reads "
            f"{FORMAT_VERSION}")
    try:
        return TimingTable.from_doc(payload.get("entries", []))
    except ValueError as e:
        raise TuningCacheError(f"tuning cache {p}: {e}")


def load_misses(path: Union[str, pathlib.Path]) -> list:
    """The persisted cache-miss worklist, as ``(collective, strategy,
    n, N, payload_bytes)`` tuples.  Empty list when the cache is
    missing, corrupt, or carries no ``misses`` key — misses are
    advisory (a re-probe hint), so unlike the entries themselves a
    rotten worklist never blocks a launch."""
    p = pathlib.Path(path)
    if not p.exists():
        return []
    try:
        doc = json.loads(p.read_text())
        payload = doc["payload"]
        if int(doc["crc32"]) != zlib.crc32(_canon(payload).encode("utf-8")):
            return []
        rows = payload.get("misses", [])
        return [(str(c), str(s), int(n), int(N), int(b))
                for c, s, n, N, b in rows]
    except (OSError, ValueError, KeyError, TypeError):
        return []


def load_timing_table_or_none(
        path: Union[str, pathlib.Path]) -> Optional[TimingTable]:
    """Dispatch-facing load: None on ANY failure (missing, corrupt,
    wrong version) — auto-dispatch then runs on the closed-form model,
    which is exactly the no-cache behavior.  The reason is printed once
    so a silently-ignored rotten cache is still visible in logs."""
    try:
        return load_timing_table(path)
    except TuningCacheError as e:
        if pathlib.Path(path).exists():
            print(f"tuning cache ignored: {e}", flush=True)
        return None
