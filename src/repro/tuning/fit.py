"""Least-squares fit of the §3/§5 constants from measured timings.

The closed forms keep doing the RANKING (that is the paper's point —
the structure of the cost model is the analysis), but the constants
(`HW.alpha_ici`/`alpha_dcn` latencies, `ici_bw`/`dcn_bw` bandwidths)
are fitted from the probe's TimingTable instead of a spec sheet.  Every
§3/§5 cost in :mod:`repro.comm.costs` is LINEAR in the four parameters

    x = [alpha_ici, beta_ici, alpha_dcn, beta_dcn]      (beta = s/B)

once the bucket count K is pinned, so each measured cell contributes
one row of an ordinary least-squares system ``A @ x = t``:

  native          rounds·alpha + optimal_vol·beta at the slowest level
  lane            [rounds_node, vol_node, rounds_lane, vol_lane]
                  (node level at ICI, lane level at DCN — klane_time)
  lane_pipelined  (K+S-1)·alpha + (K+S-1)·(stripe/K)·beta at the
                  slowest level, with K resolved the way dispatch
                  resolves it (under the active HW at fit time — the
                  one nonlinearity, pinned rather than fitted)

``fit_hw`` solves the system, clamps the solution to physical ranges
(a CPU-backend fit can go degenerate — shared memory has no DCN), and
reports residuals per cell so BENCH_tuning.json records how well the
paper's forms explain the measured regime.  The fitted constants are
returned as a fresh :class:`~repro.core.costmodel.HW`; installing them
is the caller's explicit step (``core.costmodel.set_hw``) — never a
side effect of fitting, because the bucket/block resolutions feed ZeRO
shard layouts (DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.costmodel import HW, _lg, get_hw, mockup_cost, \
    optimal_num_buckets
from repro.core.pipeline import ALLGATHER_STAGES, ALLREDUCE_STAGES

from .table import TimingTable, parse_topology_signature

__all__ = ["FitResult", "design_row", "fit_hw", "predicted_us"]

_PARAM_NAMES = ("alpha_ici", "beta_ici", "alpha_dcn", "beta_dcn")

# grad_sync is charged as the allreduce it is (same mapping as the
# registry's cost= assignments in repro.comm.impls)
_MOCKUP_COLL = {"grad_sync": "allreduce"}

_ROUND_FACTOR = {"allreduce": 2, "reduce": 2, "bcast": 2, "grad_sync": 2}

_PIPELINE_STAGES = {"grad_sync": ALLREDUCE_STAGES,
                    "allreduce": ALLREDUCE_STAGES,
                    "prefetch_allgather": ALLGATHER_STAGES}


def design_row(collective: str, strategy: str, n: int, N: int,
               c_bytes: float) -> np.ndarray:
    """One least-squares row: coefficients of [alpha_ici, beta_ici,
    alpha_dcn, beta_dcn] such that row @ x = predicted seconds —
    mirroring the corresponding cost function in repro.comm.costs."""
    row = np.zeros(4)
    coll = _MOCKUP_COLL.get(collective, collective)
    p = max(n * N, 1)
    lvl = 2 if N > 1 else 0             # slowest-level param offset
    if strategy == "native":
        rounds = _ROUND_FACTOR.get(collective, 1) * _lg(p)
        row[lvl] = rounds
        row[lvl + 1] = mockup_cost(coll, n, N, c_bytes).optimal_vol
        return row
    if strategy == "lane":
        cost = mockup_cost(coll, n, N, c_bytes)
        row[0] = cost.rounds_node
        row[1] = cost.vol_node
        row[2] = cost.rounds_lane
        row[3] = cost.vol_lane
        return row
    if strategy == "lane_pipelined":
        stages = _PIPELINE_STAGES[collective]
        stripe = c_bytes / max(n, 1) \
            if collective in ("grad_sync", "allreduce") else c_bytes
        hw = get_hw()                   # K pinned under the active HW
        alpha = hw.alpha_dcn if N > 1 else hw.alpha_ici
        beta = 1.0 / (hw.dcn_bw if N > 1 else hw.ici_bw)
        K = max(optimal_num_buckets(stripe, stages=stages, alpha=alpha,
                                    beta=beta), 1)
        waves = K + stages - 1
        row[lvl] = waves
        row[lvl + 1] = waves * stripe / K
        return row
    raise ValueError(
        f"no design row for ({collective!r}, {strategy!r}) — the fitter "
        f"covers the auto-eligible §3/§5 forms")


def predicted_us(collective: str, strategy: str, n: int, N: int,
                 c_bytes: float, hw: HW) -> float:
    """The design row priced under ``hw``, in µs."""
    x = np.array([hw.alpha_ici, 1.0 / hw.ici_bw,
                  hw.alpha_dcn, 1.0 / hw.dcn_bw])
    return float(design_row(collective, strategy, n, N, c_bytes) @ x) * 1e6


@dataclasses.dataclass(frozen=True)
class FitResult:
    """Fitted constants + how well they explain the measurements."""
    hw: HW
    params: dict            # name -> fitted value (post-clamp)
    residual_rms_us: float
    residual_max_us: float
    num_cells: int
    cells: tuple            # per-cell {..., measured_us, fitted_us}


def fit_hw(table: TimingTable, *, topo_sig: str = None,
           alpha_floor: float = 1e-9,
           beta_floor: float = 1e-13) -> FitResult:
    """Least-squares fit over every table cell with a known design row.

    ``topo_sig`` restricts the fit to one topology signature (default:
    all — each entry's (n, N) comes out of its own signature).  The
    solution is clamped to ``alpha_floor``/``beta_floor`` (lstsq happily
    returns negative latencies on a degenerate CPU fit; a cost model
    must stay monotone in payload), and the residuals are computed
    against the CLAMPED parameters — the numbers the report publishes
    are the numbers dispatch would actually be priced with.
    """
    rows, times, meta = [], [], []
    for e in table.entries():
        if topo_sig is not None and e.topo_sig != topo_sig:
            continue
        try:
            n, N = parse_topology_signature(e.topo_sig)
            row = design_row(e.collective, e.strategy, n, N,
                             e.payload_bytes)
        except ValueError:
            continue            # cell outside the fitted §3/§5 forms
        rows.append(row)
        times.append(e.median_us * 1e-6)
        meta.append(e)
    if not rows:
        raise ValueError(
            "fit_hw: no fittable cells in the timing table"
            + (f" for signature {topo_sig!r}" if topo_sig else ""))
    A = np.asarray(rows)
    t = np.asarray(times)
    x, *_ = np.linalg.lstsq(A, t, rcond=None)
    x = np.maximum(x, [alpha_floor, beta_floor, alpha_floor, beta_floor])
    params = dict(zip(_PARAM_NAMES, (float(v) for v in x)))
    hw = dataclasses.replace(
        HW(),
        alpha_ici=params["alpha_ici"], ici_bw=1.0 / params["beta_ici"],
        alpha_dcn=params["alpha_dcn"], dcn_bw=1.0 / params["beta_dcn"])
    fitted = A @ x
    resid_us = (fitted - t) * 1e6
    cells = tuple(
        {"collective": e.collective, "strategy": e.strategy,
         "topo_sig": e.topo_sig, "payload_bytes": e.payload_bytes,
         "measured_us": round(e.median_us, 2),
         "fitted_us": round(float(f) * 1e6, 2)}
        for e, f in zip(meta, fitted))
    return FitResult(
        hw=hw, params=params,
        residual_rms_us=float(np.sqrt(np.mean(resid_us ** 2))),
        residual_max_us=float(np.max(np.abs(resid_us))),
        num_cells=len(meta), cells=cells)
