"""Seeded sampling for the serving tier: replayable by construction.

Every sampled token is a PURE function of ``(seed, rid, position)`` —
the PRNG key is ``fold_in(fold_in(PRNGKey(seed), rid), position)``, so a
request's token stream is independent of how it was batched, which slot
it landed in, which other requests shared the engine, or how many times
the stream is replayed.  That invariance is what the batched-vs-
sequential equivalence tier pins (tests/test_serve.py): greedy AND
sampled serving must be token-identical to decoding each request alone.

The categorical draw is Gumbel-argmax over the (temperature-scaled,
top-p-renormalized) distribution: ``argmax(log p + g)`` with iid Gumbel
``g`` never selects a token with ``p == 0``, so the nucleus property is
structural, not numeric.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = ["SamplerConfig", "top_p_renormalize", "sample_token",
           "request_key"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Sampling hyperparameters + the replay seed.

    temperature <= 0 is exact greedy (argmax — no RNG consumed, so a
    greedy stream is trivially replayable too); top_p = 1.0 disables the
    nucleus filter.
    """
    temperature: float = 1.0
    top_p: float = 1.0
    seed: int = 0

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


GREEDY = SamplerConfig(temperature=0.0)


def request_key(seed: int, rid, position):
    """The (seed, rid, position) key contract — one key per sampled
    token, independent of batching/slot assignment."""
    key = jax.random.PRNGKey(seed)
    key = jax.random.fold_in(key, jnp.asarray(rid, jnp.uint32))
    return jax.random.fold_in(key, jnp.asarray(position, jnp.uint32))


def top_p_renormalize(probs, top_p: float):
    """Nucleus filter: keep the smallest prefix of descending-probability
    tokens whose mass reaches ``top_p``, zero the rest, renormalize.

    The keep rule is exclusive-cumsum < top_p, so the top-1 token is
    always kept (its exclusive cumsum is 0) and the kept mass is the
    minimal prefix covering ``top_p``.  Returns a distribution that sums
    to 1 with exact zeros outside the nucleus (the properties
    tests/test_serve.py pins).
    """
    probs = jnp.asarray(probs, jnp.float32)
    order = jnp.argsort(-probs, axis=-1)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    exclusive = jnp.cumsum(sorted_p, axis=-1) - sorted_p
    kept = jnp.where(exclusive < top_p, sorted_p, 0.0)
    kept = kept / jnp.sum(kept, axis=-1, keepdims=True)
    inv = jnp.argsort(order, axis=-1)
    return jnp.take_along_axis(kept, inv, axis=-1)


def sample_token(logits, sampler: SamplerConfig, rid, position):
    """One token id from one unnormalized logits row (V,).

    Greedy (temperature <= 0): exact argmax.  Otherwise: softmax at
    ``temperature``, nucleus-filter at ``top_p``, and a Gumbel-argmax
    categorical draw keyed by (seed, rid, position) — tokens outside the
    nucleus have log-prob -inf and can never win the argmax.
    """
    logits = jnp.asarray(logits, jnp.float32)
    if sampler is None or sampler.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    probs = jax.nn.softmax(logits / sampler.temperature, axis=-1)
    if sampler.top_p < 1.0:
        probs = top_p_renormalize(probs, sampler.top_p)
    logp = jnp.where(probs > 0, jnp.log(jnp.maximum(probs, 1e-38)),
                     -jnp.inf)
    g = jax.random.gumbel(request_key(sampler.seed, rid, position),
                          logits.shape)
    return jnp.argmax(logp + g, axis=-1).astype(jnp.int32)
