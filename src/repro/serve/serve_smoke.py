import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks
# at first backend init) — this module is a standalone CI entry point.
"""CI leg: the serving tier must actually SERVE, end to end.

Three checks, each a production path rather than a unit:

  * scenario sweep — the registry-derived scenario generator drives the
    continuous batcher over every scenario kind for a bucketed family
    (dense) and an exact-length-prefill family (ssm); every request must
    finish with a recorded reason and a first-token timestamp;
  * checkpoint → serve — a REAL training-driver checkpoint (2 steps,
    native gradsync → replicated layout) restored through
    ``load_serve_params`` must serve a scenario to completion, proving
    the train→serve hand-off path stays wired;
  * zero3 identity — the restored weights served under ``lane_zero3``
    hosting (1/p masters, prefetch-gathered layers, sharded slots,
    kv_splice cache distribution) must produce byte-identical tokens to
    replicated hosting.

The full hosting × family × scenario matrix lives in
``repro.testing.serve_cases`` (run by tier1); this leg is the fast
always-on heartbeat that names a red serving path even when tier1 dies
earlier.

Usage:  python -m repro.serve.serve_smoke   (wired into ``make ci``)
"""
import sys                                                    # noqa: E402
import tempfile                                               # noqa: E402


def _run_scenarios(cfg, params, kinds, *, slots):
    from repro.serve import ContinuousBatcher, make_scenario
    for kind in kinds:
        reqs = make_scenario(cfg, kind=kind, n=5, seed=3, max_seq=96)
        eng = ContinuousBatcher(params, cfg, slots=slots, max_seq=96)
        done, stats = eng.run(reqs)
        if len(done) != len(reqs):
            raise RuntimeError(f"{kind}: {len(done)}/{len(reqs)} done")
        if stats["decode_tokens"] <= 0:
            raise RuntimeError(f"{kind}: no decode tokens")
        for r in done:
            if not r.done or r.finish_reason is None:
                raise RuntimeError(f"{kind}: request {r.rid} unfinished")
            if r.t_first is None:
                raise RuntimeError(f"{kind}: request {r.rid} missing "
                                   f"first-token time")
        print(f"  {cfg.family:6s} {kind:13s} "
              f"{stats['decode_tokens']:4d} tok  "
              f"{stats['tok_per_s']:.1f} tok/s", flush=True)


def main(argv=None) -> int:
    import numpy as np
    import jax
    from repro.configs import resolve
    from repro.launch.train import main as train_main
    from repro.models import init_model
    from repro.serve import (ContinuousBatcher, SCENARIO_KINDS,
                             load_serve_params, make_scenario)

    fails = []

    def _leg(name, fn):
        print(f"=== serve-smoke {name} ===", flush=True)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            fails.append(name)
            print(f"FAIL {name}: {e!r}", flush=True)
        else:
            print(f"PASS {name}", flush=True)

    def _scenarios():
        for arch in ("llama3.2-3b", "mamba2-780m"):
            cfg = resolve(arch, smoke=True)
            params = init_model(jax.random.PRNGKey(0), cfg)
            _run_scenarios(cfg, params, SCENARIO_KINDS, slots=3)

    _leg("scenario_sweep[dense,ssm]", _scenarios)

    def _ckpt_and_zero3():
        cfg = resolve("llama3.2-3b", smoke=True)
        with tempfile.TemporaryDirectory() as td:
            ck = f"{td}/ck"
            rc = train_main(["--arch", "llama3.2-3b", "--smoke",
                             "--batch", "8", "--seq", "32", "--ckpt", ck,
                             "--steps", "2", "--ckpt-every", "2",
                             "--gradsync", "native", "--pods", "2"])
            if rc != 0:
                raise RuntimeError(f"training run failed: rc={rc}")
            params, step = load_serve_params(ck, cfg)
            if step != 2:
                raise RuntimeError(f"loaded step {step}, expected 2")
        reqs = lambda: make_scenario(cfg, kind="short_chat", n=6,  # noqa: E731
                                     seed=7, max_seq=96)
        rep = ContinuousBatcher(params, cfg, slots=2, max_seq=96)
        rep_done, _ = rep.run(reqs())
        if not all(r.done for r in rep_done):
            raise RuntimeError("replicated engine left requests undone")
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()).reshape(2, 2, 2),
            ("pod", "data", "model"))
        z3 = ContinuousBatcher(params, cfg, slots=8, max_seq=96,
                               hosting="lane_zero3", mesh=mesh)
        z3_done, z3_stats = z3.run(reqs())
        if z3_stats["hosting"] != "lane_zero3":
            raise RuntimeError(f"hosting {z3_stats['hosting']!r}, "
                               f"expected lane_zero3")
        a = {r.rid: r.out for r in rep_done}
        b = {r.rid: r.out for r in z3_done}
        if a != b:
            raise RuntimeError(
                f"zero3 ≠ replicated: "
                f"{ {k: (a[k], b[k]) for k in a if a[k] != b[k]} }")
        print(f"  ckpt step {step} → replicated == lane_zero3 over "
              f"{len(a)} requests", flush=True)

    _leg("ckpt_to_serve_zero3_identity[dense]", _ckpt_and_zero3)

    print(f"serve-smoke: {2 - len(fails)}/2 legs OK"
          + (f"; FAILED {fails}" if fails else ""))
    return len(fails)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
