"""Continuous-batching serving engine (fixed-slot, functional caches).

vLLM-style scheduling reduced to its TPU-friendly core: a fixed number of
slots equal to the decode batch; every decode step advances all live slots
in one jitted call; a finished slot is refilled by prefilling the next
request at batch=1 into a length bucket and splicing its KV into the
batched cache at the slot index.  Fixed shapes everywhere ⇒ exactly two
compiled programs (per prefill bucket + one decode), which is what keeps
serving viable across a pod.

For multi-lane serving, the decode cache is sequence-sharded over the
"model" axis (the distributed-LSE decode in models/attention.py) and the
slot-splice is a batch-dim dynamic_update_slice — local to the slot's data
shard, no cross-pod traffic.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import init_cache, prefill, decode_step
from repro.models.transformer import ServeState


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ContinuousBatcher:
    def __init__(self, params, cfg: ModelConfig, *, slots: int,
                 max_seq: int, eos_id: int = -1):
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.max_seq = max_seq
        self.eos_id = eos_id
        dtype = jnp.dtype(cfg.dtype)
        cache = init_cache(cfg, slots, max_seq, dtype=dtype)
        self.state = ServeState(
            cache=cache, length=jnp.zeros((slots,), jnp.int32), enc_kv=None)
        self.live: list[Optional[Request]] = [None] * slots
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(
            lambda p, t, s: decode_step(p, cfg, t, s), donate_argnums=(2,))
        self._prefill = jax.jit(
            lambda p, t, c, n: self._prefill_impl(p, t, c, n),
            static_argnames=())

    # -- single-request prefill into a fresh batch-1 cache -----------------
    def _prefill_impl(self, params, toks, cache1, true_len):
        logits, st = prefill(params, self.cfg, toks, cache1)
        # mask the padded tail: real length decides rope/cache-len
        st = ServeState(cache=st.cache,
                        length=jnp.minimum(st.length, true_len),
                        enc_kv=st.enc_kv)
        return logits, st

    def _splice(self, slot: int, st1: ServeState, first_tok: int):
        """Insert a batch-1 ServeState into the batched state at `slot`."""
        def ins(big, small):
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=self._batch_axis(big))
        # caches: batch dim position differs per family (kv: axis 1)
        self.state = ServeState(
            cache=jax.tree.map(lambda b, s: ins(b, s), self.state.cache,
                               st1.cache),
            length=self.state.length.at[slot].set(st1.length[0]),
            enc_kv=self.state.enc_kv)
        self.tokens = self.tokens.at[slot, 0].set(first_tok)

    def _batch_axis(self, arr) -> int:
        # stacked per-layer caches carry the layer dim first
        return 1 if arr.ndim >= 4 else 0

    def admit(self, slot: int, req: Request) -> None:
        L = int(len(req.prompt))
        b = _bucket(min(L, self.max_seq - req.max_new_tokens))
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = req.prompt[:b]
        cache1 = init_cache(self.cfg, 1, self.max_seq,
                            dtype=jnp.dtype(self.cfg.dtype))
        logits, st1 = self._prefill(self.params, jnp.asarray(toks), cache1,
                                    jnp.full((1,), L, jnp.int32))
        first = int(jnp.argmax(logits[0, -1]))
        req.out.append(first)
        self.live[slot] = req
        self._splice(slot, st1, first)

    def step(self) -> int:
        """One batched decode step; returns #live slots advanced."""
        logits, self.state = self._decode(self.params, self.tokens,
                                          self.state)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        nxt_host = np.asarray(nxt)
        live = 0
        new_tokens = np.asarray(self.tokens).copy()
        for i, req in enumerate(self.live):
            if req is None or req.done:
                continue
            live += 1
            t = int(nxt_host[i])
            req.out.append(t)
            new_tokens[i, 0] = t
            if (t == self.eos_id or len(req.out) >= req.max_new_tokens
                    or int(self.state.length[i]) >= self.max_seq - 1):
                req.done = True
                self.live[i] = None
        self.tokens = jnp.asarray(new_tokens)
        return live

    def run(self, requests: list[Request], *, max_steps: int = 10_000):
        """Drive the queue to completion; returns (requests, stats)."""
        pending = list(requests)[::-1]
        t0 = time.time()
        decoded = 0
        steps = 0
        while steps < max_steps:
            for i in range(self.slots):
                if self.live[i] is None and pending:
                    self.admit(i, pending.pop())
            if not any(self.live) and not pending:
                break
            decoded += self.step()
            steps += 1
        dt = time.time() - t0
        return requests, {"steps": steps, "decode_tokens": decoded,
                          "wall_s": dt,
                          "tok_per_s": decoded / max(dt, 1e-9)}
