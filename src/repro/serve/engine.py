"""Continuous-batching serving engine over registry-resolved serve steps.

The engine is hosting-agnostic: it drives a
:class:`~repro.serve.steps.ServeStep` (``replicated`` or ``lane_zero3``
1/p weight hosting — the cell is resolved from the ``("serve_step", ...)``
registry exactly like the training driver resolves ``("train_step", ...)``)
through the prefill → splice → decode loop and owns only host-side
bookkeeping: slot assignment, admission (bucketed prompt padding),
per-request sampling, termination, and latency accounting.

Correctness contracts pinned by tests/test_serve.py:

  * batched == sequential: greedy continuous batching is token-identical
    to decoding each request alone at batch 1, across slot counts,
    admission orders and mid-stream refills — decode rows are
    independent and prefill is per-request batch-1, so batching is pure
    throughput, never a semantic.
  * seeded replay: with a :class:`~repro.serve.sampling.SamplerConfig`,
    every token is a pure function of (seed, rid, position) — the same
    request replays bit-identically regardless of slot assignment or
    batch composition.
  * admission: a prompt longer than its bucket selects a larger bucket
    (never truncated — the seed engine silently sliced ``prompt[:b]``);
    a request that cannot fit ``prefix + len(prompt) + max_new_tokens``
    inside ``max_seq`` raises ValueError at admit.
  * termination: eos / max_new_tokens / max_seq fire exactly once per
    request and are recorded in ``finish_reason``.

Recurrent families (ssm/hybrid) prefill at the EXACT prompt length —
their state folds in every consumed token, so bucket padding would
contaminate the recurrence; attention families keep bucketed prompts
(bounded compile count) and rely on ``prefill(..., true_len=...)`` to
read logits at the last true position while the padded tail stays dead
behind the length mask.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Any, Optional

import numpy as np

import jax.numpy as jnp

from .sampling import SamplerConfig, sample_token
from .steps import ServeStep, build_serve_step

__all__ = ["Request", "ContinuousBatcher", "termination_reason",
           "DEFAULT_BUCKETS"]

DEFAULT_BUCKETS = (32, 64, 128, 256, 512)

# families whose serving state is a recurrence over every consumed token
# (pad tokens would corrupt it) — prefilled at exact prompt length
_RECURRENT_FAMILIES = ("ssm", "hybrid")


@dataclasses.dataclass
class Request:
    """One serving request (mutated in place by the engine)."""
    rid: Any
    prompt: Any                       # sequence of int token ids
    max_new_tokens: int = 32
    arrival_step: int = 0             # decode step at which it arrives
    extra: Any = None                 # vlm patches / audio frames
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    t_arrival: Optional[float] = dataclasses.field(default=None,
                                                   repr=False)
    t_first: Optional[float] = dataclasses.field(default=None, repr=False)
    t_done: Optional[float] = dataclasses.field(default=None, repr=False)


def termination_reason(token: int, n_out: int, length: int, *,
                       eos_id: int, max_new_tokens: int,
                       max_seq: int) -> Optional[str]:
    """The single termination decision, applied after appending the
    ``n_out``-th generated token (``length`` = cache positions consumed
    so far; the NEXT decode would write at position ``length``).
    Priority: eos, then the request's token budget, then cache capacity.
    Returns None while the request should keep decoding — callers set
    ``finish_reason`` from the first non-None answer and never overwrite
    it, so each reason fires exactly once per request.  (The property
    tests drive this function directly for the capacity branch, which a
    validated admit makes unreachable end-to-end.)"""
    if eos_id >= 0 and token == eos_id:
        return "eos"
    if n_out >= max_new_tokens:
        return "length"
    if length >= max_seq:
        return "max_seq"
    return None


def _int_rid(rid) -> int:
    """Stable uint32 for the sampling key (non-int rids hash via crc32)."""
    if isinstance(rid, (int, np.integer)):
        return int(rid) & 0xFFFFFFFF
    return zlib.crc32(str(rid).encode()) & 0xFFFFFFFF


class ContinuousBatcher:
    """Slot-based continuous batching over one ServeStep.

    params    replicated init_model tree; ``step.prepare`` lays it out
              for the chosen hosting (1/p masters under lane_zero3).
    sampler   None = greedy argmax; a SamplerConfig = seeded temperature/
              top-p sampling keyed by (seed, rid, position).
    hosting   a registered serve_step strategy ("replicated" |
              "lane_zero3"); lane_zero3 needs ``mesh`` and
              slots % chip-count == 0.
    step      inject a prebuilt ServeStep to share jit caches across
              engines (the equivalence tests run batched and sequential
              engines over ONE step).
    """

    def __init__(self, params, cfg, *, slots: int, max_seq: int,
                 eos_id: int = -1, sampler: Optional[SamplerConfig] = None,
                 hosting: str = "replicated", mesh=None,
                 step: Optional[ServeStep] = None,
                 buckets: tuple = DEFAULT_BUCKETS,
                 prefetch_blocks: int = 0, model_parallel: int = 1):
        self.cfg = cfg
        self.slots = int(slots)
        self.max_seq = int(max_seq)
        self.eos_id = int(eos_id)
        self.sampler = sampler
        self.buckets = tuple(sorted(buckets))
        if step is not None:
            if (step.ctx.max_seq, step.ctx.slots) != (self.max_seq,
                                                      self.slots):
                raise ValueError(
                    f"injected step was built for max_seq="
                    f"{step.ctx.max_seq}, slots={step.ctx.slots}; engine "
                    f"wants max_seq={self.max_seq}, slots={self.slots}")
            self.step = step
        else:
            self.step = build_serve_step(
                cfg, max_seq=self.max_seq, slots=self.slots,
                hosting=hosting, mesh=mesh,
                prefetch_blocks=prefetch_blocks,
                model_parallel=model_parallel)
        self.hosted = self.step.prepare(params)
        self.state = self.step.init_state()
        self._active: dict[int, Request] = {}
        self._free = list(range(self.slots))
        self._last_tok = np.zeros((self.slots,), np.int32)
        self._prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
        self._sample_fn = None
        if sampler is not None and not sampler.greedy:
            import jax
            self._sample_fn = jax.jit(
                lambda row, rid, pos: sample_token(row, sampler, rid, pos))

    # -- sampling / termination ------------------------------------------

    def _next_token(self, row: np.ndarray, req: Request) -> int:
        pos = len(req.out)            # 0 = the prefill-produced token
        if self._sample_fn is None:
            return int(np.argmax(row))
        return int(self._sample_fn(jnp.asarray(row, jnp.float32),
                                   jnp.asarray(_int_rid(req.rid),
                                               jnp.uint32),
                                   jnp.asarray(pos, jnp.uint32)))

    def _finish_if_done(self, req: Request, token: int,
                        length: int) -> bool:
        reason = termination_reason(
            token, len(req.out), length, eos_id=self.eos_id,
            max_new_tokens=req.max_new_tokens, max_seq=self.max_seq)
        if reason is None:
            return False
        if req.finish_reason is not None:
            raise RuntimeError(
                f"request {req.rid} finished twice "
                f"({req.finish_reason!r} then {reason!r})")
        req.finish_reason = reason
        req.done = True
        req.t_done = time.perf_counter()
        return True

    # -- admission --------------------------------------------------------

    def _bucket_for(self, L: int) -> int:
        """Prompt pad width: smallest registered bucket >= L (falling
        back to the prompt length itself past the largest bucket), exact
        L for the recurrent families.  Never below L — long prompts
        select a LARGER bucket instead of truncating.  Admission has
        already proven ``prefix + L + max_new_tokens <= max_seq``, so
        the capacity clamp can never push the bucket under L."""
        if self.cfg.family in _RECURRENT_FAMILIES:
            return L
        cap = self.max_seq - self._prefix
        for b in self.buckets:
            if b >= L:
                return min(b, cap)
        return min(max(L, self.buckets[-1]), cap)

    def _extra_embeds(self, req: Request):
        if self.cfg.family not in ("vlm", "audio"):
            return None
        kind = "patch" if self.cfg.family == "vlm" else "frame"
        if req.extra is None:
            raise ValueError(
                f"request {req.rid!r}: family {self.cfg.family!r} needs "
                f"{kind} embeddings in Request.extra")
        x = np.asarray(req.extra, np.float32)
        if x.ndim == 2:
            x = x[None]
        return jnp.asarray(x)

    def admit(self, req: Request, slot: int):
        """Prefill ``req`` at batch 1 and splice its state into ``slot``.
        Produces the first generated token (from the last TRUE prompt
        position).  Raises ValueError when the request cannot fit
        ``max_seq``."""
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        L = int(prompt.shape[0])
        if L == 0:
            raise ValueError(f"request {req.rid!r}: empty prompt")
        need = self._prefix + L + int(req.max_new_tokens)
        if need > self.max_seq:
            raise ValueError(
                f"request {req.rid!r}: prompt length {L}"
                + (f" + {self._prefix} vision tokens"
                   if self._prefix else "")
                + f" + max_new_tokens {req.max_new_tokens} = {need} "
                f"exceeds max_seq={self.max_seq}; shorten the prompt or "
                f"lower max_new_tokens")
        b = self._bucket_for(L)
        if b < L:
            raise RuntimeError(
                f"prefill bucket {b} shorter than prompt length {L}")
        toks = np.zeros((1, b), np.int32)
        toks[0, :L] = prompt          # whole prompt, never sliced
        logits, st1 = self.step.prefill(self.hosted, jnp.asarray(toks), L,
                                        self._extra_embeds(req))
        if req.t_arrival is None:
            req.t_arrival = time.perf_counter()
        t = self._next_token(np.asarray(logits)[0, -1], req)
        req.out.append(t)
        req.t_first = time.perf_counter()
        if self._finish_if_done(req, t, self._prefix + L):
            return
        self.state = self.step.splice(self.state, st1, slot)
        self._active[slot] = req
        self._last_tok[slot] = t

    # -- decode -----------------------------------------------------------

    def step_decode(self) -> int:
        """One batched decode over every slot (idle slots carry garbage
        rows; decode rows are independent so they cannot influence the
        active ones).  Returns the number of tokens appended."""
        tok = jnp.asarray(self._last_tok.reshape(self.slots, 1))
        logits, self.state = self.step.decode(self.hosted, tok, self.state)
        rows = np.asarray(logits)
        lengths = np.asarray(self.state.length)
        produced = 0
        for slot, req in list(self._active.items()):
            t = self._next_token(rows[slot, -1], req)
            req.out.append(t)
            self._last_tok[slot] = t
            produced += 1
            if self._finish_if_done(req, t, int(lengths[slot])):
                del self._active[slot]
                self._free.append(slot)
        return produced

    # -- the serving loop -------------------------------------------------

    def run(self, requests, *, max_steps: int = 10_000):
        """Serve ``requests`` to completion (or ``max_steps`` decode
        steps).  Admission honors ``arrival_step`` (bursty scenarios: a
        request is invisible until the decode-step clock reaches it) and
        otherwise follows submission order.  Returns
        ``(requests, stats)``."""
        pending = list(requests)
        t0 = time.perf_counter()
        steps = 0
        decode_tokens = 0
        while (pending or self._active) and steps < max_steps:
            now = time.perf_counter()
            for r in pending:
                if r.arrival_step <= steps and r.t_arrival is None:
                    r.t_arrival = now
            while self._free and pending:
                nxt = next((r for r in pending
                            if r.arrival_step <= steps), None)
                if nxt is None:
                    break
                pending.remove(nxt)
                slot = self._free.pop(0)
                self.admit(nxt, slot)
                if nxt.done:          # finished on its very first token
                    self._free.insert(0, slot)
            if not self._active:
                steps += 1            # idle tick toward the next arrival
                continue
            decode_tokens += self.step_decode()
            steps += 1
        wall = time.perf_counter() - t0
        stats = {
            "steps": steps,
            "decode_tokens": decode_tokens,
            "wall_s": wall,
            "tok_per_s": decode_tokens / wall if wall > 0 else 0.0,
            "hosting": self.step.hosting,
            "requests": [
                {"rid": r.rid,
                 "tokens": len(r.out),
                 "finish_reason": r.finish_reason,
                 "ttft_ms": None if r.t_first is None or r.t_arrival is None
                 else (r.t_first - r.t_arrival) * 1e3,
                 "latency_ms": None if r.t_done is None or r.t_arrival is None
                 else (r.t_done - r.t_arrival) * 1e3}
                for r in requests],
        }
        return requests, stats
