"""Serving scenario generator, family-keyed through the registry.

Each model family registers one ``("serve_scenario", family)`` cell — a
factory producing deterministic request mixes for the equivalence tests,
the smoke leg and ``benchmarks/serve_bench.py``.  The FAMILY list the
serving tier claims to support is therefore derived
(``scenario_families()``), never hand-maintained — vlm and audio are
serving scenarios (their extras are synthesized here) even though the
training driver cannot train them.

Kinds (``SCENARIO_KINDS``):

  short_chat     short prompts, short outputs, all at step 0
  long_context   prompts spanning several buckets (incl. one straddling
                 a bucket boundary), modest outputs
  bursty         arrival_step waves — slots drain and refill mid-stream
  mixed          long-context + short-chat interleaved, staggered
                 arrivals: the closest thing to production traffic

Every request is a pure function of (family, kind, seed, index):
replaying a scenario replays the byte-identical requests.
"""
from __future__ import annotations

import zlib

import numpy as np

from repro.comm import get_impl, has_impl, register_impl, strategies_for
from repro.configs.base import ModelConfig

from .engine import Request

__all__ = ["SCENARIO_KINDS", "make_scenario", "scenario_families"]

SCENARIO_KINDS = ("short_chat", "long_context", "bursty", "mixed")


def scenario_families() -> tuple:
    """Families the serving tier supports (derived from the registry)."""
    return strategies_for("serve_scenario")


def make_scenario(cfg: ModelConfig, *, kind: str, n: int, seed: int,
                  max_seq: int) -> list:
    """``n`` deterministic Requests for ``cfg.family`` (ValueError on an
    unregistered family or kind)."""
    if kind not in SCENARIO_KINDS:
        raise ValueError(f"unknown scenario kind {kind!r}; one of "
                         f"{SCENARIO_KINDS}")
    if not has_impl("serve_scenario", cfg.family):
        raise ValueError(
            f"no serving scenario for family {cfg.family!r}; registered: "
            f"{scenario_families()}")
    return get_impl("serve_scenario", cfg.family).fn(
        cfg, kind=kind, n=n, seed=seed, max_seq=max_seq)


# ---------------------------------------------------------------------------
# shared request-mix logic (per-family cells only add their extras)
# ---------------------------------------------------------------------------

def _budget(cfg: ModelConfig, max_seq: int) -> int:
    """Positions available to prompt + output (vlm pays its prefix)."""
    prefix = cfg.vision_tokens if cfg.family == "vlm" else 0
    return max_seq - prefix


def _lengths(kind: str, budget: int, n: int,
             rng: np.random.Generator) -> list:
    """(prompt_len, max_new, arrival_step) per request."""
    rows = []
    for i in range(n):
        if kind == "short_chat":
            L = int(rng.integers(3, min(16, budget // 2)))
            out = int(rng.integers(4, 9))
            arrive = 0
        elif kind == "long_context":
            # span buckets: one request pinned to exactly 2/3 of budget,
            # the rest spread wide (incl. > the 32 bucket)
            hi = max(8, budget - 12)
            L = (2 * budget) // 3 if i == 0 else int(rng.integers(8, hi))
            out = int(rng.integers(4, 9))
            arrive = 0
        elif kind == "bursty":
            L = int(rng.integers(3, min(24, budget // 2)))
            out = int(rng.integers(4, 9))
            arrive = 6 * (i // 3)          # waves of 3
        else:  # mixed
            long = i % 3 == 0
            hi = max(9, budget - 12)
            L = int(rng.integers(8, hi)) if long \
                else int(rng.integers(3, 12))
            out = int(rng.integers(4, 13))
            arrive = int(rng.integers(0, 10))
        out = max(1, min(out, budget - L))
        rows.append((max(1, min(L, budget - out)), out, arrive))
    return rows


def _requests(cfg: ModelConfig, *, kind: str, n: int, seed: int,
              max_seq: int, extra_fn=None) -> list:
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, zlib.crc32(kind.encode())]))
    budget = _budget(cfg, max_seq)
    if budget < 8:
        raise ValueError(
            f"max_seq={max_seq} leaves a {budget}-token budget for "
            f"family {cfg.family!r} — too small for a scenario")
    reqs = []
    for i, (L, out, arrive) in enumerate(_lengths(kind, budget, n, rng)):
        prompt = rng.integers(1, cfg.vocab_size, size=L).astype(np.int32)
        reqs.append(Request(
            rid=i, prompt=prompt, max_new_tokens=out, arrival_step=arrive,
            extra=None if extra_fn is None else extra_fn(rng)))
    return reqs


def _register_plain(family: str):
    @register_impl("serve_scenario", family, auto_ok=False)
    def _cell(cfg, *, kind, n, seed, max_seq):
        return _requests(cfg, kind=kind, n=n, seed=seed, max_seq=max_seq)
    return _cell


for _fam in ("dense", "moe", "ssm", "hybrid"):
    _register_plain(_fam)


@register_impl("serve_scenario", "vlm", auto_ok=False)
def _scenario_vlm(cfg, *, kind, n, seed, max_seq):
    """Patch embeddings (vision_tokens, d_model) ride in Request.extra."""
    def patches(rng):
        return rng.standard_normal(
            (cfg.vision_tokens, cfg.d_model)).astype(np.float32) * 0.02
    return _requests(cfg, kind=kind, n=n, seed=seed, max_seq=max_seq,
                     extra_fn=patches)


@register_impl("serve_scenario", "audio", auto_ok=False)
def _scenario_audio(cfg, *, kind, n, seed, max_seq):
    """Frame embeddings (encoder_seq, d_model) ride in Request.extra."""
    def frames(rng):
        return rng.standard_normal(
            (cfg.encoder_seq, cfg.d_model)).astype(np.float32) * 0.02
    return _requests(cfg, kind=kind, n=n, seed=seed, max_seq=max_seq,
                     extra_fn=frames)
