"""Serving steps resolved through the (collective, strategy) registry.

The inference counterpart of ``launch/steps.py``'s train-step table: each
hosting flavor is one ``@register_impl("serve_step", ...)`` cell —

  replicated   every chip holds full weights; prefill/decode are plain
               jits (the single-host baseline, and the only hosting the
               hybrid family supports — its grouped attention cache does
               not fit the flat layer scan).
  lane_zero3   1/p weight hosting: the family's BlockSpec splits the
               params exactly like training (models/blockstack.py), the
               (L, B, p, s) masters stay sharded, and every prefill/
               decode re-gathers layer-by-layer through
               ``comm.prefetch_allgather`` with the one-layer prefetch
               (``scan_stack_cached``) — a pod can serve checkpoints it
               cannot hold replicated.  Decode SLOTS are sharded over
               the global rank (lane-major, matching ``scatter``), the
               batch-1 prefill runs replicated, and the fresh cache is
               distributed into its slot through the ``kv_splice``
               collective (decomposed lane bcast + local splice).

A :class:`ServeStep` is hosting-agnostic to its caller (the engine):
``prepare`` lays the weights out, ``init_state``/``prefill``/``decode``/
``splice`` are the four jitted entry points, and ``collectives`` names
the registry cells the step resolves (what the conformance grid and the
api-surface lock assert).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.comm import CommConfig, LaneComm, get_impl, has_impl, \
    register_impl, strategies_for
from repro.configs.base import ModelConfig
from repro.core import LaneTopology
from repro.models import decode_step, init_cache, prefill
from repro.models.blockstack import (
    ShardedStack, block_stack_spec, resolve_extras_prefetch_blocks,
    resolve_prefetch_blocks, shard_stack,
    split_params,
)
from repro.models.layers import _dtype
from repro.models.transformer import ServeState, _SCANNED_FAMILIES

__all__ = ["ServeContext", "ServeStep", "build_serve_step",
           "serve_hostings", "load_serve_params"]


@dataclasses.dataclass(frozen=True)
class ServeContext:
    """Everything a registered serve-step builder needs.

    slots: decode batch width (lane_zero3: must divide by the mesh's
    chip count — each chip owns a contiguous global-rank block of
    slots).  prefetch_blocks: the ZeRO-3 gather pipeline B (0 =
    cost-model auto, -1 = blocking negative control), mirroring
    ``run.fsdp_prefetch``.  kv_strategy: which registered ``kv_splice``
    cell distributes a fresh prefill into its slot.
    """
    cfg: ModelConfig
    max_seq: int
    slots: int
    mesh: Any = None
    prefetch_blocks: int = 0
    kv_strategy: str = "lane"
    # tensor-parallel degree for the hosted forwards: the mesh's "model"
    # axis (must equal this size) runs the MLP activation allgathers
    # through model-axis (collective, strategy) cells; 1 = off.  zero3
    # hosting only — it composes the 1/p weight gather (batch axes) with
    # the TP activation collectives (model axis), the serving face of
    # the 3D pods × data × model mesh
    model_parallel: int = 1


@dataclasses.dataclass
class ServeStep:
    """One hosting flavor's jitted serving surface (see module docstring).

    prepare(params) -> hosted          lay the replicated tree out
    init_state() -> ServeState         batched (slots) zero state
    prefill(hosted, toks(1,b), true_len, extra=None)
        -> (logits (1,1,V) at the last TRUE position, batch-1 state)
    decode(hosted, tok(slots,1), state) -> (logits (slots,1,V), state)
    splice(state, state1, slot) -> state   write the batch-1 state into
        global slot ``slot`` (a traced int32 array — one compile serves
        every slot)
    collectives: {"weights": (collective, strategy), "kv": ...} — the
        registry cells this step resolves (empty for replicated).
    debug_lower: optional ``(params) -> {name: compiled-HLO text}`` AOT
        hook — lowers the step's jitted surfaces WITHOUT executing them,
        for the lanelint step sweep (``repro.analysis``).  None when the
        hosting has no distributed lowering worth walking (replicated).
    """
    hosting: str
    cfg: ModelConfig
    ctx: ServeContext
    prepare: Callable
    init_state: Callable
    prefill: Callable
    decode: Callable
    splice: Callable
    collectives: dict
    debug_lower: Any = None


def serve_hostings() -> tuple:
    """Registered serve_step hostings, in registration order (the derived
    table benches/tests enumerate)."""
    return strategies_for("serve_step")


def build_serve_step(cfg: ModelConfig, *, max_seq: int, slots: int,
                     hosting: str = "replicated", mesh=None,
                     prefetch_blocks: int = 0,
                     kv_strategy: str = "lane",
                     model_parallel: int = 1) -> ServeStep:
    """Resolve ``hosting`` from the serve_step registry and build."""
    if not has_impl("serve_step", hosting):
        raise ValueError(
            f"unknown serving hosting {hosting!r}; registered: "
            f"{serve_hostings()}")
    if model_parallel > 1 and hosting != "lane_zero3":
        raise ValueError(
            f"model_parallel > 1 needs hosting='lane_zero3' (got "
            f"{hosting!r}); replicated hosting has no mesh to carry the "
            f"'model' axis")
    ctx = ServeContext(cfg=cfg, max_seq=max_seq, slots=slots, mesh=mesh,
                       prefetch_blocks=prefetch_blocks,
                       kv_strategy=kv_strategy,
                       model_parallel=model_parallel)
    return get_impl("serve_step", hosting).fn(ctx)


def _init_serve_state(cfg: ModelConfig, batch: int, max_seq: int):
    """Zero ServeState at the model compute dtype (audio gets a zero
    batched enc_kv buffer the per-request splice fills)."""
    dt = _dtype(cfg)
    cache = init_cache(cfg, batch, max_seq, dtype=dt)
    enc_kv = None
    if cfg.family == "audio":
        K, hd = cfg.num_kv_heads, cfg.hd()
        shape = (cfg.num_layers, batch, cfg.encoder_seq, K, hd)
        enc_kv = {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
    return ServeState(cache=cache, length=jnp.zeros((batch,), jnp.int32),
                      enc_kv=enc_kv)


# every stacked cache leaf — kv (L,B,S,K,hd), the stacked mamba states,
# the hybrid grouped kv (groups,B,S,K,hd), enc_kv (L,B,Te,K,hd) — keeps
# batch at axis 1; length is the single axis-0 exception
_BATCH_AXIS = 1


def _splice_leaf(big, small, slot, axis=_BATCH_AXIS):
    return lax.dynamic_update_slice_in_dim(
        big, small.astype(big.dtype), jnp.asarray(slot, jnp.int32),
        axis=axis)


# ---------------------------------------------------------------------------
# replicated hosting
# ---------------------------------------------------------------------------

@register_impl("serve_step", "replicated", auto_ok=False)
def _serve_replicated(ctx: ServeContext) -> ServeStep:
    cfg = ctx.cfg

    @jax.jit
    def _init():
        return _init_serve_state(cfg, ctx.slots, ctx.max_seq)

    @jax.jit
    def _prefill(params, toks, true_len, extra=None):
        cache1 = init_cache(cfg, 1, ctx.max_seq, dtype=_dtype(cfg))
        return prefill(params, cfg, toks, cache1, extra_embeds=extra,
                       true_len=true_len)

    @partial(jax.jit, donate_argnums=(2,))
    def _decode(params, tok, state):
        return decode_step(params, cfg, tok, state)

    @partial(jax.jit, donate_argnums=(0,))
    def _splice(state, st1, slot):
        cache = jax.tree.map(lambda b, s: _splice_leaf(b, s, slot),
                             state.cache, st1.cache)
        length = lax.dynamic_update_slice(
            state.length, st1.length.astype(state.length.dtype),
            (jnp.asarray(slot, jnp.int32),))
        enc_kv = state.enc_kv
        if state.enc_kv is not None:
            enc_kv = jax.tree.map(lambda b, s: _splice_leaf(b, s, slot),
                                  state.enc_kv, st1.enc_kv)
        return ServeState(cache=cache, length=length, enc_kv=enc_kv)

    return ServeStep(hosting="replicated", cfg=cfg, ctx=ctx,
                     prepare=lambda params: params, init_state=_init,
                     prefill=_prefill, decode=_decode, splice=_splice,
                     collectives={})


# ---------------------------------------------------------------------------
# lane_zero3 hosting (1/p weights, sharded slots)
# ---------------------------------------------------------------------------

@register_impl("serve_step", "lane_zero3", auto_ok=False)
def _serve_zero3(ctx: ServeContext) -> ServeStep:
    from repro.launch.mesh import batch_axes
    from repro.launch.steps import zero3_stack_layouts
    cfg = ctx.cfg
    if ctx.mesh is None:
        raise ValueError("lane_zero3 serving needs a mesh (slots and "
                         "weights are sharded over it)")
    if cfg.family == "hybrid":
        raise ValueError(
            "the hybrid family cannot serve from 1/p-sharded weights "
            "(its grouped attention cache does not fit the flat cached "
            "layer scan); use hosting='replicated'")
    mesh = ctx.mesh
    ba = batch_axes(mesh)
    topo = LaneTopology(node_axes=ba[1:], lane_axis=ba[0])
    n, N = topo.sizes(mesh)
    p = max(n * N, 1)
    if ctx.slots % p:
        raise ValueError(
            f"slots={ctx.slots} must be divisible by the chip count "
            f"p={p} (each chip owns a contiguous global-rank block)")
    lays = zero3_stack_layouts(cfg)
    lay_b, lay_e = lays["blocks"], lays["extras"]
    Bb = resolve_prefetch_blocks(lay_b.row_elems, n, N, ctx.prefetch_blocks)
    Be = resolve_extras_prefetch_blocks(lay_e.row_elems, n, N,
                                        ctx.prefetch_blocks)
    blocking = ctx.prefetch_blocks == -1
    ccfg = CommConfig(prefetch_blocks=ctx.prefetch_blocks)
    weights_cell = ("prefetch_allgather",
                    "blocking" if blocking else "lane_pipelined")
    tp = max(ctx.model_parallel, 1)
    if tp > 1:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        if sizes.get("model", 1) != tp:
            raise ValueError(
                f"model_parallel={tp} needs a mesh 'model' axis of that "
                f"size (mesh axes: {sizes})")

    # slot ownership follows the GLOBAL rank (lane-major, the scatter /
    # kv_splice block order); the weight masters keep the training
    # placement (shard_stack's node-major stripe order)
    bpart = (topo.lane_axis, *topo.node_axes)
    master = P(None, None, (*topo.node_axes, topo.lane_axis), None)
    fspec = block_stack_spec(cfg)

    def _comm():
        return LaneComm(topo, ccfg)

    def _assemble(hosted, comm):
        """Sharded masters + replicated leftovers -> the params tree the
        cached forwards consume (extras gathered ONCE per call — no
        backward here, so no vjp bookkeeping)."""
        shards_b = hosted["blocks"].reshape(lay_b.length, -1)
        shards_e = hosted["extras"].reshape(-1)
        params = {k: v for k, v in hosted.items()
                  if k not in ("blocks", "extras")}
        params.update(lay_e.unflatten_row(
            comm.prefetch_allgather(shards_e, num_blocks=Be)))
        params["blocks"] = ShardedStack(
            shards_b,
            lambda x: lay_b.unflatten_row(
                comm.prefetch_allgather(x, num_blocks=Bb)),
            prefetch=not blocking)
        return params

    def prepare(params):
        """Replicated init_model tree -> sharded host masters, placed."""
        stack, extras, repl = split_params(fspec, params)
        shards_b, got_b = shard_stack(stack, n, N, ctx.prefetch_blocks)
        shards_e, got_e = shard_stack(extras, n, N, ctx.prefetch_blocks,
                                      stacked=False)
        if (got_b, got_e) != (Bb, Be):
            raise RuntimeError(
                f"prepare resolved prefetch blocks {(got_b, got_e)} but "
                f"the step was built for {(Bb, Be)}")
        hosted = {k: jax.device_put(v, NamedSharding(mesh, P()))
                  for k, v in repl.items()}
        hosted["blocks"] = jax.device_put(shards_b,
                                          NamedSharding(mesh, master))
        hosted["extras"] = jax.device_put(shards_e,
                                          NamedSharding(mesh, master))
        return hosted

    def _hspec(hosted):
        spec = {k: jax.tree.map(lambda _: P(), v)
                for k, v in hosted.items() if k not in ("blocks", "extras")}
        spec["blocks"] = spec["extras"] = master
        return spec

    def _sspec(state: ServeState):
        """Slot-sharded PartitionSpec tree of a batched ServeState."""
        leaf = lambda a: P(None, bpart, *([None] * (a.ndim - 2)))
        return ServeState(
            cache=jax.tree.map(leaf, state.cache),
            length=P(bpart),
            enc_kv=None if state.enc_kv is None
            else jax.tree.map(leaf, state.enc_kv))

    state_t = jax.eval_shape(
        lambda: _init_serve_state(cfg, ctx.slots, ctx.max_seq))
    sspec = _sspec(state_t)
    repl_spec = jax.tree.map(lambda _: P(), state_t)
    state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), sspec,
                            is_leaf=lambda x: isinstance(x, P))

    def init_state():
        state = jax.jit(
            lambda: _init_serve_state(cfg, ctx.slots, ctx.max_seq),
            out_shardings=state_sh)()
        return state

    hspec_cache: dict = {}

    def _wrap(fn, in_specs, out_specs, donate=()):
        sm = jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_vma=False)
        return jax.jit(sm, donate_argnums=donate)

    def _get(kind, hosted, build):
        key = kind
        if key not in hspec_cache:
            hspec_cache[key] = build(_hspec(hosted))
        return hspec_cache[key]

    def _pctx():
        """parallel_context of the hosted forwards: the TP activation
        collectives trace inside it (no-op context when tp == 1).  The
        TP forward is bitwise replicated over "model" (mlp_tp gathers
        its outputs full), so the replicated out_specs stay sound."""
        import contextlib
        if tp == 1:
            return contextlib.nullcontext()
        from repro.models.parallel import parallel_context
        return parallel_context(tp=tp, tp_comm=LaneComm(
            LaneTopology(node_axes=(), lane_axis="model"), ccfg))

    def _prefill_local(hosted, toks, true_len, extra):
        comm = _comm()
        params = _assemble(hosted, comm)
        cache1 = init_cache(cfg, 1, ctx.max_seq, dtype=_dtype(cfg))
        with _pctx():
            return prefill(params, cfg, toks, cache1, extra_embeds=extra,
                           true_len=true_len)

    def prefill_step(hosted, toks, true_len, extra=None):
        # batch-1 prefill runs REPLICATED (every chip computes the same
        # gathered-weight forward — deterministic, so out_specs P() is
        # sound); the splice below distributes the result to its slot
        if extra is None:
            fn = _get("prefill", hosted, lambda hs: _wrap(
                lambda h, t, l: _prefill_local(h, t, l, None),
                (hs, P(), P()), (P(), repl_spec)))
            return fn(hosted, toks, jnp.asarray(true_len, jnp.int32))
        fn = _get("prefill_extra", hosted, lambda hs: _wrap(
            _prefill_local, (hs, P(), P(), P()), (P(), repl_spec)))
        return fn(hosted, toks, jnp.asarray(true_len, jnp.int32), extra)

    def _decode_local(hosted, tok, state):
        comm = _comm()
        params = _assemble(hosted, comm)
        with _pctx():
            return decode_step(params, cfg, tok, state)

    def _decode_fn(hosted):
        return _get("decode", hosted, lambda hs: _wrap(
            _decode_local, (hs, P(bpart, None), sspec),
            (P(bpart, None, None), sspec), donate=(2,)))

    def decode(hosted, tok, state):
        return _decode_fn(hosted)(hosted, tok, state)

    def _splice_local(state, st1, slot):
        comm = _comm()
        sp = lambda axis: (lambda big, small: comm.kv_splice(
            big, small=small, slot=slot, batch_axis=axis,
            strategy=ctx.kv_strategy))
        cache = jax.tree.map(sp(_BATCH_AXIS), state.cache, st1.cache)
        length = comm.kv_splice(state.length, small=st1.length, slot=slot,
                                batch_axis=0, strategy=ctx.kv_strategy)
        enc_kv = state.enc_kv
        if state.enc_kv is not None:
            enc_kv = jax.tree.map(sp(_BATCH_AXIS), state.enc_kv,
                                  st1.enc_kv)
        return ServeState(cache=cache, length=length, enc_kv=enc_kv)

    splice_fn = _wrap(_splice_local, (sspec, repl_spec, P()), sspec,
                      donate=(0,))

    def splice(state, st1, slot):
        return splice_fn(state, st1, jnp.asarray(slot, jnp.int32))

    def debug_lower(params):
        """AOT compiled HLO of the distributed serving surfaces (decode
        with its prefetch weight gathers, and the kv_splice) — nothing
        executes; the lanelint step sweep walks the text for R1."""
        hosted = prepare(params)
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), hosted)
        tok = jax.ShapeDtypeStruct((ctx.slots, 1), jnp.int32)
        st_t = jax.eval_shape(
            lambda: _init_serve_state(cfg, ctx.slots, ctx.max_seq))
        st1_t = jax.eval_shape(
            lambda: _init_serve_state(cfg, 1, ctx.max_seq))
        slot = jax.ShapeDtypeStruct((), jnp.int32)
        dec = _decode_fn(hosted).lower(shapes, tok,
                                       st_t).compile().as_text()
        spl = splice_fn.lower(st_t, st1_t, slot).compile().as_text()
        return {"decode": dec, "splice": spl}

    cells = {"weights": weights_cell,
             "kv": ("kv_splice", ctx.kv_strategy)}
    if tp > 1:
        cells["tp"] = ("allgather", "auto")
    return ServeStep(
        hosting="lane_zero3", cfg=cfg, ctx=ctx, prepare=prepare,
        init_state=init_state, prefill=prefill_step, decode=decode,
        splice=splice, collectives=cells,
        debug_lower=debug_lower)


# ---------------------------------------------------------------------------
# checkpoint -> serving weights (the PR-5 cross-layout canonical path)
# ---------------------------------------------------------------------------

def load_serve_params(ckpt_dir: str, cfg: ModelConfig,
                      step: Optional[int] = None):
    """Replicated serving weights from ANY training checkpoint layout.

    Reads the canonical flat order (crc-verified), pairs it against the
    stored layout's state template, lifts to the replicated form through
    the same ``state_to_replicated`` path training restarts use, drops
    the optimizer state, and casts back to the model's parameter dtypes.
    A zero3 ServeStep re-shards the result through ``prepare`` — so a
    checkpoint written at p chips serves at any p′.  Returns
    ``(params, step)``.
    """
    from repro.checkpoint import load_canonical
    from repro.launch.steps import _abs_params, _canonical_state_template, \
        state_to_replicated
    man, arrays, got = load_canonical(ckpt_dir, step)
    entry = (man.get("layout") or {})
    params_t = _abs_params(cfg)
    state_t = _canonical_state_template(cfg, entry)
    n_state = len(jax.tree.leaves(state_t))
    n_params = len(jax.tree.leaves(params_t))
    if len(arrays) == n_state:
        state = jax.tree.unflatten(jax.tree.structure(state_t), arrays)
        params, _ = state_to_replicated(cfg, entry, state)
    elif len(arrays) == n_params \
            and entry.get("kind", "replicated") == "replicated":
        params = jax.tree.unflatten(jax.tree.structure(params_t), arrays)
    else:
        raise ValueError(
            f"checkpoint at {ckpt_dir} holds {len(arrays)} leaves; a "
            f"{entry.get('kind', 'replicated')!r} state of this model "
            f"has {n_state} (or {n_params} params-only) — different "
            f"model?")
    params = jax.tree.map(lambda v, t: jnp.asarray(v).astype(t.dtype),
                          params, params_t)
    return params, got
