"""Multi-lane serving tier: registry-resolved steps + continuous batching.

Import order matters only for registration: importing the subpackage
registers the ``serve_step`` hostings (steps), the ``serve_scenario``
generators (scenarios) and the ``kv_splice`` collective cells
(repro.comm.impls, pulled in transitively).
"""
from .engine import (ContinuousBatcher, Request, termination_reason,
                     DEFAULT_BUCKETS)
from .sampling import SamplerConfig, request_key, sample_token, \
    top_p_renormalize
from .steps import (ServeContext, ServeStep, build_serve_step,
                    load_serve_params, serve_hostings)
from .scenarios import make_scenario, scenario_families, SCENARIO_KINDS

__all__ = [
    "ContinuousBatcher", "Request", "termination_reason", "DEFAULT_BUCKETS",
    "SamplerConfig", "request_key", "sample_token", "top_p_renormalize",
    "ServeContext", "ServeStep", "build_serve_step", "serve_hostings",
    "load_serve_params",
    "make_scenario", "scenario_families", "SCENARIO_KINDS",
]
