from .engine import ContinuousBatcher, Request
