"""Sharded, atomic, async checkpointing with cross-mesh restore.

Layout:   <dir>/step_<N>/
             manifest.json           tree structure, shapes, dtypes, step,
                                     and the shard LAYOUT of the writer
             arr_<i>.npy             one file per leaf (host-local fetch)
          <dir>/step_<N>.tmp/        written first, renamed when complete
The rename is the commit point — a crash mid-write never corrupts the
latest complete checkpoint (restart scans for the largest committed step).

Cross-mesh restore: leaves are stored in a topology-FREE canonical form —
full arrays for replicated state, the unpadded flat parameter order for
the ZeRO master layouts (see :mod:`repro.checkpoint.layouts`) — and on
load they are re-laid-out for the *current* mesh and device_put against
its shardings, so a 512-chip checkpoint restarts on 256 chips (elastic
shrink after pod loss) or any other mesh without conversion, including a
``lane_zero3`` run whose (L, B, p, s) master geometry changed with p.
Canonicalization is pure reshape/transpose — restores are bit-identical.
At real scale the np.save per leaf becomes a per-shard write keyed by
shard index — the manifest format already records canonical shapes/dtypes
independently of the shard layout.

AsyncCheckpointer: serializes the save on a worker thread; the train loop
only blocks on fetching arrays to host (device_get), not on disk I/O.
Worker errors are re-raised by ``wait()`` (and by the next ``save()``),
and ``error`` exposes the pending failure so emergency paths (SIGTERM)
can surface it even when they must not raise.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from .layouts import CheckpointLayout, REPLICATED


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _flatten_with_paths(tree):
    pairs, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [p for p, _ in pairs]
    leaves = [l for _, l in pairs]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    layout: Optional[CheckpointLayout] = None) -> str:
    """Write ``tree`` atomically; master leaves canonicalize through
    ``layout`` (None = replicated identity) so the files on disk are
    mesh-independent."""
    layout = layout or REPLICATED
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step}.tmp"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    paths, leaves, treedef = _flatten_with_paths(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "layout": layout.manifest_entry(), "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = layout.to_canonical(path,
                                  np.asarray(jax.device_get(leaf)))
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # commit point
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for p in base.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp") \
                and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None,
                       layout: Optional[CheckpointLayout] = None
                       ) -> tuple[Any, int]:
    """Restore into the structure of `tree_like`; device_put against
    `shardings` (a matching tree) when given — this is where cross-mesh
    resharding happens.  ``layout`` describes the CURRENT run's master
    layout: the stored canonical leaves are re-laid-out through
    ``layout.from_canonical`` (the manifest's recorded layout must agree
    in kind and canonical geometry; B/p may differ — elastic restore)."""
    layout = layout or REPLICATED
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    layout.check_manifest(manifest.get("layout"))
    paths, refs, treedef = _flatten_with_paths(tree_like)
    if len(manifest["leaves"]) != len(refs):
        raise ValueError(
            f"checkpoint {d} holds {len(manifest['leaves'])} leaves but "
            f"the restore target tree has {len(refs)}")
    out = []
    for i, (path, ref) in enumerate(zip(paths, refs)):
        arr = layout.from_canonical(path, np.load(d / f"arr_{i}.npy"))
        if tuple(arr.shape) != tuple(ref.shape):
            # a bare assert here vanishes under ``python -O`` and the
            # mismatch would surface as silent corruption steps later
            raise ValueError(
                f"checkpoint leaf {i} ({d / f'arr_{i}.npy'}) restores to "
                f"shape {tuple(arr.shape)} but the target tree expects "
                f"{tuple(ref.shape)} — mesh/layout mismatch?")
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, step


def peek_manifest(ckpt_dir: str, step: int | None = None
                  ) -> tuple[dict, int]:
    """Read one checkpoint's manifest only (no arrays) — enough to decide
    the layout kind before committing to a full load."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    return json.loads((d / "manifest.json").read_text()), step


def load_canonical(ckpt_dir: str, step: int | None = None
                   ) -> tuple[dict, list, int]:
    """Read one checkpoint's manifest and its RAW canonical leaves, with
    no layout validation or re-layout — the cross-layout restore path
    (launch/steps.py:restore_lane_train_state) pairs these against a
    source-layout template and lifts them to the replicated form through
    the canonical flat order.  Returns (manifest, [np arrays], step)."""
    manifest, step = peek_manifest(ckpt_dir, step)
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    arrays = [np.load(d / f"arr_{i}.npy")
              for i in range(len(manifest["leaves"]))]
    return manifest, arrays, step


def keep_last_k(ckpt_dir: str, k: int = 3) -> None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return
    steps = sorted(int(p.name.split("_")[1]) for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for s in steps[:-k]:
        shutil.rmtree(base / f"step_{s}", ignore_errors=True)


class AsyncCheckpointer:
    """One background writer; at most one save in flight (later saves wait,
    which back-pressures rather than stacking host copies).  ``layout``
    is threaded into every ``save_checkpoint`` so ZeRO master state
    canonicalizes off the critical path."""

    def __init__(self, ckpt_dir: str, keep: int = 3,
                 layout: Optional[CheckpointLayout] = None):
        self.dir = ckpt_dir
        self.keep = keep
        self.layout = layout or REPLICATED
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    @property
    def error(self) -> Optional[BaseException]:
        """The pending worker failure, if any (peek without raising —
        the SIGTERM emergency path reports it even when raising would
        mask the original exception)."""
        return self._err

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree,
                                layout=self.layout)
                keep_last_k(self.dir, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
