"""Sharded, atomic, async checkpointing with cross-mesh restore.

Layout:   <dir>/step_<N>/
             manifest.json           tree structure, shapes, dtypes, step
             arr_<i>.npy             one file per leaf (host-local fetch)
          <dir>/step_<N>.tmp/        written first, renamed when complete
The rename is the commit point — a crash mid-write never corrupts the
latest complete checkpoint (restart scans for the largest committed step).

Cross-mesh restore: leaves are stored as full (unsharded) arrays; on load
they are device_put against the *current* mesh's shardings, so a 512-chip
checkpoint restarts on 256 chips (elastic shrink after pod loss) or any
other divisor mesh without conversion.  At real scale the np.save per leaf
becomes a per-shard write keyed by shard index — the manifest format
already records shapes/dtypes independently of the shard layout.

AsyncCheckpointer: serializes the save on a worker thread; the train loop
only blocks on fetching arrays to host (device_get), not on disk I/O.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step}.tmp"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves, treedef = _flatten(tree)
    manifest = {"step": step, "treedef": str(treedef),
                "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / f"arr_{i}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                      # commit point
    return str(final)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = []
    for p in base.iterdir():
        if p.is_dir() and p.name.startswith("step_") \
                and not p.name.endswith(".tmp") \
                and (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of `tree_like`; device_put against
    `shardings` (a matching tree) when given — this is where cross-mesh
    resharding happens."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = pathlib.Path(ckpt_dir) / f"step_{step}"
    leaves, treedef = _flatten(tree_like)
    out = []
    for i, ref in enumerate(leaves):
        arr = np.load(d / f"arr_{i}.npy")
        assert tuple(arr.shape) == tuple(ref.shape), (arr.shape, ref.shape)
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, step


def keep_last_k(ckpt_dir: str, k: int = 3) -> None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return
    steps = sorted(int(p.name.split("_")[1]) for p in base.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for s in steps[:-k]:
        shutil.rmtree(base / f"step_{s}", ignore_errors=True)


class AsyncCheckpointer:
    """One background writer; at most one save in flight (later saves wait,
    which back-pressures rather than stacking host copies)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree)
                keep_last_k(self.dir, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
