"""Sharded, atomic, async checkpointing with cross-mesh restore.

Layout:   <dir>/step_<N>/
             manifest.json           tree structure, shapes, dtypes, step,
                                     per-leaf crc32, and the shard LAYOUT
                                     of the writer
             arr_<i>.npy             one file per leaf (host-local fetch)
          <dir>/step_<N>.tmp/        written first, renamed when complete
          <dir>/step_<N>.old/        the PREVIOUS committed copy of the
                                     same step, parked for the instant of
                                     an overwrite (never both absent)
The rename is the commit point — a crash mid-write never corrupts the
latest complete checkpoint (restart scans for the largest committed
step).  Overwriting an existing step swaps through ``.old``: the old
copy is renamed aside, the new one renamed in, THEN the old one removed,
so a crash at any instant leaves at least one committed copy of the
step (the scanner treats a lone ``step_N.old`` as committed).

Integrity: every leaf's crc32 (of its raw buffer) is recorded in the
manifest and re-checked on restore; a mismatch — or an unreadable file —
raises :class:`CheckpointCorruptError`, and the default restore path
falls back to the newest step that DOES verify instead of crashing the
restart on a rotted latest.  Transient write errors (flaky filesystem)
are retried with bounded exponential backoff inside ``save_checkpoint``.

Cross-mesh restore: leaves are stored in a topology-FREE canonical form —
full arrays for replicated state, the unpadded flat parameter order for
the ZeRO master layouts (see :mod:`repro.checkpoint.layouts`) — and on
load they are re-laid-out for the *current* mesh and device_put against
its shardings, so a 512-chip checkpoint restarts on 256 chips (elastic
shrink after pod loss) or any other mesh without conversion, including a
``lane_zero3`` run whose (L, B, p, s) master geometry changed with p.
Canonicalization is pure reshape/transpose — restores are bit-identical.
At real scale the np.save per leaf becomes a per-shard write keyed by
shard index — the manifest format already records canonical shapes/dtypes
independently of the shard layout.

AsyncCheckpointer: serializes the save on a worker thread; the train loop
only blocks on fetching arrays to host (device_get), not on disk I/O.
Worker errors are re-raised by ``wait()`` (and by the next ``save()``),
and ``error`` exposes the pending failure so emergency paths (SIGTERM)
can surface it even when they must not raise.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import sys
import threading
import time
import zlib
from typing import Any, Callable, Optional

import jax
import numpy as np

from .layouts import CheckpointLayout, REPLICATED


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed its integrity check: a leaf's crc32
    disagrees with the manifest, a leaf file is missing/unreadable, or
    the manifest itself cannot be parsed.  DISTINCT from the ValueErrors
    of a genuine geometry mismatch (wrong model/mesh), which must never
    be silently skipped by the verified-fallback scan."""


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _flatten_with_paths(tree):
    pairs, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [p for p, _ in pairs]
    leaves = [l for _, l in pairs]
    return paths, leaves, treedef


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _parse_step(name: str) -> Optional[int]:
    """``step_<N>`` / ``step_<N>.old`` -> N; anything else (an operator's
    ``step_backup``, a ``.tmp`` in flight) -> None.  Restart must never
    die on a stray directory name."""
    if name.endswith(".old"):
        name = name[:-len(".old")]
    if not name.startswith("step_"):
        return None
    suffix = name[len("step_"):]
    return int(suffix) if suffix.isdigit() else None


def step_dir(ckpt_dir: str, step: int) -> Optional[pathlib.Path]:
    """The committed directory for ``step``: the final name, or the
    parked ``.old`` copy when a crash mid-overwrite left only that.
    None when neither holds a manifest."""
    base = pathlib.Path(ckpt_dir)
    for d in (base / f"step_{step}", base / f"step_{step}.old"):
        if (d / "manifest.json").exists():
            return d
    return None


def committed_steps(ckpt_dir: str) -> list:
    """Sorted committed step numbers (manifest present; ``.old``-only
    counts; malformed names and in-flight ``.tmp`` dirs skipped)."""
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return []
    steps = set()
    for p in base.iterdir():
        if not p.is_dir() or p.name.endswith(".tmp"):
            continue
        s = _parse_step(p.name)
        if s is not None and (p / "manifest.json").exists():
            steps.add(s)
    return sorted(steps)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = committed_steps(ckpt_dir)
    return steps[-1] if steps else None


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    layout: Optional[CheckpointLayout] = None, *,
                    attempts: int = 3, backoff_s: float = 0.05,
                    attempt_hook: Optional[Callable[[int], None]] = None
                    ) -> str:
    """Write ``tree`` atomically; master leaves canonicalize through
    ``layout`` (None = replicated identity) so the files on disk are
    mesh-independent.  Every leaf's crc32 lands in the manifest.

    Transient ``OSError``s (flaky filesystem) are retried up to
    ``attempts`` times with exponential backoff starting at
    ``backoff_s``; each retry starts from a clean tmp dir.  Any other
    exception — and an OSError on the last attempt — propagates.
    ``attempt_hook(attempt)`` is called at the start of each attempt
    (0-based) inside the retried region; the deterministic fault
    injection (runtime.faults) uses it to raise the transient errors
    tier-1 exercises this path with.
    """
    layout = layout or REPLICATED
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step}.tmp"
    final = base / f"step_{step}"
    old = base / f"step_{step}.old"
    last_err: Optional[OSError] = None
    for attempt in range(max(attempts, 1)):
        if attempt:
            time.sleep(backoff_s * (2 ** (attempt - 1)))
        try:
            if attempt_hook is not None:
                attempt_hook(attempt)
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            paths, leaves, treedef = _flatten_with_paths(tree)
            manifest = {"step": step, "treedef": str(treedef),
                        "layout": layout.manifest_entry(), "leaves": []}
            for i, (path, leaf) in enumerate(zip(paths, leaves)):
                arr = layout.to_canonical(
                    path, np.asarray(jax.device_get(leaf)))
                np.save(tmp / f"arr_{i}.npy", arr)
                manifest["leaves"].append({"shape": list(arr.shape),
                                           "dtype": str(arr.dtype),
                                           "crc32": _crc32(arr)})
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            # overwrite swap: park the committed copy aside, rename the
            # new one in, THEN drop the parked copy — a crash at any
            # point leaves step_N or step_N.old (never neither), and
            # the scanner accepts either
            if old.exists():
                shutil.rmtree(old)
            if final.exists():
                final.rename(old)
            tmp.rename(final)                  # commit point
            if old.exists():
                shutil.rmtree(old)
            return str(final)
        except OSError as e:
            last_err = e
            print(f"checkpoint save step {step}: attempt "
                  f"{attempt + 1}/{attempts} failed ({e}); "
                  f"{'retrying' if attempt + 1 < attempts else 'giving up'}",
                  file=sys.stderr, flush=True)
    raise last_err


def verify_checkpoint(ckpt_dir: str, step: int) -> dict:
    """Re-check every leaf of one committed step against its manifest
    crc32.  Returns the manifest on success; raises
    :class:`CheckpointCorruptError` naming the first bad leaf.
    Checkpoints written before crc32s existed (no ``crc32`` keys) pass
    vacuously — there is nothing to check them against."""
    d = step_dir(ckpt_dir, step)
    if d is None:
        raise FileNotFoundError(f"no committed step {step} in {ckpt_dir}")
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {d}: {e}") from e
    for i, entry in enumerate(manifest["leaves"]):
        want = entry.get("crc32")
        if want is None:
            continue
        try:
            arr = np.load(d / f"arr_{i}.npy")
        except Exception as e:  # noqa: BLE001 - any load failure = rot
            raise CheckpointCorruptError(
                f"unreadable leaf {d / f'arr_{i}.npy'}: {e}") from e
        got = _crc32(arr)
        if got != want:
            raise CheckpointCorruptError(
                f"crc32 mismatch on {d / f'arr_{i}.npy'}: manifest "
                f"{want:#010x}, file {got:#010x}")
    return manifest


def latest_verified_step(ckpt_dir: str) -> Optional[int]:
    """Newest committed step whose every leaf passes its crc32 check —
    the step restart should trust.  None when nothing verifies."""
    for s in reversed(committed_steps(ckpt_dir)):
        try:
            verify_checkpoint(ckpt_dir, s)
            return s
        except CheckpointCorruptError as e:
            print(f"checkpoint step {s} failed verification ({e}); "
                  f"trying an earlier step", file=sys.stderr, flush=True)
    return None


def _read_manifest(d: pathlib.Path) -> dict:
    try:
        return json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {d}: {e}") from e


def _load_leaf(d: pathlib.Path, i: int, entry: dict,
               verify: bool) -> np.ndarray:
    try:
        arr = np.load(d / f"arr_{i}.npy")
    except Exception as e:  # noqa: BLE001 - any load failure = rot
        raise CheckpointCorruptError(
            f"unreadable leaf {d / f'arr_{i}.npy'}: {e}") from e
    if verify and entry.get("crc32") is not None \
            and _crc32(arr) != entry["crc32"]:
        raise CheckpointCorruptError(
            f"crc32 mismatch on {d / f'arr_{i}.npy'}: manifest "
            f"{entry['crc32']:#010x}, file {_crc32(arr):#010x}")
    return arr


def restore_checkpoint(ckpt_dir: str, tree_like: Any, step: int | None = None,
                       shardings: Any = None,
                       layout: Optional[CheckpointLayout] = None,
                       verify: bool = True) -> tuple[Any, int]:
    """Restore into the structure of `tree_like`; device_put against
    `shardings` (a matching tree) when given — this is where cross-mesh
    resharding happens.  ``layout`` describes the CURRENT run's master
    layout: the stored canonical leaves are re-laid-out through
    ``layout.from_canonical`` (the manifest's recorded layout must agree
    in kind and canonical geometry; B/p may differ — elastic restore).

    Integrity: with ``verify`` (default) every leaf is crc-checked as it
    is read.  An EXPLICIT ``step`` that fails raises
    :class:`CheckpointCorruptError`; ``step=None`` walks the committed
    steps newest-first and restores the newest one that verifies —
    corruption of the latest checkpoint costs the steps since the
    previous commit, never the restart.  Geometry mismatches (wrong
    model/mesh — ValueError) always propagate: falling back PAST a
    config error would silently resurrect an ancient checkpoint.
    """
    candidates = [step] if step is not None \
        else list(reversed(committed_steps(ckpt_dir)))
    if not candidates:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    last_err: Optional[CheckpointCorruptError] = None
    for s in candidates:
        try:
            return _restore_one(ckpt_dir, tree_like, s, shardings,
                                layout, verify)
        except CheckpointCorruptError as e:
            last_err = e
            if step is not None:
                raise
            print(f"checkpoint step {s} is corrupt ({e}); falling back "
                  f"to the previous committed step",
                  file=sys.stderr, flush=True)
    raise CheckpointCorruptError(
        f"no verifiable checkpoint in {ckpt_dir} "
        f"(tried steps {candidates})") from last_err


def _restore_one(ckpt_dir: str, tree_like: Any, step: int,
                 shardings: Any, layout: Optional[CheckpointLayout],
                 verify: bool) -> tuple[Any, int]:
    layout = layout or REPLICATED
    d = step_dir(ckpt_dir, step)
    if d is None:
        raise FileNotFoundError(
            f"no committed step {step} in {ckpt_dir}")
    manifest = _read_manifest(d)
    layout.check_manifest(manifest.get("layout"))
    paths, refs, treedef = _flatten_with_paths(tree_like)
    if len(manifest["leaves"]) != len(refs):
        raise ValueError(
            f"checkpoint {d} holds {len(manifest['leaves'])} leaves but "
            f"the restore target tree has {len(refs)}")
    out = []
    for i, (path, ref) in enumerate(zip(paths, refs)):
        arr = layout.from_canonical(
            path, _load_leaf(d, i, manifest["leaves"][i], verify))
        if tuple(arr.shape) != tuple(ref.shape):
            # a bare assert here vanishes under ``python -O`` and the
            # mismatch would surface as silent corruption steps later
            raise ValueError(
                f"checkpoint leaf {i} ({d / f'arr_{i}.npy'}) restores to "
                f"shape {tuple(arr.shape)} but the target tree expects "
                f"{tuple(ref.shape)} — mesh/layout mismatch?")
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree, step


def peek_manifest(ckpt_dir: str, step: int | None = None
                  ) -> tuple[dict, int]:
    """Read one checkpoint's manifest only (no arrays) — enough to decide
    the layout kind before committing to a full load."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = step_dir(ckpt_dir, step)
    if d is None:
        raise FileNotFoundError(f"no committed step {step} in {ckpt_dir}")
    return _read_manifest(d), step


def load_canonical(ckpt_dir: str, step: int | None = None,
                   verify: bool = True) -> tuple[dict, list, int]:
    """Read one checkpoint's manifest and its RAW canonical leaves
    (crc-checked), with no layout validation or re-layout — the
    cross-layout restore path (launch/steps.py:restore_lane_train_state)
    pairs these against a source-layout template and lifts them to the
    replicated form through the canonical flat order.  Returns
    (manifest, [np arrays], step)."""
    manifest, step = peek_manifest(ckpt_dir, step)
    d = step_dir(ckpt_dir, step)
    arrays = [_load_leaf(d, i, manifest["leaves"][i], verify)
              for i in range(len(manifest["leaves"]))]
    return manifest, arrays, step


def keep_last_k(ckpt_dir: str, k: int = 3) -> None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return
    for s in committed_steps(ckpt_dir)[:-k]:
        shutil.rmtree(base / f"step_{s}", ignore_errors=True)
        shutil.rmtree(base / f"step_{s}.old", ignore_errors=True)


class AsyncCheckpointer:
    """One background writer; at most one save in flight (later saves wait,
    which back-pressures rather than stacking host copies).  ``layout``
    is threaded into every ``save_checkpoint`` so ZeRO master state
    canonicalizes off the critical path; ``attempts``/``backoff_s``
    configure the transient-I/O retry of every save."""

    def __init__(self, ckpt_dir: str, keep: int = 3,
                 layout: Optional[CheckpointLayout] = None,
                 attempts: int = 3, backoff_s: float = 0.05):
        self.dir = ckpt_dir
        self.keep = keep
        self.layout = layout or REPLICATED
        self.attempts = attempts
        self.backoff_s = backoff_s
        self._thread: Optional[threading.Thread] = None
        self._err: Optional[BaseException] = None

    @property
    def error(self) -> Optional[BaseException]:
        """The pending worker failure, if any (peek without raising —
        the SIGTERM emergency path reports it even when raising would
        mask the original exception)."""
        return self._err

    def save(self, step: int, tree: Any,
             attempt_hook: Optional[Callable[[int], None]] = None) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.dir, step, host_tree,
                                layout=self.layout,
                                attempts=self.attempts,
                                backoff_s=self.backoff_s,
                                attempt_hook=attempt_hook)
                keep_last_k(self.dir, self.keep)
            except BaseException as e:  # noqa: BLE001
                self._err = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._err is not None:
            err, self._err = self._err, None
            raise err
