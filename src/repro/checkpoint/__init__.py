from .layouts import (CheckpointLayout, Zero1CheckpointLayout,
                      Zero3CheckpointLayout, REPLICATED,
                      concat_flat_order, split_flat_order)
from .store import save_checkpoint, restore_checkpoint, latest_step, \
    load_canonical, AsyncCheckpointer
