from .layouts import (CheckpointLayout, Zero1CheckpointLayout,
                      Zero3CheckpointLayout, REPLICATED)
from .store import save_checkpoint, restore_checkpoint, latest_step, \
    AsyncCheckpointer
