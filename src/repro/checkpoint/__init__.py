from .layouts import (CheckpointLayout, Zero1CheckpointLayout,
                      Zero3CheckpointLayout, REPLICATED,
                      concat_flat_order, split_flat_order)
from .store import (AsyncCheckpointer, CheckpointCorruptError,
                    committed_steps, keep_last_k, latest_step,
                    latest_verified_step, load_canonical, peek_manifest,
                    restore_checkpoint, save_checkpoint, step_dir,
                    verify_checkpoint)
