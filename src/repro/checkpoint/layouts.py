"""Checkpoint shard-layout adapters: canonical on disk, sharded in HBM.

The ZeRO train steps keep their master state in topology-dependent
layouts — ZeRO-1 moments as a node-sharded bucket-major flat vector,
ZeRO-3 layer stacks in the (L, B, p, s) master layout of
``repro.models.blockstack.shard_stack`` — and B, p and the padding change
when the mesh changes.  A checkpoint that stored those arrays verbatim
would only restore onto the exact chip count that wrote it, which is the
opposite of what an elastic fleet needs (Träff's k-lane follow-up:
decompositions must survive topology change).

So the store canonicalizes: every master leaf is written in a
topology-FREE canonical form (the unpadded flat element order of the
parameter tree — exactly the order ``gradsync.zero1_unshard`` /
``gradsync.zero3_unshard`` reassemble on-device, pinned bit-for-bit by
the ``*_ckpt_canonical_matches_unshard`` conformance cases), and restore
re-pads and re-shapes into the layout of the CURRENT mesh.  Both
directions are pure reshapes/transposes of host numpy arrays — no float
is ever converted, so a checkpoint written at p chips restores
bit-identically onto p′ chips.

A ``CheckpointLayout`` is threaded through ``save_checkpoint`` /
``restore_checkpoint`` / ``AsyncCheckpointer`` (repro.checkpoint.store);
the manifest records ``layout.manifest_entry()`` so a restore under the
wrong layout kind fails loudly instead of silently mis-shaping.  Which
layout a given run needs is answered by
``LaneComm.param_layout`` + the factories in ``launch.steps``
(``zero1_checkpoint_layout`` / ``zero3_checkpoint_layout``).
"""
from __future__ import annotations

import numpy as np

__all__ = ["CheckpointLayout", "Zero1CheckpointLayout",
           "Zero3CheckpointLayout", "REPLICATED",
           "concat_flat_order", "split_flat_order"]


def _path_keys(path) -> tuple:
    """Key names along a jax tree path (DictKey.key / SequenceKey.idx)."""
    out = []
    for k in path:
        out.append(getattr(k, "key", getattr(k, "name", getattr(k, "idx",
                                                                k))))
    return tuple(out)


class CheckpointLayout:
    """Identity layout: every leaf is already canonical (replicated
    trees).  Base class for the shard-aware layouts below; the store
    calls ``to_canonical``/``from_canonical`` per leaf with the leaf's
    tree path, and records/validates ``manifest_entry``."""

    kind = "replicated"

    def manifest_entry(self) -> dict:
        return {"kind": self.kind}

    def check_manifest(self, entry: dict) -> None:
        """Raise ValueError when a checkpoint's recorded layout is not
        restorable under this layout (kind or canonical-geometry drift).
        Manifests from before the layout field default to replicated."""
        got = (entry or {}).get("kind", "replicated")
        if got != self.kind:
            raise ValueError(
                f"checkpoint layout mismatch: manifest records layout "
                f"{got!r} but restore was asked for {self.kind!r}; "
                f"restore with the layout of the run that WROTE the "
                f"checkpoint (strategy layouts: LaneComm.param_layout)")

    def to_canonical(self, path, leaf):
        return leaf

    def from_canonical(self, path, leaf):
        return leaf


REPLICATED = CheckpointLayout()


class Zero1CheckpointLayout(CheckpointLayout):
    """ZeRO-1 flat optimizer moments (``m``/``v``): on-device the padded
    flat vector lives node-sharded in the bucket-major layout of
    ``gradsync.zero1_param_shard`` — host-global shape (n·K·s,) in
    (chip, bucket, s) order.  Canonical form: the unpadded flat
    parameter order, i.e. the (K, n, s) ← (n, K, s) transpose that
    ``gradsync.zero1_unshard`` performs on-device, then the padding
    stripped."""

    kind = "zero1"

    def __init__(self, total_elems: int, num_buckets: int, n: int):
        if total_elems <= 0 or num_buckets < 1 or n < 1:
            raise ValueError((total_elems, num_buckets, n))
        self.total_elems = int(total_elems)
        self.num_buckets = int(num_buckets)
        self.n = int(n)
        self.padded = -(-self.total_elems
                        // (num_buckets * n)) * (num_buckets * n)
        self.shard_elems = self.padded // (num_buckets * n)   # s

    def manifest_entry(self) -> dict:
        return {"kind": self.kind, "total_elems": self.total_elems,
                "num_buckets": self.num_buckets, "n": self.n}

    def check_manifest(self, entry: dict) -> None:
        super().check_manifest(entry)
        want = entry.get("total_elems", self.total_elems)
        if want != self.total_elems:
            raise ValueError(
                f"zero1 checkpoint holds {want} canonical elements but "
                f"the restoring run expects {self.total_elems} (different "
                f"model?)")

    def _is_master(self, path, leaf) -> bool:
        keys = _path_keys(path)
        return bool(keys) and keys[-1] in ("m", "v") \
            and getattr(leaf, "ndim", None) == 1

    def to_canonical(self, path, leaf):
        if not (self._is_master(path, leaf)
                and leaf.shape[0] == self.padded):
            return leaf
        a = np.asarray(leaf)
        K, n, s = self.num_buckets, self.n, self.shard_elems
        return np.ascontiguousarray(
            a.reshape(n, K, s).transpose(1, 0, 2)).reshape(-1)[
                :self.total_elems]

    def from_canonical(self, path, leaf):
        if not (self._is_master(path, leaf)
                and leaf.shape[0] == self.total_elems):
            return leaf
        a = np.asarray(leaf)
        pad = self.padded - self.total_elems
        if pad:
            a = np.concatenate([a, np.zeros((pad,), a.dtype)])
        K, n, s = self.num_buckets, self.n, self.shard_elems
        return np.ascontiguousarray(
            a.reshape(K, n, s).transpose(1, 0, 2)).reshape(-1)


class Zero3CheckpointLayout(CheckpointLayout):
    """ZeRO-3 stack masters (params ``blocks``/``extras`` and the
    matching moment arrays): on-device/host-global shape is the
    bucket-major (L, B, p, s) of ``repro.models.blockstack.shard_stack``.
    That layout is already the per-layer flat (bucket, chip, s) element
    order ``gradsync.zero3_unshard`` reassembles (DESIGN.md §5 zero-copy
    layout choice), so canonicalization is a plain reshape to (L, B·p·s)
    plus stripping the padding: canonical form (L, layer_elems).

    The ``extras`` pseudo-layer (embeddings/final-norm sharded as one
    more stack row — DESIGN.md §8) carries its own geometry
    (``extra_elems``/``extra_blocks``, master (1, Be, p, se)); layouts
    from before the extras stack (``extra_elems=0``) stay constructible
    and restore checkpoints that never recorded one."""

    kind = "zero3"

    def __init__(self, num_layers: int, layer_elems: int, num_blocks: int,
                 num_shards: int, extra_elems: int = 0,
                 extra_blocks: int = 0, ep: bool = False):
        if min(num_layers, layer_elems, num_blocks, num_shards) < 1:
            raise ValueError((num_layers, layer_elems, num_blocks,
                              num_shards))
        # expert-parallel flavor: the MoE expert FFN leaves live OUTSIDE
        # the flat stack, under an "experts" params/moments subtree whose
        # natural (L, E, ...) shapes ARE canonical (identity passthrough
        # below — neither _in_blocks nor _in_extras matches them).  The
        # flag changes layer_elems, so it is manifest-recorded and
        # restore-checked like the rest of the canonical geometry.
        self.ep = bool(ep)
        if (extra_elems > 0) != (extra_blocks > 0):
            raise ValueError((extra_elems, extra_blocks))
        self.num_layers = int(num_layers)                  # L
        self.layer_elems = int(layer_elems)                # D (unpadded)
        self.num_blocks = int(num_blocks)                  # B
        self.num_shards = int(num_shards)                  # p = n·N
        bp = self.num_blocks * self.num_shards
        padded = -(-self.layer_elems // bp) * bp
        self.shard_elems = padded // bp                    # s
        self.master_shape = (self.num_layers, self.num_blocks,
                             self.num_shards, self.shard_elems)
        self.extra_elems = int(extra_elems)                # De (unpadded)
        self.extra_blocks = int(extra_blocks)              # Be
        if self.extra_elems:
            bpe = self.extra_blocks * self.num_shards
            padded_e = -(-self.extra_elems // bpe) * bpe
            self.extra_shard_elems = padded_e // bpe       # se
            self.extra_master_shape = (1, self.extra_blocks,
                                       self.num_shards,
                                       self.extra_shard_elems)
        else:
            self.extra_shard_elems = 0
            self.extra_master_shape = None

    def manifest_entry(self) -> dict:
        entry = {"kind": self.kind, "num_layers": self.num_layers,
                 "layer_elems": self.layer_elems,
                 "num_blocks": self.num_blocks,
                 "num_shards": self.num_shards}
        if self.extra_elems:
            entry["extra_elems"] = self.extra_elems
            entry["extra_blocks"] = self.extra_blocks
        if self.ep:
            entry["ep"] = True
        return entry

    def check_manifest(self, entry: dict) -> None:
        super().check_manifest(entry)
        if bool(entry.get("ep", False)) != self.ep:
            raise ValueError(
                f"zero3 checkpoint ep={bool(entry.get('ep', False))} but "
                f"the restoring layout has ep={self.ep}; an expert-"
                f"parallel flavor change restores through the canonical "
                f"form (launch.steps.restore_lane_train_state), not the "
                f"same-layout fast path")
        for field in ("num_layers", "layer_elems", "extra_elems"):
            want = entry.get(field, 0 if field == "extra_elems"
                             else getattr(self, field))
            if want != getattr(self, field):
                raise ValueError(
                    f"zero3 checkpoint {field}={want} but the restoring "
                    f"run expects {getattr(self, field)} (different "
                    f"model?); num_blocks/num_shards MAY differ (elastic "
                    f"restore), canonical geometry may not")

    def _in_blocks(self, path) -> bool:
        return "blocks" in _path_keys(path)

    def _in_extras(self, path) -> bool:
        return "extras" in _path_keys(path)

    def to_canonical(self, path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if self._in_blocks(path) and shape == self.master_shape:
            a = np.asarray(leaf)
            return np.ascontiguousarray(
                a.reshape(self.num_layers, -1)[:, :self.layer_elems])
        if self.extra_elems and self._in_extras(path) \
                and shape == self.extra_master_shape:
            a = np.asarray(leaf)
            return np.ascontiguousarray(
                a.reshape(1, -1)[:, :self.extra_elems])
        return leaf

    def from_canonical(self, path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if self._in_blocks(path) \
                and shape == (self.num_layers, self.layer_elems):
            return self._pad_to_master(leaf, self.master_shape,
                                       self.layer_elems)
        if self.extra_elems and self._in_extras(path) \
                and shape == (1, self.extra_elems):
            return self._pad_to_master(leaf, self.extra_master_shape,
                                       self.extra_elems)
        return leaf

    @staticmethod
    def _pad_to_master(leaf, master_shape, elems):
        a = np.asarray(leaf)
        pad = master_shape[1] * master_shape[2] * master_shape[3] - elems
        if pad:
            a = np.concatenate(
                [a, np.zeros((master_shape[0], pad), a.dtype)], axis=1)
        return np.ascontiguousarray(a).reshape(master_shape)


# ---------------------------------------------------------------------------
# canonical flat order (cross-layout restore primitives)
# ---------------------------------------------------------------------------
#
# Every layout above canonicalizes to the same underlying element order:
# the unpadded flat concatenation of the parameter tree's leaves, leaf by
# leaf, row-major.  That shared order is what makes a checkpoint written
# under ONE strategy layout restorable into ANOTHER (zero3 -> zero1 ->
# replicated and back): lift the stored canonical arrays to the
# replicated tree with these primitives, then re-lay them out through the
# destination layout.  The orchestration (which needs the model's tree
# structure) lives in launch/steps.py:restore_lane_train_state; these
# helpers are model-free array plumbing, kept here so the flat-order
# contract sits next to the layouts that depend on it.

def concat_flat_order(leaves) -> np.ndarray:
    """Leaves -> ONE unpadded fp32 canonical flat vector (the
    ``gradsync._flatten_bucket`` element order, host-side)."""
    if not leaves:
        return np.zeros((0,), np.float32)
    return np.concatenate(
        [np.asarray(l).reshape(-1).astype(np.float32) for l in leaves])


def split_flat_order(flat, shapes, dtypes=None) -> list:
    """Inverse of :func:`concat_flat_order`: split a canonical flat
    vector back into leaves of ``shapes`` (cast to ``dtypes`` when
    given).  Raises ValueError when the element counts disagree — the
    "shapes genuinely differ" guard of cross-layout restore."""
    flat = np.asarray(flat).reshape(-1)
    total = sum(int(np.prod(s)) for s in shapes)
    if flat.shape[0] != total:
        raise ValueError(
            f"canonical flat vector holds {flat.shape[0]} elements but "
            f"the target leaves need {total} (different model?)")
    out, ofs = [], 0
    for i, s in enumerate(shapes):
        sz = int(np.prod(s))
        leaf = flat[ofs:ofs + sz].reshape(s)
        if dtypes is not None:
            leaf = leaf.astype(dtypes[i])
        out.append(leaf)
        ofs += sz
    return out
