"""Once-per-process deprecation warnings for the legacy collective APIs.

Python's default warning machinery dedupes by (message, module, lineno),
which varies with the *call site*; the deprecation contract of the
``repro.comm`` migration is per *entry point* — every legacy entry point
warns exactly once per process no matter how many call sites touch it
(tests pin this; see tests/test_comm_api.py).  Hence the explicit latch.

No repro imports here: this module sits below everything (core, optim,
comm) so any layer may use it without creating an import cycle.
"""
from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(key: str, message: str) -> None:
    """Emit ``DeprecationWarning(message)`` the first time ``key`` is seen."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, DeprecationWarning, stacklevel=3)


def reset_warned() -> None:
    """Forget all emitted keys (test isolation only)."""
    _WARNED.clear()
