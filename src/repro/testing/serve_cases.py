"""Multi-host serving cases (run on the 8-device host mesh).

The zero3-hosted half of the tentpole's equivalence matrix: cases that
need a real mesh — slot sharding across chips, 1/p weight hosting,
checkpoint → serve round trips — live here and run in a fresh
subprocess (``python -m repro.testing.run_serve_cases``), import-safe
for pytest enumeration exactly like ``conformance_cases``.

The tier certifies the PR's headline claim end to end: zero3-hosted
serving (weights gathered layer-by-layer through ``prefetch_allgather``,
slots sharded lane-major, fresh caches distributed through
``kv_splice``) produces byte-identical tokens to replicated hosting —
greedy AND seeded-sampled, from in-memory weights AND from a restored
training checkpoint of any layout.
"""
import numpy as np
import jax

CASES = {}


def _register(name, fn):
    assert name not in CASES, name
    CASES[name] = fn


def _mesh():
    devs = np.array(jax.devices()).reshape(2, 2, 2)
    return jax.sharding.Mesh(devs, ("pod", "data", "model"))


def _serve(cfg, params, reqs, *, slots=8, max_seq=96, sampler=None,
           hosting="replicated", mesh=None, **kw):
    from repro.serve import ContinuousBatcher
    eng = ContinuousBatcher(params, cfg, slots=slots, max_seq=max_seq,
                            sampler=sampler, hosting=hosting, mesh=mesh,
                            **kw)
    done, stats = eng.run(reqs)
    return {r.rid: r.out for r in done}, stats


def _reqs(cfg, kind="short_chat", n=6, seed=1, max_seq=96):
    from repro.serve import make_scenario
    return make_scenario(cfg, kind=kind, n=n, seed=seed, max_seq=max_seq)


def _b_zero3_identity(arch, kind, prefetch_blocks=0):
    """zero3-hosted tokens == replicated tokens, per request id."""
    from repro.configs import resolve
    from repro.models import init_model
    cfg = resolve(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rep, _ = _serve(cfg, params, _reqs(cfg, kind))
    z3, stats = _serve(cfg, params, _reqs(cfg, kind),
                       hosting="lane_zero3", mesh=_mesh(),
                       prefetch_blocks=prefetch_blocks)
    assert stats["hosting"] == "lane_zero3"
    assert rep == z3, {k: (rep[k], z3[k]) for k in rep if rep[k] != z3[k]}


# every zero3-servable family (hybrid is replicated-only by contract),
# across scenario kinds that exercise refills and bucket spans
for _arch, _kind in (("llama3.2-3b", "short_chat"),
                     ("llama3.2-3b", "bursty"),
                     ("mamba2-780m", "mixed"),
                     ("granite-moe-3b-a800m", "short_chat"),
                     ("llava-next-mistral-7b", "short_chat"),
                     ("whisper-large-v3", "short_chat")):
    _register(f"zero3_identity_{_arch}__{_kind}",
              lambda a=_arch, k=_kind: _b_zero3_identity(a, k))

_register("zero3_identity_llama3.2-3b__blocking_prefetch",
          lambda: _b_zero3_identity("llama3.2-3b", "short_chat",
                                    prefetch_blocks=-1))


def _b_zero3_sampled_replay():
    """Seeded sampling is batching- and hosting-invariant: replicated
    slots=2 vs zero3 slots=8 produce identical sampled tokens."""
    from repro.configs import resolve
    from repro.models import init_model
    from repro.serve import SamplerConfig
    cfg = resolve("llama3.2-3b", smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    samp = SamplerConfig(temperature=0.8, top_p=0.9, seed=11)
    rep, _ = _serve(cfg, params, _reqs(cfg), slots=2, sampler=samp)
    z3, _ = _serve(cfg, params, _reqs(cfg), slots=8, sampler=samp,
                   hosting="lane_zero3", mesh=_mesh())
    assert rep == z3, {k: (rep[k], z3[k]) for k in rep if rep[k] != z3[k]}


_register("zero3_sampled_replay_llama3.2-3b", _b_zero3_sampled_replay)


def _b_tp_serve_identity(arch="llama3.2-3b", kind="short_chat"):
    """Tensor-parallel zero3 hosting (PR 10): the decode/prefill MLPs run
    through mlp_tp over the mesh's 'model' axis — tokens must be
    byte-identical to both plain zero3 hosting and replicated hosting
    (mlp_tp's forward is bitwise vs the replicated MLP, so TP serving is
    a pure latency knob, never an accuracy one)."""
    from repro.configs import resolve
    from repro.models import init_model
    cfg = resolve(arch, smoke=True)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rep, _ = _serve(cfg, params, _reqs(cfg, kind))
    z3, _ = _serve(cfg, params, _reqs(cfg, kind),
                   hosting="lane_zero3", mesh=_mesh())
    tp, stats = _serve(cfg, params, _reqs(cfg, kind),
                       hosting="lane_zero3", mesh=_mesh(),
                       model_parallel=2)
    assert stats["hosting"] == "lane_zero3"
    assert tp == rep, {k: (rep[k], tp[k]) for k in rep if rep[k] != tp[k]}
    assert tp == z3, {k: (z3[k], tp[k]) for k in z3 if z3[k] != tp[k]}


_register("tp_serve_identity_llama3.2-3b__short_chat",
          _b_tp_serve_identity)


def _b_ckpt_roundtrip(gradsync, kind):
    """Real training checkpoint (written by the driver under layout
    ``kind``) -> load_serve_params -> serve: the restored weights must
    serve identically under replicated and zero3 hosting, and for the
    replicated layout, byte-identically to restore_checkpoint's own
    answer — the PR-5 cross-layout canonical path feeding serving."""
    import json
    import pathlib
    import tempfile
    from repro.configs import resolve
    from repro.launch.train import main as train_main
    from repro.serve import load_serve_params
    cfg = resolve("llama3.2-3b", smoke=True)
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        rc = train_main(["--arch", "llama3.2-3b", "--smoke", "--batch",
                         "8", "--seq", "32", "--ckpt", ck, "--steps",
                         "2", "--ckpt-every", "2", "--gradsync",
                         gradsync, "--pods", "2"])
        assert rc == 0, rc
        man = json.loads((pathlib.Path(ck) / "step_2" /
                          "manifest.json").read_text())
        assert man["layout"]["kind"] == kind, man["layout"]
        params, step = load_serve_params(ck, cfg)
        assert step == 2, step
        if kind == "replicated":
            from repro.checkpoint import restore_checkpoint
            from repro.launch.steps import _abs_params
            tmpl = jax.tree.map(lambda t: np.zeros(t.shape, t.dtype),
                                _abs_params(cfg))
            opt_tmpl = {"m": jax.tree.map(
                            lambda a: np.zeros(a.shape, np.float32), tmpl),
                        "v": jax.tree.map(
                            lambda a: np.zeros(a.shape, np.float32), tmpl),
                        "count": np.zeros((), np.int32)}
            (ref, _), _ = restore_checkpoint(ck, (tmpl, opt_tmpl))
            mism = [p for p, (a, b) in enumerate(zip(
                jax.tree.leaves(ref), jax.tree.leaves(params)))
                if not np.array_equal(np.asarray(a), np.asarray(b))]
            assert not mism, f"leaves {mism} differ from direct restore"
        rep, _ = _serve(cfg, params, _reqs(cfg), slots=2)
        z3, _ = _serve(cfg, params, _reqs(cfg), slots=8,
                       hosting="lane_zero3", mesh=_mesh())
    assert rep == z3, {k: (rep[k], z3[k]) for k in rep if rep[k] != z3[k]}


for _gs, _kind in (("native", "replicated"), ("lane_zero1", "zero1"),
                   ("lane_zero3", "zero3")):
    _register(f"serve_ckpt_roundtrip__{_gs}",
              lambda g=_gs, k=_kind: _b_ckpt_roundtrip(g, k))


def main(argv):
    names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    import sys
    sys.exit(main(sys.argv[1:]))
