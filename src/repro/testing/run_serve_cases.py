"""Subprocess entry point for the multi-host serving cases.

Sets the host-device-count flag BEFORE any jax import, then delegates to
repro.testing.serve_cases.main.  Never import this from pytest.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=8 "
    + os.environ.get("XLA_FLAGS", ""))

from repro.testing.serve_cases import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
