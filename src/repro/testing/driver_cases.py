"""Multi-device restart-matrix cases for the training driver.

IMPORT-SAFE: pytest imports this module only to enumerate case names
(tests/test_checkpoint_runtime.py); EXECUTING the cases needs 8 host
devices — run ``python -m repro.testing.run_driver_cases`` (which sets
the device-count flag in a fresh process before importing jax).

Covered here (the pieces that need a real multi-pod mesh):
  * lane_zero3 checkpoint round-trip: driver trains, checkpoints the
    (L, B, p, s) masters, and resumes — then the SAME checkpoint restores
    onto an elastically SHRUNK mesh (p′ < p) bit-identically, params AND
    optimizer moments, and the driver finishes the run on the survivors.
  * resume-vs-uninterrupted trajectory: a lane_pipelined run resumed
    from a mid-run checkpoint writes a final checkpoint byte-identical
    to the uninterrupted run's (same mesh ⇒ same reduction order ⇒ the
    restart must be invisible).
Single-device restart cases (SIGTERM, crash step accounting, resume at
completion) live directly in tests/test_checkpoint_runtime.py.
"""
import pathlib
import sys
import tempfile

import numpy as np

CASES = {}


def case(f):
    CASES[f.__name__] = f
    return f


def _train(argv):
    from repro.launch.train import main
    rc = main(argv)
    assert rc == 0, rc


def _read_step_dir(d: pathlib.Path) -> dict:
    return {p.name: p.read_bytes() for p in sorted(d.iterdir())}


@case
def zero3_driver_elastic_restore_bitident():
    import json

    import jax
    import jax.tree_util as jtu
    from repro.checkpoint import latest_step, restore_checkpoint
    from repro.configs import resolve, RunConfig
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import init_lane_train_state
    from repro.models import init_model
    from repro.runtime.elastic import plan_elastic_mesh
    cfg = resolve("llama3.2-3b", smoke=True)
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        args = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--ckpt", ck, "--log-every", "1",
                "--gradsync", "lane_zero3", "--pods", "2"]
        _train([*args, "--steps", "2", "--ckpt-every", "2"])
        assert latest_step(ck) == 2

        # restore the p-chip checkpoint onto the SHRUNK survivor mesh
        # (lost pod-0 slice) and check bit-identity through the canonical
        # layout — params AND optimizer moments
        full = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        lost = [i for i in range(8)
                if np.unravel_index(i, (2, 2, 2))[0] == 0]
        mesh2 = plan_elastic_mesh(full.axis_names, full.devices.shape,
                                  lost).make()
        run = RunConfig(model=cfg,
                        shape=ShapeConfig("cli", 32, 8, "train"),
                        gradsync="lane_zero3")
        st = init_lane_train_state(cfg, run, mesh2,
                                   init_model(jax.random.PRNGKey(0), cfg))
        (p2, o2), step = restore_checkpoint(ck, (st.params, st.opt_state),
                                            layout=st.ckpt_layout)
        assert step == 2
        d = pathlib.Path(ck) / "step_2"
        man = json.loads((d / "manifest.json").read_text())
        assert man["layout"]["kind"] == "zero3"
        pairs, _ = jtu.tree_flatten_with_path((p2, o2))
        assert len(pairs) == len(man["leaves"])
        for i, (path, leaf) in enumerate(pairs):
            canon = st.ckpt_layout.to_canonical(path, np.asarray(leaf))
            stored = np.load(d / f"arr_{i}.npy")
            assert np.array_equal(canon, stored), \
                f"leaf {i} not bit-identical after p→p′ restore"

        # and the driver itself finishes the run on the survivors
        _train([*args, "--steps", "3", "--lose-chips",
                ",".join(str(i) for i in lost)])
        assert latest_step(ck) == 3


@case
def driver_resume_matches_uninterrupted():
    import shutil
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "2", "--gradsync",
                "lane_pipelined", "--pods", "2", "--ckpt-every", "2",
                "--ckpt", ck, "--steps", "4"]
        _train(base)                          # uninterrupted: saves 2, 4
        step4 = pathlib.Path(ck) / "step_4"
        fa = _read_step_dir(step4)
        # simulate a crash right after the step-2 commit, then restart
        # with the IDENTICAL config: the restart must be invisible
        shutil.rmtree(step4)
        assert latest_step(ck) == 2
        _train(base)
        assert latest_step(ck) == 4
        fb = _read_step_dir(step4)
        assert set(fa) == set(fb)
        for name in fa:
            assert fa[name] == fb[name], \
                f"{name} differs between resumed and uninterrupted runs"


def main(argv):
    names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
