"""Multi-device restart-matrix cases for the training driver.

IMPORT-SAFE: pytest imports this module only to enumerate case names
(tests/test_checkpoint_runtime.py); EXECUTING the cases needs 8 host
devices — run ``python -m repro.testing.run_driver_cases`` (which sets
the device-count flag in a fresh process before importing jax).

Covered here (the pieces that need a real multi-pod mesh):
  * lane_zero3 checkpoint round-trip: driver trains, checkpoints the
    (L, B, p, s) masters, and resumes — then the SAME checkpoint restores
    onto an elastically SHRUNK mesh (p′ < p) bit-identically, params AND
    optimizer moments, and the driver finishes the run on the survivors.
  * resume-vs-uninterrupted trajectory: a lane_pipelined run resumed
    from a mid-run checkpoint writes a final checkpoint byte-identical
    to the uninterrupted run's (same mesh ⇒ same reduction order ⇒ the
    restart must be invisible).
  * the ``fault_*`` matrix (also runnable alone: ``--match fault_`` /
    ``make fault-smoke``): the injected-fault recovery surface —
    corrupt-latest crc fallback, kill-mid-write ``.old`` swap,
    transient ckpt-I/O retry, quorum-masked grad-sync bit-identity,
    and the DEGRADED→RESTART ladder end-to-end.
Single-device restart cases (SIGTERM, crash step accounting, resume at
completion) live directly in tests/test_checkpoint_runtime.py, and the
single-device fault/quorum/integrity units in tests/test_faults.py.
"""
import pathlib
import sys
import tempfile

import numpy as np

CASES = {}


def case(f):
    CASES[f.__name__] = f
    return f


def _train(argv):
    from repro.launch.train import main
    rc = main(argv)
    assert rc == 0, rc


def _read_step_dir(d: pathlib.Path) -> dict:
    return {p.name: p.read_bytes() for p in sorted(d.iterdir())}


@case
def zero3_driver_elastic_restore_bitident():
    import json

    import jax
    import jax.tree_util as jtu
    from repro.checkpoint import latest_step, restore_checkpoint
    from repro.configs import resolve, RunConfig
    from repro.configs.base import ShapeConfig
    from repro.launch.steps import init_lane_train_state
    from repro.models import init_model
    from repro.runtime.elastic import plan_elastic_mesh
    cfg = resolve("llama3.2-3b", smoke=True)
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        args = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--ckpt", ck, "--log-every", "1",
                "--gradsync", "lane_zero3", "--pods", "2"]
        _train([*args, "--steps", "2", "--ckpt-every", "2"])
        assert latest_step(ck) == 2

        # restore the p-chip checkpoint onto the SHRUNK survivor mesh
        # (lost pod-0 slice) and check bit-identity through the canonical
        # layout — params AND optimizer moments
        full = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        lost = [i for i in range(8)
                if np.unravel_index(i, (2, 2, 2))[0] == 0]
        mesh2 = plan_elastic_mesh(full.axis_names, full.devices.shape,
                                  lost).make()
        run = RunConfig(model=cfg,
                        shape=ShapeConfig("cli", 32, 8, "train"),
                        gradsync="lane_zero3")
        st = init_lane_train_state(cfg, run, mesh2,
                                   init_model(jax.random.PRNGKey(0), cfg))
        (p2, o2), step = restore_checkpoint(ck, (st.params, st.opt_state),
                                            layout=st.ckpt_layout)
        assert step == 2
        d = pathlib.Path(ck) / "step_2"
        man = json.loads((d / "manifest.json").read_text())
        assert man["layout"]["kind"] == "zero3"
        pairs, _ = jtu.tree_flatten_with_path((p2, o2))
        assert len(pairs) == len(man["leaves"])
        for i, (path, leaf) in enumerate(pairs):
            canon = st.ckpt_layout.to_canonical(path, np.asarray(leaf))
            stored = np.load(d / f"arr_{i}.npy")
            assert np.array_equal(canon, stored), \
                f"leaf {i} not bit-identical after p→p′ restore"

        # and the driver itself finishes the run on the survivors
        _train([*args, "--steps", "3", "--lose-chips",
                ",".join(str(i) for i in lost)])
        assert latest_step(ck) == 3


@case
def driver_resume_matches_uninterrupted():
    import shutil
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "2", "--gradsync",
                "lane_pipelined", "--pods", "2", "--ckpt-every", "2",
                "--ckpt", ck, "--steps", "4"]
        _train(base)                          # uninterrupted: saves 2, 4
        step4 = pathlib.Path(ck) / "step_4"
        fa = _read_step_dir(step4)
        # simulate a crash right after the step-2 commit, then restart
        # with the IDENTICAL config: the restart must be invisible
        shutil.rmtree(step4)
        assert latest_step(ck) == 2
        _train(base)
        assert latest_step(ck) == 4
        fb = _read_step_dir(step4)
        assert set(fa) == set(fb)
        for name in fa:
            assert fa[name] == fb[name], \
                f"{name} differs between resumed and uninterrupted runs"


def _zero3_family_roundtrip(arch):
    """The driver trains this family's smoke arch through lane_zero3 on
    the 8-device multi-pod mesh, checkpoints the (L, B, p, s) masters
    (blocks AND extras), and resumes bit-exactly — then the SAME
    checkpoint restores onto an elastically shrunk mesh and the run
    finishes on the survivors (the transformer-family variant of this,
    plus the moment-level bit-identity audit, lives in
    zero3_driver_elastic_restore_bitident)."""
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        args = ["--arch", arch, "--smoke", "--batch", "8", "--seq", "32",
                "--ckpt", ck, "--log-every", "1", "--ckpt-every", "2",
                "--gradsync", "lane_zero3", "--pods", "2"]
        _train([*args, "--steps", "2"])
        assert latest_step(ck) == 2
        _train([*args, "--steps", "3"])               # restore path
        assert latest_step(ck) == 3
        # elastic shrink: lose pod 0, finish on the 4 survivors
        lost = [i for i in range(8)
                if np.unravel_index(i, (2, 2, 2))[0] == 0]
        _train([*args, "--steps", "4", "--lose-chips",
                ",".join(str(i) for i in lost)])
        assert latest_step(ck) == 4


@case
def zero3_driver_family_ssm():
    _zero3_family_roundtrip("mamba2-780m")


@case
def zero3_driver_family_hybrid():
    _zero3_family_roundtrip("zamba2-7b")


@case
def zero3_driver_family_moe():
    _zero3_family_roundtrip("granite-moe-3b-a800m")


@case
def zero3_driver_degenerate_n1():
    """Degenerate topology: --batch 2 --pods 2 forces the mesh to
    (pod=2, data=1, model=4) — the node level is trivial (n=1) and the
    lane axis carries the whole batch product.  lane_zero3 must still
    shard 1/p, train, checkpoint and resume."""
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        args = ["--arch", "llama3.2-3b", "--smoke", "--batch", "2",
                "--seq", "32", "--ckpt", ck, "--log-every", "1",
                "--ckpt-every", "2", "--gradsync", "lane_zero3",
                "--pods", "2"]
        _train([*args, "--steps", "2"])
        assert latest_step(ck) == 2
        _train([*args, "--steps", "3"])
        assert latest_step(ck) == 3


@case
def driver_cross_layout_restore_chain():
    """Cross-layout restore (satellite): ONE checkpoint directory is
    resumed under a CHAIN of different strategy layouts — zero3 writes,
    zero1 resumes (and writes its own layout), native resumes that, and
    zero3 takes it back.  Every hop converts through the canonical flat
    order (checkpoint/layouts.py + steps.restore_lane_train_state); the
    smoke model is fp32, so the conversions are pure re-layouts — pinned
    by comparing the resumed step's loss between a cross-layout resume
    and a same-layout resume of the SAME checkpoint (identical restored
    values ⇒ identical forward)."""
    import contextlib
    import io
    import json
    import re
    import shutil
    from repro.checkpoint import latest_step

    def run(gradsync, steps, ck):
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            _train(["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                    "--seq", "32", "--ckpt", ck, "--log-every", "1",
                    "--ckpt-every", "1", "--gradsync", gradsync,
                    "--pods", "2", "--steps", str(steps)])
        return buf.getvalue()

    def first_loss(out):
        m = re.search(r"step\s+\d+\s+loss\s+([\d.]+)", out)
        assert m, out
        return float(m.group(1))

    def manifest_kind(ck):
        d = pathlib.Path(ck) / f"step_{latest_step(ck)}"
        return json.loads(
            (d / "manifest.json").read_text())["layout"].get("kind")

    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        run("lane_zero3", 2, ck)
        assert manifest_kind(ck) == "zero3"
        ck_ref = f"{td}/ck_ref"
        shutil.copytree(ck, ck_ref)
        # reference: same-layout resume of the same checkpoint — its
        # step-2 loss is the ground truth the cross-layout resume must hit
        ref = first_loss(run("lane_zero3", 3, ck_ref))
        out1 = run("lane_zero1", 4, ck)      # zero3 ckpt -> zero1 run
        assert "resumed from step 2" in out1
        assert manifest_kind(ck) == "zero1"
        got = first_loss(out1)
        assert abs(got - ref) <= 1e-4 * max(1.0, abs(ref)), (got, ref)
        out2 = run("native", 6, ck)          # zero1 ckpt -> replicated run
        assert "resumed from step 4" in out2
        assert manifest_kind(ck) == "replicated"
        out3 = run("lane_zero3", 8, ck)      # replicated ckpt -> zero3 run
        assert "resumed from step 6" in out3
        assert manifest_kind(ck) == "zero3"


@case
def driver_tp2_restores_tp1_ckpt_bitident():
    """Third-axis restore (PR-10 satellite): a TP=2 run restores from a
    TP=1 lane_zero3 checkpoint through the canonical flat order and hits
    the SAME losses as the TP=1 resume of the same checkpoint.  The mesh
    reshapes (2,4,1) → (2,2,2), so p changes 8 → 4 under the resume as
    well — geometry-elastic AND tensor-parallel at once; the TP step
    itself being bitwise vs TP=1 is pinned in collective_cases."""
    import contextlib
    import io
    import re
    import shutil
    from repro.checkpoint import latest_step

    def run(ck, steps, tp):
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            _train(["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                    "--seq", "32", "--ckpt", ck, "--log-every", "1",
                    "--ckpt-every", "2", "--gradsync", "lane_zero3",
                    "--pods", "2", "--steps", str(steps),
                    "--model-parallel", str(tp)])
        return buf.getvalue()

    def losses(out):
        got = re.findall(r"step\s+(\d+)\s+loss\s+([\d.]+)", out)
        assert got, out
        return {int(s): l for s, l in got}

    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        run(ck, 2, tp=1)
        assert latest_step(ck) == 2
        ck_ref = f"{td}/ck_ref"
        shutil.copytree(ck, ck_ref)
        ref = losses(run(ck_ref, 4, tp=1))          # TP=1 resume: ground truth
        out = run(ck, 4, tp=2)                      # TP=2 resume, same ckpt
        assert "resumed from step 2" in out, out
        got = losses(out)
        for s in (2, 3):                            # steps are 0-indexed
            assert got[s] == ref[s], (s, got[s], ref[s])
        assert latest_step(ck) == 4


@case
def driver_ep_moe_roundtrip():
    """Expert-parallel driver round trip: the MoE smoke arch trains under
    lane_zero3 + --expert-parallel (never-gathered (L, E/p) expert
    master, moe_route alltoalls), checkpoints the ep-flavored layout,
    resumes from it, and its losses match the gather-based zero3 run of
    the same seed step for step (EP==gather bitwise is pinned in
    collective_cases; here the pin is the driver+checkpoint plumbing)."""
    import contextlib
    import io
    import re
    from repro.checkpoint import latest_step

    def run(ck, steps, *, ep, blocks=1):
        buf = io.StringIO()
        extra = ["--expert-parallel", "--ep-blocks", str(blocks)] \
            if ep else []
        with contextlib.redirect_stdout(buf):
            _train(["--arch", "dbrx-132b", "--smoke", "--batch", "8",
                    "--seq", "16", "--ckpt", ck, "--log-every", "1",
                    "--ckpt-every", "2", "--gradsync", "lane_zero3",
                    "--pods", "2", "--steps", str(steps), *extra])
        return buf.getvalue()

    def losses(out):
        return {int(s): l for s, l in
                re.findall(r"step\s+(\d+)\s+loss\s+([\d.]+)", out)}

    with tempfile.TemporaryDirectory() as td:
        ref = losses(run(f"{td}/ckg", 2, ep=False))
        out1 = run(f"{td}/cke", 2, ep=True, blocks=2)
        got = losses(out1)
        assert got == ref, (got, ref)
        out2 = run(f"{td}/cke", 4, ep=True, blocks=2)   # ep→ep resume
        assert "resumed from step 2" in out2, out2
        assert latest_step(f"{td}/cke") == 4


@case
def fault_ladder_degraded_restart_bitident():
    """THE acceptance ladder: pod 1 stops heartbeating at step 2 (injected
    pod_lost), the driver degrades (quorum-masked steps with pod 1's
    contribution zeroed), exceeds the staleness bound, RESTARTs —
    emergency checkpoint, elastic shrink to the survivor pod — and
    finishes.  The final params must be BIT-identical to a clean launch
    on the already-shrunken mesh resumed from the same emergency
    checkpoint: the in-process restart is indistinguishable from a
    scheduler respawn."""
    import contextlib
    import io
    import shutil
    from repro.checkpoint import latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "1", "--gradsync",
                "lane_quorum", "--pods", "2", "--ckpt", ck,
                "--ckpt-every", "100", "--steps", "8", "--seed", "7"]
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            _train([*base, "--fault-plan", "pod_lost@2:pod=1",
                    "--quorum-staleness", "2"])
        out = buf.getvalue()
        assert "HEALTHY -> DEGRADED" in out, out
        assert "DEGRADED -> RESTART" in out, out
        assert "replayable from (seed=7, step=2)" in out, out
        assert latest_step(ck) == 8
        fa = _read_step_dir(pathlib.Path(ck) / "step_8")

        # clean reference: fresh launch on the survivor mesh, resuming
        # the SAME emergency checkpoint (step_4)
        ck_b = f"{td}/ck_b"
        shutil.copytree(ck, ck_b)
        shutil.rmtree(pathlib.Path(ck_b) / "step_8")
        lost = [i for i in range(8)
                if np.unravel_index(i, (2, 2, 2))[0] == 1]
        base_b = [a if a != ck else ck_b for a in base]
        _train([*base_b, "--lose-chips", ",".join(str(i) for i in lost)])
        fb = _read_step_dir(pathlib.Path(ck_b) / "step_8")
        assert set(fa) == set(fb)
        for name in fa:
            assert fa[name] == fb[name], \
                f"{name}: ladder restart differs from clean shrunken launch"


@case
def fault_corrupt_latest_falls_back():
    """Post-commit rot of the NEWEST checkpoint (injected corrupt_leaf):
    restart crc-verifies, skips the rotted step_4, restores the previous
    verified commit, and re-earns the lost steps."""
    import contextlib
    import io
    from repro.checkpoint import (CheckpointCorruptError, latest_step,
                                  latest_verified_step, verify_checkpoint)
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "1", "--gradsync", "lane",
                "--pods", "2", "--ckpt", ck, "--ckpt-every", "2"]
        _train([*base, "--steps", "4",
                "--fault-plan", "corrupt_leaf@4:leaf=1"])
        assert latest_step(ck) == 4
        try:
            verify_checkpoint(ck, 4)
            raise AssertionError("injected corruption not detected")
        except CheckpointCorruptError:
            pass
        assert latest_verified_step(ck) == 2
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            _train([*base, "--steps", "6"])
        assert "resumed from step 2" in buf.getvalue()
        assert latest_step(ck) == 6
        verify_checkpoint(ck, 6)


@case
def fault_ckpt_io_transient_retry():
    """Transient checkpoint-I/O errors (injected ckpt_io, 2 failing
    attempts) are absorbed by save_checkpoint's bounded retry — the
    commit lands on the 3rd attempt and verifies."""
    from repro.checkpoint import latest_step, verify_checkpoint
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        _train(["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "1", "--gradsync", "lane",
                "--pods", "2", "--ckpt", ck, "--ckpt-every", "2",
                "--steps", "2", "--fault-plan", "ckpt_io@2:count=2"])
        assert latest_step(ck) == 2
        verify_checkpoint(ck, 2)


@case
def fault_kill_mid_write_restores_prior_commit():
    """Crash in the worst overwrite window — after the committed copy was
    parked to ``.old``, before the new one renamed in (plus a stray
    ``.tmp`` and an operator's ``step_backup`` dir).  The scanner must
    treat the lone ``step_2.old`` as committed, restore it, and the next
    save must re-commit the final name cleanly."""
    import contextlib
    import io
    from repro.checkpoint import committed_steps, latest_step
    with tempfile.TemporaryDirectory() as td:
        ck = f"{td}/ck"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "1", "--gradsync", "lane",
                "--pods", "2", "--ckpt", ck, "--ckpt-every", "2"]
        _train([*base, "--steps", "2"])
        d = pathlib.Path(ck)
        (d / "step_2").rename(d / "step_2.old")      # parked, not yet
        (d / "step_2.tmp").mkdir()                   # ...renamed in
        (d / "step_2.tmp" / "arr_0.npy").write_bytes(b"partial")
        (d / "step_backup").mkdir()                  # stray operator dir
        assert committed_steps(ck) == [2]
        buf = io.StringIO()
        with contextlib.redirect_stdout(buf):
            _train([*base, "--steps", "3"])
        assert "resumed from step 2" in buf.getvalue()
        assert latest_step(ck) == 3
        assert (d / "step_3" / "manifest.json").exists()


@case
def fault_quorum_masked_equals_skipped_microbatch():
    """Numerical contract of the quorum-degraded step: masking pod 1 out
    of the quorum is BIT-identical to a run whose batch simply repeats
    pod 0's rows under plain ``lane`` sync.  (psum([v0, 0])/1 == v0 and
    psum([v0, v0])/2 == v0 exactly; the quorum mean rescales by the live
    count, so the masked pod's microbatch is cleanly *skipped*, not
    averaged in as zeros.)"""
    import repro.data.pipeline as pl
    with tempfile.TemporaryDirectory() as td:
        ck_a, ck_b = f"{td}/a", f"{td}/b"
        base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                "--seq", "32", "--log-every", "1", "--pods", "2",
                "--ckpt-every", "2", "--steps", "2", "--seed", "11"]
        # run A: pod 1 masked out of the quorum for the whole run
        _train([*base, "--ckpt", ck_a, "--gradsync", "lane_quorum",
                "--fault-plan", "pod_slow@0-1:pod=1",
                "--quorum-staleness", "99"])
        # run B: plain lane sync, but pod 1's rows REPLACED by pod 0's
        # (averaging two identical microbatches == using one)
        orig = pl.ShardedLoader.batch_at

        def duped(self, step):
            rows = self.host_rows() // 2
            toks, labs = self.batch_slice(step, 0, rows)
            return (np.concatenate([toks, toks]),
                    np.concatenate([labs, labs]))

        pl.ShardedLoader.batch_at = duped
        try:
            _train([*base, "--ckpt", ck_b, "--gradsync", "lane"])
        finally:
            pl.ShardedLoader.batch_at = orig
        fa = _read_step_dir(pathlib.Path(ck_a) / "step_2")
        fb = _read_step_dir(pathlib.Path(ck_b) / "step_2")
        assert set(fa) == set(fb)
        for name in fa:
            assert fa[name] == fb[name], \
                f"{name}: quorum-masked step differs from skipped microbatch"


def main(argv):
    argv = list(argv)
    if argv[:1] == ["--match"]:
        pat = argv[1] if len(argv) > 1 else ""
        names = sorted(n for n in CASES if pat in n)
        if not names:
            print(f"no cases match {pat!r}")
            return 1
    else:
        names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
