"""Per-arch smoke pass: reduced config, fwd + grad + prefill/decode on CPU.

Run directly:  PYTHONPATH=src python -m repro.testing.model_smoke [arch ...]
Also imported by tests/test_archs.py (single-device, no XLA_FLAGS needed).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import resolve, all_archs
from repro.models import (init_model, model_forward, init_cache, prefill,
                          decode_step, loss_fn)


def _extra(cfg, B, T, key):
    if cfg.family == "vlm":
        return jax.random.normal(key, (B, cfg.vision_tokens, cfg.d_model),
                                 jnp.float32)
    if cfg.family == "audio":
        return jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model),
                                 jnp.float32)
    return None


def smoke_arch(arch: str, B: int = 2, T: int = 32) -> dict:
    """Returns dict of checked quantities; raises on any failure."""
    cfg = resolve(arch, smoke=True)
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    params = init_model(k1, cfg)
    n_params = sum(int(np.prod(a.shape)) for a in jax.tree.leaves(params))

    T_text = T - (cfg.vision_tokens if cfg.family == "vlm" else 0)
    tokens = jax.random.randint(k2, (B, T_text), 0, cfg.vocab_size)
    extra = _extra(cfg, B, T, k3)

    # ---- forward ----
    logits, aux = jax.jit(
        lambda p, t, e: model_forward(p, cfg, t, extra_embeds=e))(
            params, tokens, extra)
    T_total = T if cfg.family == "vlm" else T_text
    assert logits.shape == (B, T_total, cfg.vocab_size), logits.shape
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    # ---- loss + grad (one train step worth of math) ----
    # next-token labels (shifted); last position masked out
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full((B, 1), -100, tokens.dtype)], axis=1)
    lval, grads = jax.jit(jax.value_and_grad(
        lambda p: loss_fn(p, cfg, tokens, labels, extra_embeds=extra)))(params)
    assert bool(jnp.isfinite(lval)), "loss not finite"
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, f"bad grad norm {gnorm}"

    # ---- prefill + 2 decode steps ----
    cache = init_cache(cfg, B, max_seq=T_text + 8, dtype=jnp.float32)
    lg, state = jax.jit(
        lambda p, t, c, e: prefill(p, cfg, t, c, extra_embeds=e))(
            params, tokens, cache, extra)
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), "NaN in prefill logits"
    step = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s))
    tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    for _ in range(2):
        lg2, state = step(params, tok, state)
        assert lg2.shape == (B, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(lg2).all()), "NaN in decode logits"
        tok = jnp.argmax(lg2[:, -1:], -1).astype(jnp.int32)

    # ---- decode-vs-forward consistency (teacher forcing) ----
    # run forward on tokens; prefill on tokens[:, :-1] then decode last token
    if cfg.family not in ("vlm", "audio"):
        cache2 = init_cache(cfg, B, max_seq=T_text + 8, dtype=jnp.float32)
        lg_a, st = jax.jit(lambda p, t, c: prefill(p, cfg, t, c))(
            params, tokens[:, :-1], cache2)
        lg_b, _ = step(params, tokens[:, -1:], st)
        full_logits, _ = jax.jit(
            lambda p, t: model_forward(p, cfg, t))(params, tokens)
        np.testing.assert_allclose(np.asarray(lg_b[:, 0]),
                                   np.asarray(full_logits[:, -1]),
                                   rtol=2e-2, atol=2e-2)

    return {"arch": arch, "params": n_params, "loss": float(lval)}


def main(argv):
    archs = argv or all_archs()
    fails = 0
    for a in archs:
        try:
            info = smoke_arch(a)
            print(f"PASS {a}  params={info['params']:,}  loss={info['loss']:.3f}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            import traceback
            print(f"FAIL {a}: {type(e).__name__}: {str(e)[:300]}")
            traceback.print_exc(limit=4)
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
