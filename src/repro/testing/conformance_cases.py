"""Conformance grid for EVERY lane collective (paper §3, Listings 1-6).

Where ``collective_cases`` hand-picks representative scenarios, this
module *generates* a dense grid: each of the lane collectives
(bcast/reduce/scan/gather/scatter/alltoall plus allreduce/RS/AG) against
its single-process oracle, across

  * odd topologies — n=1 (every node a single process: the lane level IS
    the communicator), N=1 (single node: the node level is everything),
    heterogeneous node-axis sizes ((data, model) = (4, 1)),
  * non-power-of-two payloads (odd rows per rank — the minimal
    divisibility the mock-ups require, nothing more),
  * bf16 / int32 payloads (integer-valued so reductions are EXACT in
    every dtype — a tolerance would hide dtype-dispatch bugs),
  * non-default roots and the unreplicated-root SPMD emulation paths,
  * the divisibility preconditions (ValueError on bad leading dims).

IMPORT-SAFE like collective_cases: importing never touches XLA flags, so
pytest can enumerate CASES; executing needs 8 host devices — run
``python -m repro.testing.run_conformance_cases`` (fresh process, flag
set before the jax import).
"""
import sys

import numpy as np            # noqa: E402
import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import (      # noqa: E402
    LaneTopology, allreduce_lane, reduce_scatter_lane, allgather_lane,
    bcast_lane, alltoall_lane, reduce_lane, gather_lane, scatter_lane,
    scan_lane,
)
from repro.core import ref as _ref  # noqa: E402


# ---------------------------------------------------------------------------
# topology grid (all use the 8 host devices)
# ---------------------------------------------------------------------------

TOPOS = {
    # name: (mesh shape, axis names, node_axes, lane_axis)
    "t2": ((4, 2), ("lane", "node"), ("node",), "lane"),       # n=2, N=4
    "t3": ((2, 2, 2), ("pod", "data", "model"),
           ("data", "model"), "pod"),                          # n=4, N=2
    "het": ((2, 4, 1), ("pod", "data", "model"),
            ("data", "model"), "pod"),                         # (4,1) node
    "n1": ((8, 1), ("lane", "node"), ("node",), "lane"),       # n=1 (k=1)
    "N1": ((1, 8), ("lane", "node"), ("node",), "lane"),       # single node
}

DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "int32": jnp.int32,
}


def _make(topo_key):
    shape, names, node_axes, lane = TOPOS[topo_key]
    mesh = jax.make_mesh(shape, names)
    return mesh, LaneTopology(node_axes=node_axes, lane_axis=lane)


def _payload(p, rows, feat, dtype_key, seed):
    """Stacked per-rank inputs as fp64-exact numpy.

    bf16/int32 use small integers so every reduction below is exact in
    the target dtype (bf16 represents integers up to 256 exactly; the
    deepest sum here is bounded by 8 ranks × |4|, plus prefix depth)."""
    rng = np.random.default_rng(seed)
    if dtype_key == "f32":
        return rng.normal(size=(p, rows, feat)).astype(np.float32)
    return rng.integers(-4, 5, size=(p, rows, feat)).astype(
        np.float32 if dtype_key == "bf16" else np.int32)


def _run(mesh, topo, fn, xs, dtype_key):
    """Scatter per-rank inputs, run the shard_map'd collective in the
    target dtype, gather per-rank outputs back as float/int numpy."""
    p, rows = xs.shape[0], xs.shape[1]
    spec = P((topo.lane_axis, *topo.node_axes))
    flat = jnp.asarray(xs.reshape(p * rows, *xs.shape[2:]),
                       DTYPES[dtype_key])
    arr = jax.device_put(flat, jax.sharding.NamedSharding(mesh, spec))
    shard_fn = jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)
    out = jax.jit(shard_fn)(arr)
    out = np.asarray(out).astype(xs.dtype)
    orows = out.shape[0] // p
    return out.reshape(p, orows, *out.shape[1:])


def _check(got, want, dtype_key):
    if dtype_key == "f32":
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


def _replicate_root_node(xs, root_lane, n):
    """SPMD rooted-collective convention: the root buffer is replicated
    over the root lane's chips (global ranks root_lane·n .. +n-1)."""
    xs = xs.copy()
    base = root_lane * n
    for i in range(n):
        xs[base + i] = xs[base]
    return xs


# ---------------------------------------------------------------------------
# per-collective builders: (mesh, topo, dtype_key, seed) -> None (asserts)
# ---------------------------------------------------------------------------
# m (rows per divisibility unit) is odd everywhere — the grid's payloads
# are exactly the minimal-divisibility sizes, never "nice" powers of two.

def _b_allreduce(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo, lambda x: allreduce_lane(x, topo), xs, dt)
    _check(out, _ref.oracle_allreduce(xs), dt)


def _b_reduce_scatter(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo, lambda x: reduce_scatter_lane(x, topo), xs, dt)
    _check(out, _ref.oracle_reduce_scatter(xs), dt)


def _b_allgather(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3, 2, dt, seed)
    out = _run(mesh, topo, lambda x: allgather_lane(x, topo), xs, dt)
    _check(out, _ref.oracle_allgather(xs), dt)


def _b_bcast(mesh, topo, dt, seed, root_lane=0):
    n, N = topo.sizes(mesh)
    xs = _replicate_root_node(_payload(n * N, 3 * n, 2, dt, seed),
                              root_lane, n)
    out = _run(mesh, topo,
               lambda x: bcast_lane(x, topo, root_lane=root_lane), xs, dt)
    _check(out, _ref.oracle_bcast(xs, root=root_lane * n), dt)


def _b_bcast_unreplicated(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: bcast_lane(x, topo, root_replicated=False), xs, dt)
    _check(out, _ref.oracle_bcast(xs, root=0), dt)


def _b_reduce(mesh, topo, dt, seed, root_lane=0, root_node=0):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: reduce_lane(x, topo, root_lane=root_lane,
                                     root_node=root_node), xs, dt)
    _check(out, _ref.oracle_reduce(xs, root=root_lane * n + root_node), dt)


def _b_scan(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo, lambda x: scan_lane(x, topo), xs, dt)
    _check(out, _ref.oracle_scan(xs), dt)


def _b_gather(mesh, topo, dt, seed, root_lane=0, root_node=0):
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, 3, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: gather_lane(x, topo, root_lane=root_lane,
                                     root_node=root_node), xs, dt)
    _check(out, _ref.oracle_gather(xs, root=root_lane * n + root_node), dt)


def _b_scatter(mesh, topo, dt, seed, root_lane=0):
    n, N = topo.sizes(mesh)
    p = n * N
    xs = _replicate_root_node(_payload(p, 3 * p, 2, dt, seed), root_lane, n)
    out = _run(mesh, topo,
               lambda x: scatter_lane(x, topo, root_lane=root_lane), xs, dt)
    _check(out, _ref.oracle_scatter(xs, root=root_lane * n), dt)


def _b_scatter_unreplicated(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: scatter_lane(x, topo, root_replicated=False),
               xs, dt)
    _check(out, _ref.oracle_scatter(xs, root=0), dt)


def _b_alltoall(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo, lambda x: alltoall_lane(x, topo), xs, dt)
    _check(out, _ref.oracle_alltoall(xs), dt)


BUILDERS = {
    "allreduce": _b_allreduce,
    "reduce_scatter": _b_reduce_scatter,
    "allgather": _b_allgather,
    "bcast": _b_bcast,
    "reduce": _b_reduce,
    "scan": _b_scan,
    "gather": _b_gather,
    "scatter": _b_scatter,
    "alltoall": _b_alltoall,
}

# the six collectives the PR-2 conformance mandate names (the other three
# also run, across the odd topologies)
NAMED = ("bcast", "reduce", "scan", "gather", "scatter", "alltoall")


# ---------------------------------------------------------------------------
# grid registration
# ---------------------------------------------------------------------------

CASES = {}


def _register(name, fn):
    assert name not in CASES, name
    CASES[name] = fn


def _add(coll, topo_key, dt, seed, builder=None, suffix=""):
    builder = builder or BUILDERS[coll]

    def run(builder=builder, topo_key=topo_key, dt=dt, seed=seed):
        mesh, topo = _make(topo_key)
        builder(mesh, topo, dt, seed)

    _register(f"{coll}{suffix}__{topo_key}__{dt}", run)


_seed = 100
for _topo_key in TOPOS:
    for _coll in BUILDERS:
        _seed += 1
        _add(_coll, _topo_key, "f32", _seed)

for _dt in ("bf16", "int32"):
    for _coll in NAMED:
        _seed += 1
        _add(_coll, "t3", _dt, _seed)

# non-default roots (masked-root SPMD paths beyond lane 0)
_add("bcast", "t2", "f32", 201, suffix="_rootlane1",
     builder=lambda m, t, dt, s: _b_bcast(m, t, dt, s, root_lane=1))
_add("reduce", "t2", "f32", 202, suffix="_root11",
     builder=lambda m, t, dt, s: _b_reduce(m, t, dt, s, root_lane=1,
                                           root_node=1))
_add("gather", "t3", "f32", 203, suffix="_root12",
     builder=lambda m, t, dt, s: _b_gather(m, t, dt, s, root_lane=1,
                                           root_node=2))
_add("scatter", "t2", "f32", 204, suffix="_rootlane2",
     builder=lambda m, t, dt, s: _b_scatter(m, t, dt, s, root_lane=2))

# unreplicated-root SPMD emulation (the all-to-all Scatterv path)
_add("bcast", "t2", "f32", 211, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_bcast_unreplicated(m, t, dt, s))
_add("bcast", "het", "f32", 212, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_bcast_unreplicated(m, t, dt, s))
_add("scatter", "t2", "f32", 213, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_scatter_unreplicated(m, t, dt, s))


# divisibility preconditions: a leading dim that violates the mock-up's
# contract must raise ValueError at trace time, not silently misshard
def _expect_value_error(topo_key, fn, rows):
    mesh, topo = _make(topo_key)
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, rows, 2, "f32", 99)
    try:
        _run(mesh, topo, lambda x: fn(x, topo), xs, "f32")
    except ValueError:
        return
    raise AssertionError(f"{fn.__name__} accepted indivisible rows={rows}")


_register("allreduce_indivisible_raises__t2",
          lambda: _expect_value_error("t2", allreduce_lane, 3))     # n=2∤3
_register("alltoall_indivisible_raises__t2",
          lambda: _expect_value_error("t2", alltoall_lane, 12))     # p=8∤12
_register("scatter_indivisible_raises__t2",
          lambda: _expect_value_error("t2", scatter_lane, 12))
_register("reduce_scatter_indivisible_raises__t2",
          lambda: _expect_value_error("t2", reduce_scatter_lane, 12))
_register("bcast_indivisible_raises__t3",
          lambda: _expect_value_error("t3", bcast_lane, 3))         # n=4∤3
_register("scan_indivisible_raises__t3",
          lambda: _expect_value_error("t3", scan_lane, 5))


def main(argv):
    names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
