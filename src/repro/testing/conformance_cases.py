"""Conformance grid for EVERY lane collective (paper §3, Listings 1-6).

All cases drive the collectives through the :class:`repro.comm.LaneComm`
communicator object (strategy="lane"), so the grid conformance-tests the
registry dispatch path end to end — plus dedicated cases pinning that
the DEPRECATED entry points (``optim.gradsync.grad_sync``, direct
``pipelined_allreduce_lane``) stay bit-identical to the LaneComm path.

Where ``collective_cases`` hand-picks representative scenarios, this
module *generates* a dense grid: each of the lane collectives
(bcast/reduce/scan/gather/scatter/alltoall plus allreduce/RS/AG) against
its single-process oracle, across

  * odd topologies — n=1 (every node a single process: the lane level IS
    the communicator), N=1 (single node: the node level is everything),
    heterogeneous node-axis sizes ((data, model) = (4, 1)),
  * non-power-of-two payloads (odd rows per rank — the minimal
    divisibility the mock-ups require, nothing more),
  * bf16 / int32 payloads (integer-valued so reductions are EXACT in
    every dtype — a tolerance would hide dtype-dispatch bugs),
  * non-default roots and the unreplicated-root SPMD emulation paths,
  * the divisibility preconditions (ValueError on bad leading dims).

IMPORT-SAFE like collective_cases: importing never touches XLA flags, so
pytest can enumerate CASES; executing needs 8 host devices — run
``python -m repro.testing.run_conformance_cases`` (fresh process, flag
set before the jax import).
"""
import sys

import numpy as np            # noqa: E402
import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comm import CommConfig, LaneComm  # noqa: E402
from repro.core import LaneTopology  # noqa: E402
from repro.core import ref as _ref  # noqa: E402


# ---------------------------------------------------------------------------
# topology grid (all use the 8 host devices)
# ---------------------------------------------------------------------------

TOPOS = {
    # name: (mesh shape, axis names, node_axes, lane_axis)
    "t2": ((4, 2), ("lane", "node"), ("node",), "lane"),       # n=2, N=4
    "t3": ((2, 2, 2), ("pod", "data", "model"),
           ("data", "model"), "pod"),                          # n=4, N=2
    "het": ((2, 4, 1), ("pod", "data", "model"),
            ("data", "model"), "pod"),                         # (4,1) node
    "n1": ((8, 1), ("lane", "node"), ("node",), "lane"),       # n=1 (k=1)
    "N1": ((1, 8), ("lane", "node"), ("node",), "lane"),       # single node
}

DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "int32": jnp.int32,
}


def _make(topo_key):
    shape, names, node_axes, lane = TOPOS[topo_key]
    mesh = jax.make_mesh(shape, names)
    return mesh, LaneTopology(node_axes=node_axes, lane_axis=lane)


def _payload(p, rows, feat, dtype_key, seed):
    """Stacked per-rank inputs as fp64-exact numpy.

    bf16/int32 use small integers so every reduction below is exact in
    the target dtype (bf16 represents integers up to 256 exactly; the
    deepest sum here is bounded by 8 ranks × |4|, plus prefix depth)."""
    rng = np.random.default_rng(seed)
    if dtype_key == "f32":
        return rng.normal(size=(p, rows, feat)).astype(np.float32)
    return rng.integers(-4, 5, size=(p, rows, feat)).astype(
        np.float32 if dtype_key == "bf16" else np.int32)


def _run(mesh, topo, fn, xs, dtype_key):
    """Scatter per-rank inputs, run the shard_map'd collective in the
    target dtype, gather per-rank outputs back as float/int numpy."""
    p, rows = xs.shape[0], xs.shape[1]
    spec = P((topo.lane_axis, *topo.node_axes))
    flat = jnp.asarray(xs.reshape(p * rows, *xs.shape[2:]),
                       DTYPES[dtype_key])
    arr = jax.device_put(flat, jax.sharding.NamedSharding(mesh, spec))
    shard_fn = jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)
    out = jax.jit(shard_fn)(arr)
    out = np.asarray(out).astype(xs.dtype)
    orows = out.shape[0] // p
    return out.reshape(p, orows, *out.shape[1:])


def _check(got, want, dtype_key):
    if dtype_key == "f32":
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    else:
        np.testing.assert_array_equal(got, want)


def _replicate_root_node(xs, root_lane, n):
    """SPMD rooted-collective convention: the root buffer is replicated
    over the root lane's chips (global ranks root_lane·n .. +n-1)."""
    xs = xs.copy()
    base = root_lane * n
    for i in range(n):
        xs[base + i] = xs[base]
    return xs


# ---------------------------------------------------------------------------
# per-collective builders: (mesh, topo, dtype_key, seed) -> None (asserts)
# ---------------------------------------------------------------------------
# m (rows per divisibility unit) is odd everywhere — the grid's payloads
# are exactly the minimal-divisibility sizes, never "nice" powers of two.
# Every builder goes through LaneComm with the explicit "lane" strategy:
# the registry dispatch is part of what the grid certifies.

def _b_allreduce(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo, lambda x: comm.allreduce(x, strategy="lane"),
               xs, dt)
    _check(out, _ref.oracle_allreduce(xs), dt)


def _b_reduce_scatter(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo, lambda x: comm.reduce_scatter(x, strategy="lane"),
               xs, dt)
    _check(out, _ref.oracle_reduce_scatter(xs), dt)


def _b_allgather(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3, 2, dt, seed)
    out = _run(mesh, topo, lambda x: comm.allgather(x, strategy="lane"),
               xs, dt)
    _check(out, _ref.oracle_allgather(xs), dt)


def _b_bcast(mesh, topo, dt, seed, root_lane=0):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _replicate_root_node(_payload(n * N, 3 * n, 2, dt, seed),
                              root_lane, n)
    out = _run(mesh, topo,
               lambda x: comm.bcast(x, strategy="lane",
                                    root_lane=root_lane), xs, dt)
    _check(out, _ref.oracle_bcast(xs, root=root_lane * n), dt)


def _b_bcast_unreplicated(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: comm.bcast(x, strategy="lane",
                                    root_replicated=False), xs, dt)
    _check(out, _ref.oracle_bcast(xs, root=0), dt)


def _b_reduce(mesh, topo, dt, seed, root_lane=0, root_node=0):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: comm.reduce(x, strategy="lane", root_lane=root_lane,
                                     root_node=root_node), xs, dt)
    _check(out, _ref.oracle_reduce(xs, root=root_lane * n + root_node), dt)


def _b_scan(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3 * n, 2, dt, seed)
    out = _run(mesh, topo, lambda x: comm.scan(x, strategy="lane"), xs, dt)
    _check(out, _ref.oracle_scan(xs), dt)


def _b_gather(mesh, topo, dt, seed, root_lane=0, root_node=0):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(n * N, 3, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: comm.gather(x, strategy="lane", root_lane=root_lane,
                                     root_node=root_node), xs, dt)
    _check(out, _ref.oracle_gather(xs, root=root_lane * n + root_node), dt)


def _b_scatter(mesh, topo, dt, seed, root_lane=0):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    p = n * N
    xs = _replicate_root_node(_payload(p, 3 * p, 2, dt, seed), root_lane, n)
    out = _run(mesh, topo,
               lambda x: comm.scatter(x, strategy="lane",
                                      root_lane=root_lane), xs, dt)
    _check(out, _ref.oracle_scatter(xs, root=root_lane * n), dt)


def _b_scatter_unreplicated(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: comm.scatter(x, strategy="lane",
                                      root_replicated=False),
               xs, dt)
    _check(out, _ref.oracle_scatter(xs, root=0), dt)


def _b_alltoall(mesh, topo, dt, seed):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo, lambda x: comm.alltoall(x, strategy="lane"),
               xs, dt)
    _check(out, _ref.oracle_alltoall(xs), dt)


BUILDERS = {
    "allreduce": _b_allreduce,
    "reduce_scatter": _b_reduce_scatter,
    "allgather": _b_allgather,
    "bcast": _b_bcast,
    "reduce": _b_reduce,
    "scan": _b_scan,
    "gather": _b_gather,
    "scatter": _b_scatter,
    "alltoall": _b_alltoall,
}

# the six collectives the PR-2 conformance mandate names (the other three
# also run, across the odd topologies)
NAMED = ("bcast", "reduce", "scan", "gather", "scatter", "alltoall")


# ---------------------------------------------------------------------------
# grid registration
# ---------------------------------------------------------------------------

CASES = {}


def _register(name, fn):
    assert name not in CASES, name
    CASES[name] = fn


def _add(coll, topo_key, dt, seed, builder=None, suffix=""):
    builder = builder or BUILDERS[coll]

    def run(builder=builder, topo_key=topo_key, dt=dt, seed=seed):
        mesh, topo = _make(topo_key)
        builder(mesh, topo, dt, seed)

    _register(f"{coll}{suffix}__{topo_key}__{dt}", run)


_seed = 100
for _topo_key in TOPOS:
    for _coll in BUILDERS:
        _seed += 1
        _add(_coll, _topo_key, "f32", _seed)

for _dt in ("bf16", "int32"):
    for _coll in NAMED:
        _seed += 1
        _add(_coll, "t3", _dt, _seed)

# non-default roots (masked-root SPMD paths beyond lane 0)
_add("bcast", "t2", "f32", 201, suffix="_rootlane1",
     builder=lambda m, t, dt, s: _b_bcast(m, t, dt, s, root_lane=1))
_add("reduce", "t2", "f32", 202, suffix="_root11",
     builder=lambda m, t, dt, s: _b_reduce(m, t, dt, s, root_lane=1,
                                           root_node=1))
_add("gather", "t3", "f32", 203, suffix="_root12",
     builder=lambda m, t, dt, s: _b_gather(m, t, dt, s, root_lane=1,
                                           root_node=2))
_add("scatter", "t2", "f32", 204, suffix="_rootlane2",
     builder=lambda m, t, dt, s: _b_scatter(m, t, dt, s, root_lane=2))

# unreplicated-root SPMD emulation (the all-to-all Scatterv path)
_add("bcast", "t2", "f32", 211, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_bcast_unreplicated(m, t, dt, s))
_add("bcast", "het", "f32", 212, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_bcast_unreplicated(m, t, dt, s))
_add("scatter", "t2", "f32", 213, suffix="_unreplicated",
     builder=lambda m, t, dt, s: _b_scatter_unreplicated(m, t, dt, s))


# divisibility preconditions: a leading dim that violates the mock-up's
# contract must raise ValueError at trace time, not silently misshard
# (the explicit-strategy dispatch path must NOT swallow them either)
def _expect_value_error(topo_key, coll, rows):
    mesh, topo = _make(topo_key)
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    xs = _payload(n * N, rows, 2, "f32", 99)
    try:
        _run(mesh, topo,
             lambda x: getattr(comm, coll)(x, strategy="lane"), xs, "f32")
    except ValueError:
        return
    raise AssertionError(f"{coll} accepted indivisible rows={rows}")


_register("allreduce_indivisible_raises__t2",
          lambda: _expect_value_error("t2", "allreduce", 3))        # n=2∤3
_register("alltoall_indivisible_raises__t2",
          lambda: _expect_value_error("t2", "alltoall", 12))        # p=8∤12
_register("scatter_indivisible_raises__t2",
          lambda: _expect_value_error("t2", "scatter", 12))
_register("reduce_scatter_indivisible_raises__t2",
          lambda: _expect_value_error("t2", "reduce_scatter", 12))
_register("bcast_indivisible_raises__t3",
          lambda: _expect_value_error("t3", "bcast", 3))            # n=4∤3
_register("scan_indivisible_raises__t3",
          lambda: _expect_value_error("t3", "scan", 5))


# an unknown strategy must fail with the REGISTRY's list (derived, not
# hard-coded), before any tracing happens
def _unknown_strategy_lists_registry():
    from repro.comm import strategies_for
    mesh, topo = _make("t2")
    comm = LaneComm(topo, mesh=mesh)
    xs = _payload(8, 2, 2, "f32", 98)
    try:
        _run(mesh, topo,
             lambda x: comm.allreduce(x, strategy="lane_future"), xs, "f32")
    except ValueError as e:
        msg = str(e)
        assert "lane_future" in msg and "registered strategies" in msg, msg
        for s in strategies_for("allreduce"):
            assert s in msg, (s, msg)
        return
    raise AssertionError("unknown strategy was dispatched")


_register("comm_unknown_strategy_lists_registry__t2",
          _unknown_strategy_lists_registry)


# ---------------------------------------------------------------------------
# third parallelism axis (PR 10): the token-routing alltoall behind
# expert parallelism ("moe_route" — its own registry cells, alltoall
# semantics) and the TP activation collectives, which run through a
# DEGENERATE node_axes=() communicator whose lane axis is the mesh's
# "model" axis (exactly how launch/steps builds tp_comm).
# ---------------------------------------------------------------------------

def _b_moe_route(mesh, topo, dt, seed, strategy="lane"):
    n, N = topo.sizes(mesh)
    comm = LaneComm(topo, mesh=mesh)
    p = n * N
    xs = _payload(p, 3 * p, 2, dt, seed)
    out = _run(mesh, topo,
               lambda x: comm.moe_route(x, strategy=strategy), xs, dt)
    _check(out, _ref.oracle_alltoall(xs), dt)


for _tk in ("t3", "het", "n1", "N1"):
    _seed += 1
    _add("moe_route", _tk, "f32", _seed, builder=_b_moe_route)
for _dt in ("bf16", "int32"):
    _seed += 1
    _add("moe_route", "t3", _dt, _seed, builder=_b_moe_route)
_add("moe_route", "t3", "f32", 231, suffix="_native",
     builder=lambda m, t, dt, s: _b_moe_route(m, t, dt, s,
                                              strategy="native"))
_register("moe_route_indivisible_raises__t2",
          lambda: _expect_value_error("t2", "moe_route", 12))       # p=8∤12


_TP_ORACLES = {
    "allgather": lambda xs: _ref.oracle_allgather(xs),
    "reduce_scatter": lambda xs: _ref.oracle_reduce_scatter(xs),
    "allreduce": lambda xs: _ref.oracle_allreduce(xs),
}


def _b_tp_activation(coll, dt, seed, strategy="lane"):
    """mlp_tp's activation collectives on the tp_comm topology: a single
    'model' lane axis, NO node level (n=1 by construction, not by a
    size-1 axis) — the degenerate decomposition every TP cell rides."""
    def run():
        mesh, _ = _make("t3")
        topo = LaneTopology(node_axes=(), lane_axis="model")
        comm = LaneComm(topo, mesh=mesh)
        n, N = topo.sizes(mesh)
        p = n * N
        xs = _payload(p, 3 * p, 2, dt, seed)
        out = _run(mesh, topo,
                   lambda x: getattr(comm, coll)(x, strategy=strategy),
                   xs, dt)
        _check(out, _TP_ORACLES[coll](xs), dt)
    return run


for _coll in ("allgather", "reduce_scatter", "allreduce"):
    for _dt in ("f32", "bf16"):
        _seed += 1
        _register(f"tp_{_coll}_model_axis__{_dt}",
                  _b_tp_activation(_coll, _dt, _seed))
_register("tp_allgather_model_axis_native__f32",
          _b_tp_activation("allgather", "f32", 232, strategy="native"))


# ---------------------------------------------------------------------------
# deprecation shims: every legacy entry point must stay BIT-identical to
# the LaneComm path (they delegate to the same registered impl; these
# cases pin that the delegation itself doesn't drift)
# ---------------------------------------------------------------------------

def _b_gradsync_shim_bitident(strategy, num_buckets=3, topo_key="t3"):
    import warnings

    def run():
        from repro.optim import grad_sync
        mesh, topo = _make(topo_key)
        # the gradsync topology treats only "data" as the node level
        topo = LaneTopology(node_axes=(topo.node_axes[0],),
                            lane_axis=topo.lane_axis)
        comm = LaneComm(topo, CommConfig(strategy=strategy,
                                         buckets=num_buckets), mesh=mesh)
        n, N = topo.sizes(mesh)
        xs = _payload(n * N, 37, 2, "f32", 97)  # odd rows: padding active

        def both(x):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                legacy = grad_sync(x, topo, strategy,
                                   num_buckets=num_buckets)
            new = comm.grad_sync(x, strategy=strategy,
                                 num_buckets=num_buckets)
            if isinstance(legacy, tuple):      # ZeRO: (shard, spec)
                return legacy[0], new[0]
            return legacy, new

        spec = P((topo.lane_axis, *topo.node_axes))
        flat = jnp.asarray(xs.reshape(-1, 2), jnp.float32)
        arr = jax.device_put(flat, jax.sharding.NamedSharding(mesh, spec))
        sm = jax.shard_map(both, mesh=mesh, in_specs=spec,
                           out_specs=(P(), P()), check_vma=False)
        legacy, new = jax.jit(sm)(arr)
        np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))
    return run


for _strategy in ("native", "lane", "lane_pipelined", "lane_int8",
                  "lane_zero1", "lane_zero3"):
    _register(f"gradsync_shim_bitident_{_strategy}__t3",
              _b_gradsync_shim_bitident(_strategy))


# ---------------------------------------------------------------------------
# family-agnostic zero3 stack conformance: for EVERY registered
# lane-capable family (the grid is DERIVED from the block-stack registry
# — a new registration joins automatically, incl. the vlm/audio families
# the training driver cannot sweep), sharding the stack masters (layer
# blocks AND the embeddings/final-norm extras pseudo-layer) and
# re-gathering through the pipelined prefetch collective reproduces the
# original parameters bit-for-bit — including on the degenerate n=1 /
# N=1 topologies, where one of the two levels is trivial.
# ---------------------------------------------------------------------------

from repro.models.blockstack import family_smoke_archs  # noqa: E402

_ZERO3_FAMILY_ARCHS = family_smoke_archs()


def _b_zero3_stack_roundtrip(family, topo_key):
    from repro.configs import resolve
    from repro.launch.steps import zero3_stack_layouts
    from repro.models import init_model
    from repro.models.blockstack import (block_stack_spec,
                                         resolve_extras_prefetch_blocks,
                                         shard_stack, split_params)
    mesh, topo = _make(topo_key)
    n, N = topo.sizes(mesh)
    cfg = resolve(_ZERO3_FAMILY_ARCHS[family], smoke=True)
    assert block_stack_spec(cfg).family == family
    params = init_model(jax.random.PRNGKey(0), cfg)
    lays = zero3_stack_layouts(cfg)
    fspec = block_stack_spec(cfg)
    stack, extras, _ = split_params(fspec, params)
    comm = LaneComm(topo, mesh=mesh)
    B = 2
    for name, tree, lay, stacked in (("blocks", stack, lays["blocks"], True),
                                     ("extras", extras, lays["extras"],
                                      False)):
        master, got_b = shard_stack(tree, n, N, B, stacked=stacked)
        if stacked:
            assert got_b == B, (name, got_b)
        else:
            # the extras pseudo-layer resolves its OWN depth from its
            # vocab·d stripe — a positive override tuned for the layer
            # stack must not be inherited (PR-8 satellite)
            assert got_b == resolve_extras_prefetch_blocks(
                lay.row_elems, n, N, B), (name, got_b)
        Bg = got_b
        L = master.shape[0]

        def gather_all(m, L=L, Bg=Bg):
            rows = m.reshape(L, -1)

            def one(_, row):
                return None, comm.prefetch_allgather(row, num_blocks=Bg)
            _, full = jax.lax.scan(one, None, rows)
            return full

        spec = P(None, None, (*topo.node_axes, topo.lane_axis), None)
        sm = jax.shard_map(gather_all, mesh=mesh, in_specs=spec,
                           out_specs=P(), check_vma=False)
        full = np.asarray(jax.jit(sm)(np.asarray(master)))
        want = np.asarray(lay.flatten(tree, pad_to=full.shape[1]))
        assert np.array_equal(full, want), \
            (family, topo_key, name, np.abs(full - want).max())


for _fam in _ZERO3_FAMILY_ARCHS:
    for _tk in ("t3", "n1", "N1"):
        _register(f"zero3_stack_roundtrip_{_fam}__{_tk}",
                  lambda fam=_fam, tk=_tk: _b_zero3_stack_roundtrip(fam, tk))


def _pipelined_allreduce_shim_bitident():
    import warnings
    from repro.core.pipeline import pipelined_allreduce_lane
    mesh, topo = _make("t2")
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    B = 3
    xs = _payload(n * N, B * n * 2, 2, "f32", 96)

    def both(x):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            legacy = pipelined_allreduce_lane(x, topo, num_blocks=B)
        new = comm.allreduce(x, strategy="lane_pipelined", num_blocks=B)
        return legacy, new

    spec = P((topo.lane_axis, *topo.node_axes))
    flat = jnp.asarray(xs.reshape(-1, 2), jnp.float32)
    arr = jax.device_put(flat, jax.sharding.NamedSharding(mesh, spec))
    sm = jax.shard_map(both, mesh=mesh, in_specs=spec,
                       out_specs=(P(), P()), check_vma=False)
    legacy, new = jax.jit(sm)(arr)
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(new))


_register("pipelined_allreduce_shim_bitident__t2",
          _pipelined_allreduce_shim_bitident)


# ---------------------------------------------------------------------------
# kv_splice — the serving-side KV distribution collective: a rooted
# bcast of a batch-1 cache leaf + a local splice into the slot-sharded
# buffer.  Data moves but is never combined, so the check is EXACT.
# ---------------------------------------------------------------------------

def _b_kv_splice(strategy, topo_key, slot, seed=97):
    mesh, topo = _make(topo_key)
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    p = n * N
    B_local, L, S = 2, 3, 5
    rng = np.random.default_rng(seed)
    big = rng.normal(size=(L, p * B_local, S)).astype(np.float32)
    # per-rank distinct smalls: only the ROOT's copy may land in the slot
    smalls = rng.normal(size=(p, L, 1, S)).astype(np.float32)
    smalls = _replicate_root_node(smalls, 0, n)   # lane-bcast convention
    want = big.copy()
    want[:, slot] = smalls[0, :, 0]
    bspec = P(None, (topo.lane_axis, *topo.node_axes), None)
    sspec = P((topo.lane_axis, *topo.node_axes), None, None, None)

    def fn(b, s):
        return comm.kv_splice(b, small=s[0], slot=jnp.int32(slot),
                              batch_axis=1, strategy=strategy)

    sm = jax.shard_map(fn, mesh=mesh, in_specs=(bspec, sspec),
                       out_specs=bspec, check_vma=False)
    nb = jax.device_put(jnp.asarray(big),
                        jax.sharding.NamedSharding(mesh, bspec))
    ns = jax.device_put(jnp.asarray(smalls),
                        jax.sharding.NamedSharding(mesh, sspec))
    out = np.asarray(jax.jit(sm)(nb, ns))
    np.testing.assert_array_equal(out, want)


for _strat in ("native", "lane"):
    for _tk in ("t3", "n1", "N1"):
        # first slot, a mid-mesh slot, and the last slot (ownership
        # crosses lane boundaries on every topology)
        for _slot in (0, 9, 15):
            _register(
                f"kv_splice_{_strat}__{_tk}__slot{_slot}",
                lambda st=_strat, tk=_tk, sl=_slot: _b_kv_splice(st, tk, sl))


def _serve_step_resolves_decomposed_cells():
    """The zero3 serving step must resolve the PAPER's decomposed cells:
    weights through ("prefetch_allgather", "lane_pipelined") (blocking
    only as the -1 negative control) and KV distribution through
    ("kv_splice", "lane") — and every named cell must exist in the
    registry."""
    from repro.comm import has_impl
    from repro.configs import resolve
    from repro.serve import build_serve_step
    mesh, topo = _make("t3")
    cfg = resolve("llama3.2-3b", smoke=True)
    step = build_serve_step(cfg, max_seq=64, slots=8,
                            hosting="lane_zero3", mesh=mesh)
    assert step.collectives == {
        "weights": ("prefetch_allgather", "lane_pipelined"),
        "kv": ("kv_splice", "lane")}, step.collectives
    for coll, strat in step.collectives.values():
        assert has_impl(coll, strat), (coll, strat)
    blocking = build_serve_step(cfg, max_seq=64, slots=8,
                                hosting="lane_zero3", mesh=mesh,
                                prefetch_blocks=-1)
    assert blocking.collectives["weights"] == \
        ("prefetch_allgather", "blocking"), blocking.collectives
    replicated = build_serve_step(cfg, max_seq=64, slots=8)
    assert replicated.collectives == {}, replicated.collectives


_register("serve_step_resolves_decomposed_cells__t3",
          _serve_step_resolves_decomposed_cells)


def main(argv):
    names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
