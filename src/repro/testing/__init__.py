"""Test support: multi-device collective cases run in subprocesses.

The main pytest process must see exactly ONE device (per project policy the
host-device-count flag is never set globally), so anything needing a real
multi-device mesh runs through ``python -m repro.testing.collective_cases``
in a child process which sets XLA_FLAGS before importing jax.
"""
