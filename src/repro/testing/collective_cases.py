"""Multi-device validation cases for repro.core.

IMPORT-SAFE: this module never touches XLA flags, so pytest may import it
to enumerate case names.  EXECUTING the cases needs 8 host devices — run
``python -m repro.testing.run_collective_cases`` (which sets the flag in a
fresh process before importing jax/this module).
"""
import sys

import numpy as np            # noqa: E402
import jax                    # noqa: E402
import jax.numpy as jnp       # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.comm import CommConfig, LaneComm  # noqa: E402
from repro.core import (      # noqa: E402
    LaneTopology, allreduce_lane, reduce_scatter_lane, allgather_lane,
    bcast_lane, alltoall_lane, reduce_lane, gather_lane, scatter_lane,
    scan_lane, native_allreduce, native_allgather, native_reduce_scatter,
    native_alltoall, native_scan, pipelined_bcast_lane, ref,
)
from repro.core.pipeline import pipelined_reduce_lane  # noqa: E402
from repro.core import ref as _ref  # noqa: E402


# ---------------------------------------------------------------------------
# harness: build a mesh, scatter per-rank inputs, run a shard_map'd
# collective, gather per-rank outputs, compare to the oracle.
# ---------------------------------------------------------------------------

def _mesh(shape, names):
    return jax.make_mesh(shape, names)


def _run(mesh, topo, fn, xs, out_rows=None):
    """xs: (p, rows, feat) stacked per-global-rank inputs.

    Device order: global rank = lane_rank * n + node_rank, with node_rank
    row-major over topo.node_axes.  We shard the stacked input over
    (lane, *node) so device (j, i) receives xs[j*n+i].
    """
    p, rows = xs.shape[0], xs.shape[1]
    spec = P((topo.lane_axis, *topo.node_axes))
    flat = xs.reshape(p * rows, *xs.shape[2:])
    arr = jax.device_put(flat, jax.sharding.NamedSharding(mesh, spec))
    shard_fn = jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)
    out = jax.jit(shard_fn)(arr)
    out = np.asarray(out)
    orows = out.shape[0] // p
    return out.reshape(p, orows, *out.shape[1:])


def _inputs(p, rows, feat=3, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(p, rows, feat)).astype(dtype)


def _close(a, b, tol=1e-5):
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# cases
# ---------------------------------------------------------------------------
CASES = {}


def case(f):
    CASES[f.__name__] = f
    return f


def _topo3():
    """2 pods × (2 data × 2 model) = 8 devices; node level is 2 axes."""
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    return mesh, LaneTopology(node_axes=("data", "model"), lane_axis="pod")


def _topo2():
    """4 lanes × 2-chip nodes (single node axis)."""
    mesh = _mesh((4, 2), ("lane", "node"))
    return mesh, LaneTopology(node_axes=("node",), lane_axis="lane")


@case
def allreduce_3axis():
    mesh, topo = _topo3()
    n, N = topo.sizes(mesh)
    xs = _inputs(n * N, rows=8)
    out = _run(mesh, topo, lambda x: allreduce_lane(x, topo), xs)
    _close(out, _ref.oracle_allreduce(xs))


@case
def allreduce_2axis():
    mesh, topo = _topo2()
    xs = _inputs(8, rows=6)
    out = _run(mesh, topo, lambda x: allreduce_lane(x, topo), xs)
    _close(out, _ref.oracle_allreduce(xs))


@case
def allreduce_native_matches():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=8)
    out = _run(mesh, topo, lambda x: native_allreduce(x, topo), xs)
    _close(out, _ref.oracle_allreduce(xs))


@case
def reduce_scatter_3axis():
    mesh, topo = _topo3()
    p = 8
    xs = _inputs(p, rows=p * 2)          # m=2 rows per block
    out = _run(mesh, topo, lambda x: reduce_scatter_lane(x, topo), xs)
    _close(out, _ref.oracle_reduce_scatter(xs))


@case
def reduce_scatter_native():
    mesh, topo = _topo3()
    p = 8
    xs = _inputs(p, rows=p * 2)
    out = _run(mesh, topo, lambda x: native_reduce_scatter(x, topo), xs)
    _close(out, _ref.oracle_reduce_scatter(xs))


@case
def allgather_3axis():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=2)
    out = _run(mesh, topo, lambda x: allgather_lane(x, topo), xs)
    _close(out, _ref.oracle_allgather(xs))


@case
def allgather_native():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=2)
    out = _run(mesh, topo, lambda x: native_allgather(x, topo), xs)
    _close(out, _ref.oracle_allgather(xs))


@case
def bcast_3axis():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=8)
    n, N = topo.sizes(mesh)
    # root lane 0: node-replicate the root buffer there (SPMD convention)
    root = xs[0].copy()
    for i in range(n):
        xs[i] = root
    out = _run(mesh, topo, lambda x: bcast_lane(x, topo), xs)
    _close(out, _ref.oracle_bcast(xs, root=0))


@case
def bcast_unreplicated_root():
    mesh, topo = _topo2()
    xs = _inputs(8, rows=4)
    out = _run(mesh, topo,
               lambda x: bcast_lane(x, topo, root_replicated=False), xs)
    _close(out, _ref.oracle_bcast(xs, root=0))


@case
def alltoall_3axis():
    mesh, topo = _topo3()
    p = 8
    xs = _inputs(p, rows=p * 2)
    out = _run(mesh, topo, lambda x: alltoall_lane(x, topo), xs)
    _close(out, _ref.oracle_alltoall(xs))


@case
def alltoall_native():
    mesh, topo = _topo3()
    p = 8
    xs = _inputs(p, rows=p * 2)
    out = _run(mesh, topo, lambda x: native_alltoall(x, topo), xs)
    _close(out, _ref.oracle_alltoall(xs))


@case
def reduce_3axis():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=4)
    out = _run(mesh, topo, lambda x: reduce_lane(x, topo), xs)
    _close(out, _ref.oracle_reduce(xs, root=0))


@case
def gather_2axis():
    mesh, topo = _topo2()
    xs = _inputs(8, rows=2)
    out = _run(mesh, topo, lambda x: gather_lane(x, topo), xs)
    _close(out, _ref.oracle_gather(xs, root=0))


@case
def scatter_2axis():
    mesh, topo = _topo2()
    p = 8
    xs = _inputs(p, rows=p * 2)
    root = xs[0].copy()
    n, N = topo.sizes(mesh)
    for i in range(n):          # replicate root buffer on the root node
        xs[i] = root
    out = _run(mesh, topo, lambda x: scatter_lane(x, topo), xs)
    _close(out, _ref.oracle_scatter(xs, root=0))


@case
def pipelined_bcast():
    mesh, topo = _topo2()
    n, N = topo.sizes(mesh)
    B = 4
    rows = B * n * 3
    xs = _inputs(8, rows=rows)
    root = xs[0].copy()
    for i in range(n):
        xs[i] = root
    out = _run(mesh, topo,
               lambda x: pipelined_bcast_lane(x, topo, num_blocks=B), xs)
    _close(out, _ref.oracle_bcast(xs, root=0))


@case
def pipelined_bcast_3axis():
    mesh, topo = _topo3()
    n, N = topo.sizes(mesh)
    B = 3
    rows = B * n * 2
    xs = _inputs(8, rows=rows)
    root = xs[0].copy()
    for i in range(n):
        xs[i] = root
    out = _run(mesh, topo,
               lambda x: pipelined_bcast_lane(x, topo, num_blocks=B), xs)
    _close(out, _ref.oracle_bcast(xs, root=0))


@case
def pipelined_reduce():
    mesh, topo = _topo2()
    n, N = topo.sizes(mesh)
    B = 4
    rows = B * n * 2
    xs = _inputs(8, rows=rows, seed=11)
    out = _run(mesh, topo,
               lambda x: pipelined_reduce_lane(x, topo, num_blocks=B), xs)
    _close(out, _ref.oracle_reduce(xs, root=0), tol=1e-4)


@case
def pipelined_reduce_3axis():
    mesh, topo = _topo3()
    n, N = topo.sizes(mesh)
    B = 3
    rows = B * n * 2
    xs = _inputs(8, rows=rows, seed=12)
    out = _run(mesh, topo,
               lambda x: pipelined_reduce_lane(x, topo, num_blocks=B), xs)
    _close(out, _ref.oracle_reduce(xs, root=0), tol=1e-4)


@case
def pipelined_allreduce():
    mesh, topo = _topo2()
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    B = 4
    rows = B * n * 3
    xs = _inputs(8, rows=rows, seed=21)
    out = _run(mesh, topo,
               lambda x: comm.allreduce(x, strategy="lane_pipelined",
                                        num_blocks=B), xs)
    _close(out, _ref.oracle_allreduce(xs), tol=1e-4)


@case
def pipelined_allreduce_3axis():
    mesh, topo = _topo3()
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    B = 3
    rows = B * n * 2
    xs = _inputs(8, rows=rows, seed=22)
    out = _run(mesh, topo,
               lambda x: comm.allreduce(x, strategy="lane_pipelined",
                                        num_blocks=B), xs)
    _close(out, _ref.oracle_allreduce(xs), tol=1e-4)


@case
def pipelined_allreduce_single_block():
    """B=1 degenerates to the monolithic Listing-4 chain — must still agree."""
    mesh, topo = _topo2()
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    xs = _inputs(8, rows=n * 2, seed=23)
    out = _run(mesh, topo,
               lambda x: comm.allreduce(x, strategy="lane_pipelined",
                                        num_blocks=1), xs)
    _close(out, _ref.oracle_allreduce(xs), tol=1e-4)


@case
def scan_2axis():
    mesh, topo = _topo2()
    xs = _inputs(8, rows=6, seed=24)
    out = _run(mesh, topo, lambda x: scan_lane(x, topo), xs)
    _close(out, _ref.oracle_scan(xs))


@case
def scan_3axis():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=8, seed=25)
    out = _run(mesh, topo, lambda x: scan_lane(x, topo), xs)
    _close(out, _ref.oracle_scan(xs))


@case
def scan_native_matches():
    mesh, topo = _topo3()
    xs = _inputs(8, rows=8, seed=26)
    out = _run(mesh, topo, lambda x: native_scan(x, topo), xs)
    _close(out, _ref.oracle_scan(xs))


@case
def allreduce_int32():
    mesh, topo = _topo3()
    rng = np.random.default_rng(1)
    xs = rng.integers(-50, 50, size=(8, 8, 3)).astype(np.int32)
    out = _run(mesh, topo, lambda x: allreduce_lane(x, topo), xs)
    np.testing.assert_array_equal(out, _ref.oracle_allreduce(xs))


@case
def allgather_unordered_zero_copy():
    """reorder=False returns a node-major permutation of the rank order."""
    mesh, topo = _topo3()
    n, N = topo.sizes(mesh)
    xs = _inputs(8, rows=2)
    out = _run(mesh, topo,
               lambda x: allgather_lane(x, topo, reorder=False), xs)
    want = _ref.oracle_allgather(xs)     # (p, p*m, f)
    m = 2
    w = want.reshape(8, N, n, m, -1).transpose(0, 2, 1, 3, 4).reshape(want.shape)
    _close(out, w)


def _gradsync_harness(gshapes, seed=3):
    """(mesh, topo, per-leaf inputs, runner) for gradsync strategy cases.

    Returns run(strategy, **kw) → reduced tree as numpy; inputs carry 4
    replicas over (pod, data).  Sync goes through LaneComm (the registry
    dispatch path — what build_train_step_lane actually runs).
    """
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    rng = np.random.default_rng(seed)
    gl = {k: rng.normal(size=(4, *s)).astype(np.float32)
          for k, s in gshapes.items()}

    def run(strategy, **kw):
        def f(g):
            return comm.grad_sync(g, strategy=strategy, **kw)
        # flattened arrays: replica dim folds into dim0 ⇒ len(s) spec entries
        spec = {k: P(("pod", "data"), *([None] * (len(s) - 1)))
                for k, s in gshapes.items()}
        arrs = {k: jax.device_put(
            v.reshape(-1, *v.shape[2:]),
            jax.sharding.NamedSharding(mesh, spec[k])) for k, v in gl.items()}
        sm = jax.shard_map(f, mesh=mesh, in_specs=(spec,),
                           out_specs=jax.tree.map(lambda _: P(), spec),
                           check_vma=False)
        return jax.tree.map(np.asarray, jax.jit(sm)(arrs))

    return mesh, topo, gl, run


@case
def gradsync_lane_matches_native():
    """Paper technique vs one-shot psum on a gradient pytree."""
    gshapes = {"a": (4, 6), "b": (10,), "c": (3, 2, 2)}
    _, _, gl, run = _gradsync_harness(gshapes)
    native = run("native")
    lane = run("lane")
    for k in gl:
        np.testing.assert_allclose(lane[k][:gl[k].shape[1]],
                                   native[k][:gl[k].shape[1]], rtol=1e-5)


@case
def gradsync_bucketed_lane_matches_native():
    """Multi-bucket schedule, payload NOT divisible by K·n (padding edge):
    53 elements into 3 buckets over n=2."""
    gshapes = {"a": (4, 7), "b": (13,), "c": (3, 2, 2)}     # 53 elems
    _, _, gl, run = _gradsync_harness(gshapes, seed=31)
    native = run("native")
    for K in (2, 3, 5):
        out = run("lane", num_buckets=K)
        for k in gl:
            np.testing.assert_allclose(out[k], native[k], rtol=1e-5,
                                       atol=1e-6)


@case
def gradsync_pipelined_matches_native():
    """The §5 scan pipeline as a gradsync strategy, incl. padding edges."""
    gshapes = {"a": (4, 7), "b": (13,), "c": (3, 2, 2)}     # 53 elems
    _, _, gl, run = _gradsync_harness(gshapes, seed=32)
    native = run("native")
    for K in (1, 3, 4):
        out = run("lane_pipelined", num_buckets=K)
        for k in gl:
            np.testing.assert_allclose(out[k], native[k], rtol=1e-5,
                                       atol=1e-6)


@case
def gradsync_bucketed_int8_close():
    """Bucketed compressed DCN hop stays within the quantization bound."""
    gshapes = {"w": (64, 8), "b": (37,)}                    # padding edge
    _, _, gl, run = _gradsync_harness(gshapes, seed=33)
    native = run("native")
    q = run("lane_int8", num_buckets=3)
    for k in gl:
        scale = np.abs(native[k]).max()
        np.testing.assert_allclose(q[k], native[k], atol=scale * 0.02)


@case
def gradsync_pipelined_hlo_overlap():
    """Structural acceptance: in the lowered HLO of the pipelined strategy
    the cross-pod (DCN) collective of a pipeline step has NO data
    dependence on the step's intra-pod (ICI) collectives, while the
    monolithic K=1 lane chain is strictly serial (negative control)."""
    from repro.launch import hlo_stats
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    x = np.random.default_rng(34).normal(size=(1 << 12,)).astype(np.float32)
    arr = jax.device_put(
        x, jax.sharding.NamedSharding(mesh, P(("pod", "data"))))

    def lower(strategy, K):
        sm = jax.shard_map(
            lambda g: comm.grad_sync(g, strategy=strategy, num_buckets=K),
            mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
            check_vma=False)
        hlo = jax.jit(sm).lower(arr).compile().as_text()
        return hlo_stats.collective_concurrency(hlo, pod_size=4)

    assert lower("lane_pipelined", 4)["concurrent"], \
        "pipelined lane/node collectives must be structurally concurrent"
    assert lower("lane", 4)["concurrent"], \
        "bucketed lane/node collectives must be structurally concurrent"
    assert not lower("lane", 1)["concurrent"], \
        "monolithic chain must be serial (checker negative control)"


@case
def gradsync_int8_close():
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    rng = np.random.default_rng(4)
    g = {"w": rng.normal(size=(4, 64, 8)).astype(np.float32)}
    spec = {"w": P(("pod", "data"), None)}
    arrs = {"w": jax.device_put(
        g["w"].reshape(-1, 8),
        jax.sharding.NamedSharding(mesh, spec["w"]))}

    def run(strategy):
        sm = jax.shard_map(lambda x: comm.grad_sync(x, strategy=strategy),
                           mesh=mesh, in_specs=(spec,),
                           out_specs={"w": P()}, check_vma=False)
        return np.asarray(jax.jit(sm)(arrs)["w"])

    native, q = run("native"), run("lane_int8")
    scale = np.abs(native).max()
    np.testing.assert_allclose(q, native, atol=scale * 0.02)


@case
def gradsync_zero1_matches_native():
    """ZeRO-1 path: RS'd flat grads, gathered back, equal the native mean —
    for K=1 (seed behavior) and the bucketed layouts (zero1_unshard does
    the (n,K)→(K,n) reassembly; padding edge at 138 elems)."""
    from repro.optim.gradsync import _unflatten_bucket, zero1_unshard
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    rng = np.random.default_rng(7)
    g = {"w": rng.normal(size=(4, 32, 4)).astype(np.float32),
         "b": rng.normal(size=(4, 10)).astype(np.float32)}
    spec = {"w": P(("pod", "data"), None), "b": P(("pod", "data"))}
    arrs = {k: jax.device_put(v.reshape(-1, *v.shape[2:]),
                              jax.sharding.NamedSharding(mesh, spec[k]))
            for k, v in g.items()}

    for K in (1, 3, 4):
        def f(x, K=K):
            shard, sp = comm.grad_sync(x, strategy="lane_zero1",
                                       num_buckets=K)
            return _unflatten_bucket(zero1_unshard(shard, topo, K), sp)

        sm = jax.shard_map(f, mesh=mesh, in_specs=(spec,),
                           out_specs=jax.tree.map(lambda _: P(), spec),
                           check_vma=False)
        out = jax.tree.map(np.asarray, jax.jit(sm)(arrs))
        for k in g:
            np.testing.assert_allclose(out[k], g[k].mean(axis=0), rtol=1e-5,
                                       atol=1e-6, err_msg=f"K={K} leaf {k}")


@case
def pipelined_allgather():
    """Per-chip 1/p stripes stream through AG(lane)→AG(node); every chip
    ends with the full flat vector (the ZeRO-3 weight-gather hot path)."""
    from repro.optim.gradsync import zero3_param_shard
    mesh, topo = _topo2()
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    p = n * N
    for B in (1, 3):
        flat = np.random.default_rng(41).normal(
            size=(B * p * 2, 3)).astype(np.float32)
        rep = np.broadcast_to(flat, (p, *flat.shape))

        def f(x, B=B):
            sh = zero3_param_shard(x, topo, B)
            return comm.prefetch_allgather(sh, num_blocks=B)

        out = _run(mesh, topo, f, rep)
        _close(out, np.broadcast_to(flat, (p, *flat.shape)))


@case
def pipelined_allgather_3axis():
    from repro.optim.gradsync import zero3_param_shard
    mesh, topo = _topo3()
    comm = LaneComm(topo, mesh=mesh)
    n, N = topo.sizes(mesh)
    p = n * N
    B = 2
    flat = np.random.default_rng(42).normal(
        size=(B * p * 3, 2)).astype(np.float32)
    rep = np.broadcast_to(flat, (p, *flat.shape))

    def f(x):
        sh = zero3_param_shard(x, topo, B)
        return comm.prefetch_allgather(sh, num_blocks=B)

    out = _run(mesh, topo, f, rep)
    _close(out, np.broadcast_to(flat, (p, *flat.shape)))


@case
def gradsync_zero3_matches_native():
    """lane_zero3 = full RS over node AND lane; unsharding the 1/p stripe
    recovers the native mean (padding edge at 138 elems)."""
    from repro.optim.gradsync import _unflatten_bucket, zero3_unshard
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    rng = np.random.default_rng(43)
    g = {"w": rng.normal(size=(4, 32, 4)).astype(np.float32),
         "b": rng.normal(size=(4, 10)).astype(np.float32)}
    spec = {"w": P(("pod", "data"), None), "b": P(("pod", "data"))}
    arrs = {k: jax.device_put(v.reshape(-1, *v.shape[2:]),
                              jax.sharding.NamedSharding(mesh, spec[k]))
            for k, v in g.items()}

    for K in (1, 3):
        def f(x, K=K):
            shard, sp = comm.grad_sync(x, strategy="lane_zero3",
                                       num_buckets=K)
            return _unflatten_bucket(zero3_unshard(shard, topo, K), sp)

        sm = jax.shard_map(f, mesh=mesh, in_specs=(spec,),
                           out_specs=jax.tree.map(lambda _: P(), spec),
                           check_vma=False)
        out = jax.tree.map(np.asarray, jax.jit(sm)(arrs))
        for k in g:
            np.testing.assert_allclose(out[k], g[k].mean(axis=0), rtol=1e-5,
                                       atol=1e-6, err_msg=f"K={K} leaf {k}")


def _zero3_setup(arch="llama3.2-3b"):
    """Shared fixture: smoke model + mesh + batch for the ZeRO-3
    train-step and HLO cases (any registered family's arch)."""
    from repro.configs import resolve
    from repro.models import init_model
    cfg = resolve(arch, smoke=True)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    n, N = topo.sizes(mesh)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    dspec = jax.sharding.NamedSharding(mesh, P(("pod", "data")))
    toks = jax.device_put(
        rng.integers(0, cfg.vocab_size, (8, 8)).astype(np.int32), dspec)
    labs = jax.device_put(
        rng.integers(0, cfg.vocab_size, (8, 8)).astype(np.int32), dspec)
    return cfg, mesh, topo, n, N, params, toks, labs


def _run_lane_state_step(cfg, run, opt, mesh, params, toks, labs, steps=1):
    """Build + run a lane step from its init_lane_train_state masters;
    returns (loss, new_params_host, new_opt_host) as numpy trees."""
    from repro.launch.steps import build_train_step_lane, \
        init_lane_train_state
    step, comm = build_train_step_lane(cfg, run, opt, mesh, None)
    st = init_lane_train_state(cfg, run, mesh, params, comm=comm)
    psh, osh = st.to_shardings(mesh)
    p = jax.tree.map(jax.device_put, st.params, psh)
    o = jax.tree.map(jax.device_put, st.opt_state, osh)
    dspec = P(("pod", "data"))
    sm = jax.shard_map(step, mesh=mesh,
                       in_specs=(st.pspecs, st.ospecs, dspec, dspec, None),
                       out_specs=(P(), st.pspecs, st.ospecs),
                       check_vma=False)
    fn = jax.jit(sm)
    loss, p, o = fn(p, o, toks, labs, None)
    for _ in range(steps - 1):
        loss, p, o = fn(p, o, toks, labs, None)
    return (np.asarray(loss),
            jax.tree.map(np.asarray, p), jax.tree.map(np.asarray, o))


def _unshard_zero3_params(cfg, p3, ep=False):
    """Host (L, B, p, s) masters -> the replicated params tree (blocks
    stacked tree + extras tree + replicated leftovers).  ``ep=True``
    folds the natural-shape expert master back into the moe subtree."""
    from repro.launch.steps import zero3_stack_layouts
    lays = zero3_stack_layouts(cfg, ep=ep)
    out = {k: v for k, v in p3.items()
           if k not in ("blocks", "extras", "experts")}
    blocks = np.asarray(p3["blocks"])
    flat_b = blocks.reshape(lays["blocks"].length,
                            -1)[:, :lays["blocks"].row_elems]
    out["blocks"] = lays["blocks"].unflatten(flat_b)
    extras = np.asarray(p3["extras"])
    flat_e = extras.reshape(1, -1)[:, :lays["extras"].row_elems]
    out.update(lays["extras"].unflatten(flat_e))
    if ep:
        from repro.launch.steps import _abs_params, split_expert_stack
        from repro.models.blockstack import block_stack_spec, split_params
        stack_t, _, _ = split_params(block_stack_spec(cfg),
                                     _abs_params(cfg))
        _, exp_t = split_expert_stack(stack_t)
        moe = dict(out["blocks"].get("moe", {}))
        for k, v in p3["experts"].items():
            moe[k] = np.asarray(v).astype(exp_t[k].dtype)
        out["blocks"] = {**out["blocks"], "moe": moe}
    return out


def _tree_max_err(a, b):
    errs = jax.tree.map(
        lambda x, y: float(jnp.max(jnp.abs(
            jnp.asarray(x, jnp.float32) - jnp.asarray(y, jnp.float32))))
        if np.asarray(x).size else 0.0, a, b)
    return max(jax.tree.leaves(errs), default=0.0)


def _zero3_step_matches_native(arch):
    """End to end, family-agnostic: the lane_zero3 step (sharded layer
    stack AND sharded embeddings/final-norm extras, per-layer pipelined
    prefetch gather, sharded AdamW) reproduces the native replicated
    step's loss and updated parameters for this family's arch."""
    from repro.configs.base import RunConfig, SHAPES
    from repro.optim import AdamWConfig
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup(arch)
    # wd=0 / huge clip: neutral optimizer extras for exact comparison
    # (the clipping + decay alignment has its own dedicated case)
    opt = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    runN = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync="native")
    lossN, pN, _ = _run_lane_state_step(cfg, runN, opt, mesh, params,
                                        toks, labs)
    run3 = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     gradsync="lane_zero3", fsdp_prefetch=2)
    loss3, pn3, _ = _run_lane_state_step(cfg, run3, opt, mesh, params,
                                         toks, labs)
    np.testing.assert_allclose(float(loss3), float(lossN), rtol=1e-6)
    unshard = _unshard_zero3_params(cfg, pn3)
    err = _tree_max_err(pN, unshard)
    assert err < 1e-5, (arch, err)


@case
def zero3_train_step_matches_native():
    _zero3_step_matches_native("llama3.2-3b")


@case
def zero3_train_step_matches_native_ssm():
    _zero3_step_matches_native("mamba2-780m")


@case
def zero3_train_step_matches_native_hybrid():
    _zero3_step_matches_native("zamba2-7b")


@case
def zero3_train_step_matches_native_moe():
    _zero3_step_matches_native("granite-moe-3b-a800m")


def _zero3_sharded_loss_parts(cfg, params, n, N, B, comm):
    """(repl, shards_b, shards_e, make_loss) for lowering the sharded
    loss by hand — make_loss(blocking, regather, grad, remat) returns a
    shard_map-able fn over (repl, blocks_master, extras_master, tok,
    lab)."""
    from repro.launch.steps import zero3_stack_layouts
    from repro.models import loss_fn
    from repro.models.blockstack import (ShardedStack, block_stack_spec,
                                         shard_stack, split_params)
    lays = zero3_stack_layouts(cfg)
    fspec = block_stack_spec(cfg)
    stack, extras, repl = split_params(fspec, params)
    shards_b, _ = shard_stack(stack, n, N, B)
    shards_e, _ = shard_stack(extras, n, N, B, stacked=False)

    def make_loss(blocking=False, regather=False, grad=False,
                  remat="none"):
        def gather_b(x):
            full = comm.prefetch_allgather(
                x, strategy="blocking" if blocking else "lane_pipelined",
                num_blocks=B)
            return lays["blocks"].unflatten_row(full)

        def gather_e(x):
            return lays["extras"].unflatten_row(
                comm.prefetch_allgather(x, num_blocks=B))

        def f(repl_p, shb, she, tok, lab):
            p = dict(repl_p)
            p.update(gather_e(she.reshape(-1)))
            p["blocks"] = ShardedStack(
                shb.reshape(lays["blocks"].length, -1), gather_b,
                prefetch=not blocking, regather=regather)
            return loss_fn(p, cfg, tok, lab, remat=remat)
        if grad:
            return lambda *a: jax.grad(f, argnums=(0, 1, 2))(*a)
        return f

    return repl, shards_b, shards_e, make_loss


def _lower_zero3_loss(cfg, mesh, repl, shards_b, shards_e, toks, labs, fn,
                      grad=False):
    master = P(None, None, ("data", "pod"), None)
    rspec = jax.tree.map(lambda _: P(), repl)
    out_specs = (rspec, master, master) if grad else P()
    sm = jax.shard_map(
        fn, mesh=mesh,
        in_specs=(rspec, master, master, P(("pod", "data")),
                  P(("pod", "data"))),
        out_specs=out_specs, check_vma=False)
    return jax.jit(sm).lower(repl, np.asarray(shards_b),
                             np.asarray(shards_e), toks,
                             labs).compile().as_text()


def _zero3_prefetch_overlap(arch):
    """Structural acceptance (tentpole, per family): on the optimized
    lane_zero3 HLO the prefetch all-gather of layer i+1 and layer i's
    dot FLOPs have NO ancestor relation, while the BLOCKING gather
    chains every SHARDED layer's dots behind its own all-gather
    (negative control).  Families with replicated leftovers (the hybrid
    weight-shared attention block) legitimately keep overlap even when
    blocking — the shared block's dots read only the carry, never the
    gather — so for them the control asserts that blocking kills every
    pair EXCEPT the ones carried by the shared block's conditional."""
    from repro.launch import hlo_stats
    from repro.models.blockstack import block_stack_spec
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup(arch)
    comm = LaneComm(topo, mesh=mesh)
    repl, shb, she, make_loss = _zero3_sharded_loss_parts(
        cfg, params, n, N, 2, comm)

    def conc(blocking):
        hlo = _lower_zero3_loss(cfg, mesh, repl, shb, she, toks, labs,
                                make_loss(blocking=blocking))
        return hlo_stats.collective_compute_concurrency(hlo, pod_size=4)

    pos = conc(blocking=False)
    assert pos["concurrent"], \
        f"{arch}: prefetch AG must be independent of the layer's dots"
    neg = conc(blocking=True)
    if block_stack_spec(cfg).replicated_keys:
        # prefetch overlaps the SHARDED layers' own compute too (pairs
        # beyond the shared-block conditional), blocking only keeps the
        # replicated shared block free
        assert any(p[4] != "conditional" for p in pos["pairs"]), \
            f"{arch}: prefetch must overlap sharded-layer compute"
        assert all(p[4] == "conditional" for p in neg["pairs"]), \
            f"{arch}: blocking gather must serialize the sharded " \
            f"layers' dots (only the replicated shared block may " \
            f"overlap): {neg['pairs'][:3]}"
    else:
        assert not neg["concurrent"], \
            f"{arch}: blocking gather must serialize AG before dots: " \
            f"{neg['pairs'][:3]}"


@case
def zero3_prefetch_hlo_overlap():
    _zero3_prefetch_overlap("llama3.2-3b")


@case
def zero3_prefetch_hlo_overlap_ssm():
    _zero3_prefetch_overlap("mamba2-780m")


@case
def zero3_prefetch_hlo_overlap_hybrid():
    _zero3_prefetch_overlap("zamba2-7b")


@case
def zero3_prefetch_hlo_overlap_moe():
    _zero3_prefetch_overlap("granite-moe-3b-a800m")


@case
def zero3_backward_regather_hlo():
    """Backward re-gather (tentpole memory feature): with regather on,
    the backward re-runs each layer's all-gather — the trip-corrected
    all-gather count of the grad HLO exceeds the forward's by EXACTLY
    the layer stack's forward gather count (the extras pseudo-layer is
    gathered once and not remat'd).  Without regather the backward
    contains no all-gathers at all: grad count == forward count (the
    negative control — the AD transposes are reduce-scatters)."""
    from repro.launch import hlo_stats
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup()
    comm = LaneComm(topo, mesh=mesh)
    repl, shb, she, make_loss = _zero3_sharded_loss_parts(
        cfg, params, n, N, 2, comm)

    def ag_count(**kw):
        grad = kw.pop("grad", False)
        hlo = _lower_zero3_loss(cfg, mesh, repl, shb, she, toks, labs,
                                make_loss(grad=grad, **kw), grad=grad)
        return hlo_stats.collective_kind_counts(
            hlo, pod_size=4).get("all-gather", 0)

    fwd = ag_count()
    # forward = L layer gathers + 1 extras gather; isolate the stack's
    # share by lowering a blocking single-layer-ish control? cheaper:
    # extras gather count = fwd of a model is not separable, so pin the
    # DELTA instead: regather re-runs exactly the L layer gathers
    grad_no = ag_count(grad=True)
    grad_re = ag_count(grad=True, regather=True)
    assert grad_no == fwd, \
        f"no-regather backward must add no all-gathers: {grad_no} vs {fwd}"
    assert grad_re > grad_no, \
        f"regather backward must re-gather: {grad_re} vs {grad_no}"
    # the delta is the layer stack's forward gathers: L layers, each
    # B lane hops + B·|node axes| node hops as lowered — measured as
    # fwd minus the extras gather, i.e. delta = fwd · L/(L+1) exactly
    # when both stacks lower identically; assert the sharp invariant
    # that the delta equals the blocks-only forward count
    lays_L = np.asarray(shb).shape[0]
    per_gather = fwd // (lays_L + 1)        # uniform B ⇒ equal AG cost
    assert grad_re - grad_no == per_gather * lays_L, \
        (grad_re, grad_no, fwd, lays_L)


@case
def hybrid_remat_single_gather_per_layer():
    """Satellite bugfix pin: after the move off the nested group remat,
    the hybrid sharded forward must gather each layer's weights exactly
    once — remat of the per-layer body must NOT recompute the prefetch
    gather (the gather sits outside the remat cell).  Pinned by
    trip-corrected all-gather counts: remat'd forward == plain forward,
    and the remat'd backward (no regather) adds none."""
    from repro.launch import hlo_stats
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup("zamba2-7b")
    comm = LaneComm(topo, mesh=mesh)
    repl, shb, she, make_loss = _zero3_sharded_loss_parts(
        cfg, params, n, N, 2, comm)

    def ag_count(**kw):
        grad = kw.pop("grad", False)
        hlo = _lower_zero3_loss(cfg, mesh, repl, shb, she, toks, labs,
                                make_loss(grad=grad, **kw), grad=grad)
        return hlo_stats.collective_kind_counts(
            hlo, pod_size=4).get("all-gather", 0)

    plain = ag_count(remat="none")
    remat = ag_count(remat="full")
    assert plain == remat, \
        f"group remat must not re-gather: {plain} vs {remat}"
    grad_remat = ag_count(remat="full", grad=True)
    assert grad_remat == remat, \
        f"remat backward recompute must not re-gather: " \
        f"{grad_remat} vs {remat}"


def _microbatch_matches_unaccumulated(gradsync, batch=16):
    """Satellite: the lane step builders' --microbatch accumulation is
    parity-exact (fp32 accum) with the unaccumulated step — loss AND the
    updated parameters."""
    from repro.configs import resolve
    from repro.configs.base import RunConfig, SHAPES
    from repro.optim import AdamWConfig
    cfg = resolve("llama3.2-3b", smoke=True)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    from repro.models import init_model
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig(clip_norm=0.05, weight_decay=0.1)
    rng = np.random.default_rng(11)
    dspec = jax.sharding.NamedSharding(mesh, P(("pod", "data")))
    toks = jax.device_put(
        rng.integers(0, cfg.vocab_size, (batch, 8)).astype(np.int32), dspec)
    labs = jax.device_put(
        rng.integers(0, cfg.vocab_size, (batch, 8)).astype(np.int32), dspec)
    mk = lambda mb: RunConfig(model=cfg, shape=SHAPES["train_4k"],
                              gradsync=gradsync, fsdp_prefetch=2,
                              microbatch=mb)
    loss0, p0, _ = _run_lane_state_step(cfg, mk(0), opt, mesh, params,
                                        toks, labs)
    loss2, p2, _ = _run_lane_state_step(cfg, mk(2), opt, mesh, params,
                                        toks, labs)
    np.testing.assert_allclose(float(loss2), float(loss0), rtol=1e-6)
    err = _tree_max_err(p0, p2)
    assert err < 1e-5, (gradsync, err)
    # bf16 accumulation runs and stays within the coarse compression
    # bound already accepted for the int8 DCN hop
    runb = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     gradsync=gradsync, fsdp_prefetch=2, microbatch=2,
                     accum_dtype="bfloat16")
    lossb, pb, _ = _run_lane_state_step(cfg, runb, opt, mesh, params,
                                        toks, labs)
    np.testing.assert_allclose(float(lossb), float(loss0), rtol=1e-2)


@case
def zero3_microbatch_single_extras_gather():
    """The extras pseudo-layer must gather ONCE per step even under
    microbatch accumulation: the step hoists the extras gather outside
    the µbatch scan via an explicit vjp.  XLA's loop-invariant motion
    may ALSO hoist the (invariant) layer gathers out of the µbatch while
    loop, so the trip-corrected all-gather count of the mb=2 lowering is
    bounded by mb=1 plus at most the blocks-only gathers — a regression
    that re-gathers the extras per µbatch (and is not rescued by LICM)
    lands at ag1 + per_gather·(L+1) and fails the bound."""
    from repro.configs import resolve
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch import hlo_stats
    from repro.launch.steps import (build_train_step_lane,
                                    init_lane_train_state,
                                    zero3_checkpoint_layout)
    from repro.models import init_model
    from repro.optim import AdamWConfig
    cfg = resolve("llama3.2-3b", smoke=True)
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = AdamWConfig()
    rng = np.random.default_rng(11)
    toks = rng.integers(0, cfg.vocab_size, (16, 8)).astype(np.int32)
    labs = rng.integers(0, cfg.vocab_size, (16, 8)).astype(np.int32)
    sizes = {}

    def ag_count(mb):
        run = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                        gradsync="lane_zero3", fsdp_prefetch=2,
                        microbatch=mb)
        step, comm = build_train_step_lane(cfg, run, opt, mesh, None)
        sizes["nN"] = comm.sizes()
        st = init_lane_train_state(cfg, run, mesh, params, comm=comm)
        dspec = P(("pod", "data"))
        sm = jax.shard_map(step, mesh=mesh,
                           in_specs=(st.pspecs, st.ospecs, dspec, dspec,
                                     None),
                           out_specs=(P(), st.pspecs, st.ospecs),
                           check_vma=False)
        hlo = jax.jit(sm).lower(st.params, st.opt_state, toks, labs,
                                None).compile().as_text()
        return hlo_stats.collective_kind_counts(
            hlo, pod_size=4).get("all-gather", 0)

    L = cfg.num_layers
    ag1, ag2 = ag_count(1), ag_count(2)
    # layers keep the forced B; the extras pseudo-layer resolves its OWN
    # depth from the vocab·d stripe (resolve_extras_prefetch_blocks), so
    # read both block counts off the checkpoint-layout geometry and
    # derive the per-BLOCK gather unit from the mb=1 lowering
    n_, N_ = sizes["nN"]
    lay = zero3_checkpoint_layout(cfg, n_, N_, 2)
    Bb, Be = lay.num_blocks, lay.extra_blocks
    g, rem = divmod(ag1, Bb * L + Be)
    assert rem == 0, (ag1, Bb, Be, L)
    assert ag2 <= ag1 + g * Bb * L, \
        f"extras re-gathered under microbatch: ag1={ag1} ag2={ag2} " \
        f"L={L} Bb={Bb} Be={Be}"


@case
def lane_microbatch_matches_unaccumulated():
    _microbatch_matches_unaccumulated("lane_pipelined")


@case
def zero3_microbatch_matches_unaccumulated():
    _microbatch_matches_unaccumulated("lane_zero3")


@case
def gradsync_int8_fused_single_dcn_collective():
    """The int8 strategy's scale exchange rides INSIDE the payload
    all-gather: exactly one DCN collective per bucket on the lowered HLO
    (it was two before the fuse — payload + scales)."""
    from repro.launch import hlo_stats
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    comm = LaneComm(topo, mesh=mesh)
    x = np.random.default_rng(44).normal(size=(1 << 12,)).astype(np.float32)
    arr = jax.device_put(
        x, jax.sharding.NamedSharding(mesh, P(("pod", "data"))))
    K = 3
    sm = jax.shard_map(
        lambda g: comm.grad_sync(g, strategy="lane_int8", num_buckets=K),
        mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
        check_vma=False)
    hlo = jax.jit(sm).lower(arr).compile().as_text()
    res = hlo_stats.collective_concurrency(hlo, pod_size=4)
    dcn = sum(d["dcn"] for d in res["per_computation"].values())
    assert dcn == K, f"expected {K} fused DCN collectives, found {dcn}"


@case
def gradsync_auto_selects_by_cost_model():
    """Tentpole acceptance: strategy="auto" ranks the registered impls
    with the §3/§5 cost model, RECORDS the choice, and the chosen
    program still passes the HLO structural overlap check.  Below the
    pipelining crossover the un-pipelined lane decomposition wins; far
    above it the §5 pipelined strategy wins."""
    from repro.launch import hlo_stats
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")

    def lower(elems):
        comm = LaneComm(topo, CommConfig(strategy="auto"), mesh=mesh)
        x = np.zeros((elems,), np.float32)
        arr = jax.device_put(
            x, jax.sharding.NamedSharding(mesh, P(("pod", "data"))))
        sm = jax.shard_map(lambda g: comm.grad_sync(g, strategy="auto"),
                           mesh=mesh, in_specs=P(("pod", "data")),
                           out_specs=P(), check_vma=False)
        hlo = jax.jit(sm).lower(arr).compile().as_text()
        return comm, hlo

    # small payload: pipelining pure latency backfires — plain lane wins
    comm_s, _ = lower(1 << 12)
    sel_s = comm_s.last_selection
    assert sel_s.collective == "grad_sync" and sel_s.strategy == "lane", \
        sel_s
    # the recorded choice IS the cost-model argmin (ranking is ascending)
    assert sel_s.ranking[0][1] == "lane", sel_s.ranking
    assert {s for _, s in sel_s.ranking} == \
        {"native", "lane", "lane_pipelined"}, sel_s.ranking

    # large payload: the §5 pipelined construction wins, and its HLO
    # keeps the DCN/ICI overlap the k-lane model assumes
    comm_l, hlo = lower(1 << 23)
    sel_l = comm_l.last_selection
    assert sel_l.strategy == "lane_pipelined", sel_l
    # selection is reproducible from the pure ranking API
    again, _ = comm_l.select("grad_sync", sel_l.payload_bytes, n=2, N=2)
    assert again == "lane_pipelined"
    conc = hlo_stats.collective_concurrency(hlo, pod_size=4)
    assert conc["concurrent"], \
        "auto-selected pipelined program must keep the §5 overlap"


def _train_step_pair(gradsync, opt, fsdp_prefetch=0):
    """Build (native step, lane-flavor step) on the shared zero3 fixture."""
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch.steps import build_train_step_lane
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup()
    runN = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync="native")
    runL = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync=gradsync,
                     fsdp_prefetch=fsdp_prefetch)
    stepN, _ = build_train_step_lane(cfg, runN, opt, mesh, None)
    stepL, _ = build_train_step_lane(cfg, runL, opt, mesh, None)
    return cfg, mesh, topo, n, N, params, toks, labs, stepN, stepL


def _run_native_step(mesh, params, toks, labs, stepN, opt, steps=1):
    from repro.optim import adamw_init
    dspec = P(("pod", "data"))
    optsN = adamw_init(params)
    pspec = jax.tree.map(lambda _: P(), params)
    smN = jax.shard_map(stepN, mesh=mesh,
                        in_specs=(pspec, jax.tree.map(lambda _: P(), optsN),
                                  dspec, dspec, None),
                        out_specs=(P(), pspec,
                                   jax.tree.map(lambda _: P(), optsN)),
                        check_vma=False)
    fn = jax.jit(smN)
    loss, p, o = fn(params, optsN, toks, labs, None)
    for _ in range(steps - 1):
        loss, p, o = fn(p, o, toks, labs, None)
    return loss, p, o


@case
def zero1_train_step_matches_native_clipping():
    """Satellite regression (ROADMAP PR-2 follow-up): the ZeRO-1 sharded
    optimizer now reproduces the unsharded adamw_update WITH global-norm
    clipping active and matrices-only weight decay — the true global
    norm is one extra scalar psum over the shard square-norms, and the
    flat decay mask restores the per-element decay rule.  TWO steps: the
    step-1 param delta is nearly clip-scale-invariant (m/√v cancels), so
    only the moments carry the scale into a divergent second step — a
    wrong global norm fails here."""
    from repro.launch.steps import zero1_opt_init
    from repro.optim import AdamWConfig
    # clip_norm far below a real grad norm: clipping is ACTIVE; wd != 0
    opt = AdamWConfig(clip_norm=0.05, weight_decay=0.1)
    cfg, mesh, topo, n, N, params, toks, labs, stepN, step1 = \
        _train_step_pair("lane_zero1", opt)
    lossN, pN, _ = _run_native_step(mesh, params, toks, labs, stepN, opt,
                                    steps=2)

    dspec = P(("pod", "data"))
    sz = zero1_opt_init(params, n)["m"].shape[0]        # per-chip shard
    opts1 = {"m": jnp.zeros((n * sz,), jnp.float32),
             "v": jnp.zeros((n * sz,), jnp.float32),
             "count": jnp.zeros((), jnp.int32)}
    pspec = jax.tree.map(lambda _: P(), params)
    so = {"m": P(("data",)), "v": P(("data",)), "count": P()}
    sm1 = jax.shard_map(step1, mesh=mesh,
                        in_specs=(pspec, so, dspec, dspec, None),
                        out_specs=(P(), pspec, so), check_vma=False)
    fn = jax.jit(sm1)
    loss1, p1, o1 = fn(params, opts1, toks, labs, None)
    loss1, p1, o1 = fn(p1, o1, toks, labs, None)
    np.testing.assert_allclose(float(loss1), float(lossN), rtol=1e-6)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        pN, p1)
    assert max(jax.tree.leaves(errs)) < 1e-5, errs
    # the MOMENTS are the decisive clip check: m scales linearly with the
    # clip factor (params are ~scale-invariant through m/√v), so compare
    # the sharded first moment against the unsharded one, reassembled
    # through the bucket-major (n, K) → (K, n) layout
    from repro.optim.gradsync import _flatten_bucket, resolve_num_buckets
    import math as _math
    _, _, oN = _run_native_step(mesh, params, toks, labs, stepN, opt,
                                steps=2)
    total = sum(_math.prod(p.shape) for p in jax.tree.leaves(params))
    K = resolve_num_buckets(total, n, 0)
    mN, _ = _flatten_bucket(oN["m"], pad_to=K * n)
    s = (n * sz) // (K * n)
    m1 = np.asarray(o1["m"]).reshape(n, K, s).swapaxes(0, 1).reshape(-1)
    np.testing.assert_allclose(m1, np.asarray(mN), atol=2e-6)


@case
def zero3_train_step_matches_native_clipping():
    """Same satellite for ZeRO-3: the sharded stacks (layer blocks AND
    the embeddings/final-norm extras pseudo-layer) clip by the true
    global norm (scalar psum over BOTH levels' stripe norms, threaded
    into adamw_update via grad_norm for any replicated leftovers) and
    decay through the per-element masks — matching semantics of the
    unsharded optimizer.  Two steps, so the clip scale must survive
    through the moments (see the zero1 case for why one step cannot
    pin it)."""
    from repro.configs.base import RunConfig, SHAPES
    from repro.launch.steps import zero3_stack_layouts
    from repro.models.blockstack import block_stack_spec, split_params
    from repro.optim import AdamWConfig
    opt = AdamWConfig(clip_norm=0.05, weight_decay=0.1)
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup()
    runN = RunConfig(model=cfg, shape=SHAPES["train_4k"], gradsync="native")
    run3 = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     gradsync="lane_zero3", fsdp_prefetch=2)
    lossN, pN, oN = _run_lane_state_step(cfg, runN, opt, mesh, params,
                                         toks, labs, steps=2)
    loss3, pn3, on3 = _run_lane_state_step(cfg, run3, opt, mesh, params,
                                           toks, labs, steps=2)
    np.testing.assert_allclose(float(loss3), float(lossN), rtol=1e-6)
    err = _tree_max_err(pN, _unshard_zero3_params(cfg, pn3))
    assert err < 1e-5, err
    # decisive clip check via the first moment (see the zero1 case): the
    # host (L, B, p, s) moment layouts ARE the per-row flat (b, i, j, s)
    # order, so each row compares against the flattened native moments
    lays = zero3_stack_layouts(cfg)
    fspec = block_stack_spec(cfg)
    m_stack, m_extras, _ = split_params(fspec, oN["m"])
    mb = np.asarray(on3["blocks"]["m"])
    np.testing.assert_allclose(
        mb.reshape(mb.shape[0], -1),
        np.asarray(lays["blocks"].flatten(
            m_stack, pad_to=mb.size // mb.shape[0])), atol=2e-6)
    me = np.asarray(on3["extras"]["m"])
    np.testing.assert_allclose(
        me.reshape(1, -1),
        np.asarray(lays["extras"].flatten(m_extras, pad_to=me.size)),
        atol=2e-6)


@case
def zero3_ckpt_canonical_matches_unshard():
    """The checkpoint store's host-side zero3 canonicalization IS
    gradsync.zero3_unshard: laying a canonical vector out as the
    (L, B, p, s) master, scattering the per-chip stripes, and
    reassembling them with the on-device collective recovers the
    canonical element order BIT-exactly (incl. the zero padding)."""
    from repro.checkpoint import Zero3CheckpointLayout
    from repro.optim.gradsync import zero3_unshard
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    D, B, p = 53, 2, 4
    layout = Zero3CheckpointLayout(num_layers=1, layer_elems=D,
                                   num_blocks=B, num_shards=p)
    rng = np.random.default_rng(77)
    canonical = rng.normal(size=(1, D)).astype(np.float32)
    master = jax.tree_util.tree_map_with_path(
        layout.from_canonical, {"blocks": canonical})["blocks"]
    assert master.shape == layout.master_shape == (1, B, p, 56 // (B * p))

    sm = jax.shard_map(
        lambda m: zero3_unshard(m.reshape(-1), topo, B),
        mesh=mesh, in_specs=P(None, None, ("data", "pod"), None),
        out_specs=P(), check_vma=False)
    flat = np.asarray(jax.jit(sm)(master))
    assert np.array_equal(flat, master.reshape(-1))        # bit-exact
    assert np.array_equal(flat[:D], canonical[0])
    # and the store's save-side canonicalization inverts it bit-exactly
    back = jax.tree_util.tree_map_with_path(
        layout.to_canonical, {"blocks": master})["blocks"]
    assert np.array_equal(back, canonical)


@case
def zero1_ckpt_canonical_matches_unshard():
    """Same pin for ZeRO-1: the host (n, K, s) ↔ (K, n, s) transpose of
    the checkpoint layout reproduces gradsync.zero1_unshard bit-exactly
    on the node-sharded flat optimizer state."""
    from repro.checkpoint import Zero1CheckpointLayout
    from repro.optim.gradsync import zero1_unshard
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    topo = LaneTopology(node_axes=("data",), lane_axis="pod")
    total, K, n = 53, 3, 2
    layout = Zero1CheckpointLayout(total, K, n)
    rng = np.random.default_rng(78)
    canonical = rng.normal(size=(total,)).astype(np.float32)
    host = jax.tree_util.tree_map_with_path(
        layout.from_canonical, {"m": canonical})["m"]
    assert host.shape == (layout.padded,)

    sm = jax.shard_map(lambda m: zero1_unshard(m, topo, K),
                       mesh=mesh, in_specs=P(("data",)), out_specs=P(),
                       check_vma=False)
    flat = np.asarray(jax.jit(sm)(host))
    assert np.array_equal(flat[:total], canonical)         # bit-exact
    assert np.all(flat[total:] == 0)
    back = jax.tree_util.tree_map_with_path(
        layout.to_canonical, {"m": host})["m"]
    assert np.array_equal(back, canonical)


@case
def quorum_mean_drops_pod():
    from repro.runtime import quorum_mean
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    rng = np.random.default_rng(5)
    x = rng.normal(size=(8, 4)).astype(np.float32)

    def f(xl):
        pod = jax.lax.axis_index("pod")
        contributing = (pod == 0)       # pod 1 is the "straggler"
        return quorum_mean(xl, "pod", contributing)

    spec = P(("pod", "data", "model"), None)
    arr = jax.device_put(x.reshape(-1, 4)[:, :],
                         jax.sharding.NamedSharding(mesh, spec))
    sm = jax.shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec)
    out = np.asarray(jax.jit(sm)(arr)).reshape(8, 4)
    # pod 0 devices are global ranks 0..3; output = pod-0 value only
    for i in range(4):
        np.testing.assert_allclose(out[i], x[i], rtol=1e-6)
        np.testing.assert_allclose(out[i + 4], x[i], rtol=1e-6)


# ---------------------------------------------------------------------------
# third parallelism axis: tensor-parallel / expert-parallel bit-identity
# ---------------------------------------------------------------------------

def _axis_run(cfg, **kw):
    from repro.configs.base import RunConfig, SHAPES
    return RunConfig(model=cfg, shape=SHAPES["train_4k"], **kw)


@case
def ep_zero3_step_bitwise_matches_gather_moe():
    """Tentpole acceptance: the expert-parallel lane_zero3 MoE step — two
    ``moe_route`` alltoalls of 1/E-expert payload against a never-gathered
    (L, E/p, ...) local expert master — is BIT-identical to the
    gather-based lane_zero3 MoE step: the loss and EVERY updated
    parameter (expert FFN weights included), over two chained steps so
    the optimizer-moment path is covered too."""
    from repro.optim import AdamWConfig
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup("dbrx-132b")
    opt = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    runG = _axis_run(cfg, gradsync="lane_zero3", fsdp_prefetch=2)
    lossG, pG, _ = _run_lane_state_step(cfg, runG, opt, mesh, params,
                                        toks, labs, steps=2)
    runE = _axis_run(cfg, gradsync="lane_zero3", fsdp_prefetch=2,
                     expert_parallel=True)
    lossE, pE, _ = _run_lane_state_step(cfg, runE, opt, mesh, params,
                                        toks, labs, steps=2)
    assert float(lossE) == float(lossG), (float(lossE), float(lossG))
    uG = _unshard_zero3_params(cfg, pG)
    uE = _unshard_zero3_params(cfg, pE, ep=True)
    err = _tree_max_err(uG, uE)
    assert err == 0.0, f"EP zero3 params must be bit-identical: {err}"


@case
def ep_replicated_step_matches_gather_moe():
    """EP through the replicated 'lane' step (every chip slices its own
    expert block out of the replicated tree): matches the gather path to
    tolerance.  The joint-axes grad-sync psum need not associate like the
    gather path's dense expert-grad fold, so this pin is allclose — the
    bitwise EP pin is the zero3 one above, where the fold is pinned."""
    from repro.optim import AdamWConfig
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup("dbrx-132b")
    opt = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    lossG, pG, _ = _run_lane_state_step(
        cfg, _axis_run(cfg, gradsync="lane"), opt, mesh, params, toks, labs)
    lossE, pE, _ = _run_lane_state_step(
        cfg, _axis_run(cfg, gradsync="lane", expert_parallel=True), opt,
        mesh, params, toks, labs)
    np.testing.assert_allclose(float(lossE), float(lossG), rtol=1e-6)
    err = _tree_max_err(pG, pE)
    assert err < 1e-5, err


def _tp_step_matches(gradsync, bitwise, **kw):
    """TP=2 over the mesh's 'model' axis against the TP=1 run of the same
    step flavor.  mlp_tp's custom VJP hands each model rank the
    zero-padded disjoint column block of the replicated gradient, so the
    single assembly psum adds zeros — exact; the lane_zero3 flavor is
    pinned BITWISE on loss and every master.  The replicated flavor pins
    the loss exactly but the params only to tolerance: the TP=2 graph's
    extra allgather/psum ops shift XLA's fusion boundaries in the
    attention backward, reassociating its fp32 dot reductions (~1e-9 —
    compiler scheduling, not TP math; the zero3 pin proves the math)."""
    from repro.optim import AdamWConfig
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup()
    opt = AdamWConfig(weight_decay=0.0, clip_norm=1e9)
    loss1, p1, _ = _run_lane_state_step(
        cfg, _axis_run(cfg, gradsync=gradsync, **kw), opt, mesh, params,
        toks, labs, steps=2)
    loss2, p2, _ = _run_lane_state_step(
        cfg, _axis_run(cfg, gradsync=gradsync, model_parallel=2, **kw),
        opt, mesh, params, toks, labs, steps=2)
    assert float(loss2) == float(loss1), (float(loss2), float(loss1))
    err = _tree_max_err(p1, p2)
    if bitwise:
        assert err == 0.0, f"TP zero3 step must be bit-identical: {err}"
    else:
        assert err < 1e-6, err


@case
def tp_step_matches_replicated():
    _tp_step_matches("lane", bitwise=False)


@case
def tp_zero3_step_bitwise_matches_tp1():
    _tp_step_matches("lane_zero3", bitwise=True, fsdp_prefetch=2)


@case
def ep_routing_alltoall_overlaps_expert_ffn():
    """Structural §5 proof (collective_compute_concurrency over the layer
    scan body): with ``ep_blocks=2`` the dispatch alltoall of capacity
    block j+1 has NO ancestor relation to block j's expert-FFN dots —
    routing communication can hide under expert compute — while the
    sequential ``ep_blocks=1`` lowering chains every alltoall against
    the FFN (negative control)."""
    from repro.launch import hlo_stats
    from repro.models import loss_fn
    from repro.models.parallel import parallel_context
    cfg, mesh, topo, n, N, params, toks, labs = _zero3_setup("dbrx-132b")
    comm = LaneComm(topo, mesh=mesh)
    rspec = jax.tree.map(lambda _: P(), params)

    def lower(blocks):
        def f(p, tok, lab):
            with parallel_context(ep=True, ep_comm=comm,
                                  ep_blocks=blocks):
                return loss_fn(p, cfg, tok, lab)
        sm = jax.shard_map(f, mesh=mesh,
                           in_specs=(rspec, P(("pod", "data")),
                                     P(("pod", "data"))),
                           out_specs=P(), check_vma=False)
        return jax.jit(sm).lower(params, toks,
                                 labs).compile().as_text()

    conc = lambda b: hlo_stats.collective_compute_concurrency(
        lower(b), pod_size=4, coll_kinds=("all-to-all",))
    pos = conc(2)
    assert pos["concurrent"], \
        "pipelined routing alltoall must be independent of expert FFN dots"
    neg = conc(1)
    assert not neg["concurrent"], \
        f"sequential routing must chain alltoall and FFN: {neg['pairs'][:3]}"


def main(argv):
    names = argv or sorted(CASES)
    fails = 0
    for name in names:
        try:
            CASES[name]()
            print(f"PASS {name}")
        except Exception as e:  # noqa: BLE001
            fails += 1
            msg = str(e).splitlines()[0][:200] if str(e) else type(e).__name__
            print(f"FAIL {name}: {msg}")
    return fails


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
