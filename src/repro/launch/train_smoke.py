import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks
# at first backend init) — this module is a standalone CI entry point.
"""CI leg: the training driver must actually RUN every registered
gradsync strategy, with a save → restore round-trip.

For each strategy in the ``train_step`` registry (derived, never
hard-coded — a new registration is automatically covered, a lost one
fails the schema checks instead) this drives
``repro.launch.train --smoke`` twice on the 8-device multi-pod CPU mesh:
a fresh 2-step run that commits a checkpoint, then a resumed 3-step run
that must restore it (the driver prints ``resumed from step 2``; a
restore failure raises).  A strategy the driver cannot serve — missing
layout registration, broken state init, un-restorable checkpoint —
fails the build here rather than surviving as a benchmark-only artifact.

The ``lane_zero3`` strategy additionally sweeps the model FAMILIES
(dense/transformer, ssm, hybrid, moe — the driver-trainable subset of
the block-stack registry): the sharded stack is family-agnostic now,
and a family whose registered BlockSpec cannot actually train + restore
through the driver fails the build here too.

Usage:  python -m repro.launch.train_smoke   (wired into ``make ci``)
"""
import sys                                                    # noqa: E402
import tempfile                                               # noqa: E402

def main(argv=None) -> int:
    from repro.checkpoint import latest_step
    from repro.comm import strategies_for
    from repro.launch.train import main as train_main
    from repro.models.blockstack import family_smoke_archs
    import repro.launch.steps  # noqa: F401 - registers train_step table

    # the zero3 family sweep DERIVES from the block-stack registry (the
    # driver-trainable subset: vlm/audio declare needs_extra_embeds and
    # are covered by the conformance grid instead) — a newly registered
    # family joins the sweep without an edit here
    sweep_archs = family_smoke_archs(driver_trainable_only=True)

    strategies = strategies_for("train_step")
    cells = []
    for s in strategies:
        if s == "lane_zero3":
            cells += [(s, fam, arch) for fam, arch in sweep_archs.items()]
        else:
            cells.append((s, "dense", "llama3.2-3b"))

    fails = []
    for s, fam, arch in cells:
        name = f"{s}[{fam}]" if s == "lane_zero3" else s
        print(f"=== train-smoke {name} ===", flush=True)
        try:
            with tempfile.TemporaryDirectory() as td:
                ck = f"{td}/ck"
                base = ["--arch", arch, "--smoke", "--batch", "8",
                        "--seq", "32", "--ckpt", ck, "--ckpt-every", "2",
                        "--log-every", "1", "--gradsync", s, "--pods", "2"]
                rc = train_main([*base, "--steps", "2"])
                if rc != 0 or latest_step(ck) != 2:
                    raise RuntimeError(
                        f"fresh run failed: rc={rc}, "
                        f"step={latest_step(ck)}")
                rc = train_main([*base, "--steps", "3"])    # restore path
                if rc != 0 or latest_step(ck) != 3:
                    raise RuntimeError(
                        f"restore run failed: rc={rc}, "
                        f"step={latest_step(ck)}")
        except Exception as e:  # noqa: BLE001
            fails.append(name)
            print(f"FAIL {name}: {e!r}", flush=True)
        else:
            print(f"PASS {name}", flush=True)
    print(f"train-smoke: {len(cells) - len(fails)}/{len(cells)} "
          f"cells OK" + (f"; FAILED {fails}" if fails else ""))
    return len(fails)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
