import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                           + os.environ.get("XLA_FLAGS", ""))
# The two lines above MUST run before any jax import (device count locks
# at first backend init) — this module is a standalone CI entry point.
"""CI leg: the training driver must actually RUN every registered
gradsync strategy, with a save → restore round-trip.

For each strategy in the ``train_step`` registry (derived, never
hard-coded — a new registration is automatically covered, a lost one
fails the schema checks instead) this drives
``repro.launch.train --smoke`` twice on the 8-device multi-pod CPU mesh:
a fresh 2-step run that commits a checkpoint, then a resumed 3-step run
that must restore it (the driver prints ``resumed from step 2``; a
restore failure raises).  A strategy the driver cannot serve — missing
layout registration, broken state init, un-restorable checkpoint —
fails the build here rather than surviving as a benchmark-only artifact.

Usage:  python -m repro.launch.train_smoke   (wired into ``make ci``)
"""
import sys                                                    # noqa: E402
import tempfile                                               # noqa: E402


def main(argv=None) -> int:
    from repro.checkpoint import latest_step
    from repro.comm import strategies_for
    from repro.launch.train import main as train_main
    import repro.launch.steps  # noqa: F401 - registers train_step table

    strategies = strategies_for("train_step")
    fails = []
    for s in strategies:
        print(f"=== train-smoke {s} ===", flush=True)
        try:
            with tempfile.TemporaryDirectory() as td:
                ck = f"{td}/ck"
                base = ["--arch", "llama3.2-3b", "--smoke", "--batch", "8",
                        "--seq", "32", "--ckpt", ck, "--ckpt-every", "2",
                        "--log-every", "1", "--gradsync", s, "--pods", "2"]
                rc = train_main([*base, "--steps", "2"])
                assert rc == 0 and latest_step(ck) == 2, \
                    (rc, latest_step(ck))
                rc = train_main([*base, "--steps", "3"])    # restore path
                assert rc == 0 and latest_step(ck) == 3, \
                    (rc, latest_step(ck))
        except Exception as e:  # noqa: BLE001
            fails.append(s)
            print(f"FAIL {s}: {e!r}", flush=True)
        else:
            print(f"PASS {s}", flush=True)
    print(f"train-smoke: {len(strategies) - len(fails)}/{len(strategies)} "
          f"strategies OK" + (f"; FAILED {fails}" if fails else ""))
    return len(fails)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
