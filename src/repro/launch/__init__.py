"""Launch layer: production meshes, sharding rules, step builders, drivers."""
