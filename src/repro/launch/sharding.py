"""Sharding rules: parameter PartitionSpecs, input specs, cache specs.

Conventions (DESIGN.md §3):
  batch axes   ("pod","data")  — token batch, serve batch
  "model"      — tensor parallelism: attention-head / d_ff / vocab columns,
                 SSM heads, expert d_ff; KV-cache *sequence* dim for decode
  fsdp         — when enabled, the non-TP weight dim additionally shards
                 over "data" (ZeRO-3-style; the per-layer all-gathers are
                 inserted by GSPMD inside the layer scan)

KV projections replicate over "model" when num_kv_heads doesn't divide the
TP degree (GQA kv < tp) — the standard Megatron-GQA fallback.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs.base import ModelConfig
from .mesh import batch_axes, mesh_sizes


def _path_str(path) -> str:
    out = []
    for k in path:
        out.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(out)


def _base_spec(ps: str, cfg: ModelConfig, tp: int, fsdp):
    """Final-dims partition spec for one parameter, by path suffix."""
    kv_shardable = cfg.num_kv_heads and cfg.num_kv_heads % tp == 0
    if ps.endswith("embed/tok"):
        return ("model", None)
    if ps.endswith("embed/head"):
        return (fsdp, "model")
    if ps.endswith("attn/wq") or ps.endswith("xattn/wq"):
        return (fsdp, "model")
    if ps.endswith("/wk") or ps.endswith("/wv"):
        return (fsdp, "model") if kv_shardable else (fsdp, None)
    if ps.endswith("/bq"):
        return ("model",)
    if ps.endswith("/bk") or ps.endswith("/bv"):
        return ("model",) if kv_shardable else (None,)
    if ps.endswith("/wo"):
        return ("model", fsdp)
    if ps.endswith("mlp/w_up") or ps.endswith("mlp/w_gate"):
        return (fsdp, "model")
    if ps.endswith("mlp/w_down"):
        return ("model", fsdp)
    if ps.endswith("moe/router"):
        return (fsdp, None)
    if ps.endswith("moe/w_up") or ps.endswith("moe/w_gate"):
        return (None, fsdp, "model")
    if ps.endswith("moe/w_down"):
        return (None, "model", fsdp)
    if ps.endswith("mamba/w_z") or ps.endswith("mamba/w_x"):
        return (fsdp, "model")
    if ps.endswith("mamba/w_dt"):
        return (fsdp, "model")
    if ps.endswith("mamba/w_B") or ps.endswith("mamba/w_C"):
        return (fsdp, None)
    if ps.endswith("conv_x_w"):
        return (None, "model")
    if ps.endswith("conv_x_b"):
        return ("model",)
    if "conv_B" in ps or "conv_C" in ps:
        return None                      # replicated, any rank
    if ps.endswith("A_log") or ps.endswith("/D") or ps.endswith("dt_bias"):
        return ("model",)
    if ps.endswith("mamba/norm/scale"):
        return ("model",)
    if ps.endswith("mamba/out_proj"):
        return ("model", fsdp)
    if ps.endswith("vis_proj") or ps.endswith("encoder/pos"):
        return None
    # norms, biases, anything else: replicated
    return None


def param_pspecs(params_shapes: Any, cfg: ModelConfig, mesh, *,
                 fsdp: bool, tp: bool = True) -> Any:
    """PartitionSpec tree matching the (eval_shape'd) params tree.

    tp=False is the TP-free plan (small models on big meshes): the "model"
    axis joins the batch/FSDP product instead of column-sharding weights —
    same 16×16 mesh, zero tensor-parallel collectives.
    """
    tp_deg = mesh_sizes(mesh).get("model", 1) if tp else 1
    if tp:
        fs = "data" if (fsdp and "data" in mesh.axis_names) else None
    else:
        fs = (("data", "model") if fsdp else None)

    def sub(e):
        if e == "model":
            return "model" if tp else None
        return e

    def rule(path, leaf):
        ps = _path_str(path)
        base = _base_spec(ps, cfg, tp_deg, fs)
        nd = len(leaf.shape)
        if base is None:
            return P()
        base = [sub(e) for e in base]
        lead = nd - len(base)
        if lead < 0:
            raise RuntimeError(
                f"spec {ps} has more sharded dims than leaf shape "
                f"{leaf.shape} (base {base})")
        return P(*([None] * lead + list(base)))

    specs = jax.tree_util.tree_map_with_path(rule, params_shapes)
    return sanitize_specs(params_shapes, specs, mesh)


def opt_pspecs(param_specs: Any) -> Any:
    """AdamW moments mirror params; count is replicated."""
    return {"m": param_specs, "v": param_specs,
            "count": P()}


def cache_pspecs(cache_shapes: Any, cfg: ModelConfig, mesh, *,
                 seq_shard: bool = True) -> Any:
    """Serve-cache specs.  KV caches (L,B,S,K,hd): S shards over "model"
    (decode reads it with the distributed-LSE pattern); Mamba states shard
    their head/channel dims over "model"."""
    ba = batch_axes(mesh)

    def rule(path, leaf):
        ps = _path_str(path)
        nd = len(leaf.shape)
        if ps in ("k", "v") or ps.endswith("/k") or ps.endswith("/v"):
            # (L?, B, S, K, hd) — enc_kv has no layer lead handled by nd
            spec = [None] * nd
            spec[nd - 4] = ba            # batch dim
            if seq_shard:
                spec[nd - 3] = "model"
            return P(*spec)
        if "conv" in ps:                 # (L,B,W-1,C): C over model for x
            spec = [None] * nd
            spec[1] = ba
            if ps.endswith("conv_x"):
                spec[-1] = "model"
            return P(*spec)
        if ps.endswith("ssm"):           # (L,B,H,P,S): heads over model
            spec = [None] * nd
            spec[1] = ba
            spec[2] = "model"
            return P(*spec)
        if ps.endswith("length"):
            return P(ba)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)


def sanitize_specs(shapes_tree: Any, spec_tree: Any, mesh) -> Any:
    """Drop spec entries whose mesh-axis product doesn't evenly divide the
    dimension (in/out shardings must divide; e.g. whisper's vocab 51866 on a
    16-way axis).  The dropped dim becomes replicated."""
    sizes = mesh_sizes(mesh)

    def fix(leaf, spec):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        out = []
        for dim, e in zip(leaf.shape, entries):
            if e is None:
                out.append(None)
                continue
            axes = (e,) if isinstance(e, str) else tuple(e)
            n = 1
            for a in axes:
                n *= sizes[a]
            out.append(e if dim % n == 0 else None)
        return P(*out)

    return jax.tree.map(fix, shapes_tree, spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def to_shardings(spec_tree: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_spec(mesh) -> P:
    return P(batch_axes(mesh))


def token_spec(mesh) -> P:
    return P(batch_axes(mesh), None)


def embed_spec(mesh) -> P:
    return P(batch_axes(mesh), None, None)


def sds(tree, spec_tree, mesh):
    """ShapeDtypeStructs with shardings attached, for .lower()."""
    shardings = to_shardings(spec_tree, mesh)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)
