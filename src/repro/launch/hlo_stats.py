"""Exact-ish HLO accounting: dot FLOPs, HBM-traffic bytes, collective bytes,
with while-loop bodies multiplied by their known trip counts.

Why: `compiled.cost_analysis()` counts every while body exactly once (we
verified empirically — a 10-iteration scan reports 1 iteration of FLOPs),
which would understate a scanned-80-layer model by ~80×.  XLA:CPU annotates
optimized while ops with ``backend_config={"known_trip_count":{"n":...}}``,
so we reconstruct the executed totals by walking the call graph:

  flops(comp)  = Σ own dot/conv flops + Σ_child mult(child) · flops(child)
  mult = trip count for while bodies, 1 for fusions/calls/branches

Bytes model (HBM traffic): every *top-level* instruction in a computation
reads its operands and writes its result once (fusion internals are NOT
descended for bytes — a fusion is one read-operands/write-result op, which
is exactly what makes it a fusion); loop bodies multiply.  This is a
first-order traffic model: it ignores cache reuse inside a fused region
(none to ignore) and register/VMEM blocking of single dots.

Collectives: each op's wire bytes under ring algorithms, split ICI vs DCN
by replica-group membership (groups spanning multiple 256-chip pods are
DCN).  Collective ops also multiply through loop trip counts.
"""
from __future__ import annotations

import json
import math
import re
from typing import Optional

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# type may be a tuple containing /*index=N*/ comments (hence '=') — match
# lazily up to the first ')' that is followed by the op name.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(.*?\)|\S+?)\s+([\w\-]+)\(")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLED_RE = re.compile(
    r"(?:calls=|condition=|body=|to_apply=)%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(x) for x in dims.split(",") if x]))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(d) if d else _DTYPE_BYTES[dt]
               for dt, d in _dims(type_str))


def _elems_of(type_str: str) -> int:
    return sum(math.prod(d) if d else 1 for dt, d in _dims(type_str))


class Instr:
    __slots__ = ("name", "type_str", "op", "line")

    def __init__(self, name, type_str, op, line):
        self.name, self.type_str, self.op, self.line = name, type_str, op, line


class Computation:
    def __init__(self, name):
        self.name = name
        self.instrs: list[Instr] = []
        self.table: dict[str, str] = {}     # instr name -> type str


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_START_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, type_str, op = m.group(1), m.group(2), m.group(3)
            cur.instrs.append(Instr(name, type_str, op, line))
            cur.table[name] = type_str
    comps["__entry__"] = comps.get(entry) if entry else None
    return comps


def _operand_names(inst: Instr) -> list[str]:
    """Raw operand names of one HLO instruction, in order.

    Handles both operand dialects: bare ``op(%a, %b)`` and the typed
    ``op(f32[8]{0} %a, f32[8]{0} %b)`` form compiled dumps use.  Only the
    operand parenthesis group is scanned (balanced — tuple types nest), so
    attribute refs like ``to_apply=%add`` are never picked up.
    """
    line = inst.line
    try:
        start = line.index(inst.op + "(") + len(inst.op)
    except ValueError:
        return []
    seg = line[start:]
    depth = 0
    for k, ch in enumerate(line[start:], start):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                seg = line[start:k + 1]
                break
    names = re.findall(r"%([\w.\-]+)", seg)
    if not names:
        # bare dialect: comma-split, strip types, keep name-ish tokens
        names = [t.split()[-1] for t in seg.strip("()").split(",")
                 if t.strip()]
    return names


def _dot_flops(inst: Instr, table: dict[str, str]) -> float:
    out_elems = _elems_of(inst.type_str)
    mc = _CONTRACT_RE.search(inst.line)
    k = 1
    if mc:
        cdims = [int(x) for x in mc.group(1).split(",") if x]
        names = _operand_names(inst)
        lhs_t = table.get(names[0]) if names else None
        if lhs_t:
            d = _dims(lhs_t)
            if d:
                shape = d[0][1]
                for c in cdims:
                    if c < len(shape):
                        k *= shape[c]
    return 2.0 * out_elems * k


def _conv_flops(inst: Instr, table: dict[str, str]) -> float:
    # flops ≈ 2 · out_elems · (kernel spatial · in_channels); approximate
    # via rhs (kernel) element count / out_channels
    out_elems = _elems_of(inst.type_str)
    names = _operand_names(inst)
    k = 1
    if len(names) >= 2 and names[1] in table:
        d = _dims(table[names[1]])
        if d:
            k = max(1, math.prod(d[0][1]))
    return 2.0 * out_elems * k


def _operand_bytes(inst: Instr, table: dict[str, str]) -> int:
    return sum(_bytes_of(table[nm]) for nm in _operand_names(inst)
               if nm in table)


def group_info(line: str, pod_size: int):
    """(group_size, crosses_pod) from replica_groups, exact for both the
    explicit {{...}} and the iota [G,S]<=[dims]T(perm) forms."""
    m = _GROUPS_RE.search(line)
    if m:
        ids = [int(x) for x in m.group(1).split(",")]
        return len(ids), len({i // pod_size for i in ids}) > 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as _np
        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = _np.arange(math.prod(dims)).reshape(dims)
        if m.group(4):
            ids = ids.transpose([int(x) for x in m.group(4).split(",")])
        rows = ids.reshape(g, s) // pod_size
        return s, bool((rows.max(axis=1) != rows.min(axis=1)).any())
    return 2, False


def _collective(inst: Instr, pod_size: int):
    kind = inst.op.replace("-start", "")
    if kind not in _COLL_KINDS:
        return None
    b = _bytes_of(inst.type_str)
    g, dcn = group_info(inst.line, pod_size)
    if kind == "collective-permute":
        # source-target pairs, not groups: DCN iff ANY pair crosses pods
        # (the braces nest — match the whole {{a,b},{c,d},...} list, not
        # just up to the first '}')
        mp = re.search(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}",
                       inst.line)
        if mp:
            pairs = re.findall(r"\{(\d+),(\d+)\}", mp.group(1))
            dcn = any(int(a) // pod_size != int(b2) // pod_size
                      for a, b2 in pairs)
    if kind == "all-reduce":
        wire = 2 * (g - 1) / g * b
    elif kind in ("all-gather", "all-to-all", "reduce-scatter"):
        wire = (g - 1) / g * b
    else:
        wire = float(b)
    return {"kind": kind, "bytes": float(b), "wire": wire, "group": g,
            "dcn": dcn}


_SKIP_BYTES_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "while", "conditional", "call",
                   "after-all", "add-dependency"}

# ops whose HBM traffic is a function of the RESULT (or update) size, not
# the full operand buffers: a dynamic-slice of an (L, d, f) stacked weight
# reads one layer's slice, not the whole stack — counting operands would
# overcount loop-heavy models by ~L×.
_RESULT_BYTES_OPS = {
    "dynamic-slice": 2,      # read slice + write result
    "slice": 2,
    "gather": 2,
    "reshape": 2,
    "copy": 2,
    "transpose": 2,
    "convert": 2,
    "broadcast": 1,          # reads a much smaller operand
    "iota": 1,
    "reverse": 2,
    "pad": 2,
    "concatenate": 2,
}


def _instr_bytes(inst: "Instr", table: dict[str, str]) -> float:
    if inst.op in _RESULT_BYTES_OPS:
        return _RESULT_BYTES_OPS[inst.op] * _bytes_of(inst.type_str)
    if inst.op == "dynamic-update-slice":
        # aliased in place: read+write the update operand only
        names = _operand_names(inst)
        if len(names) >= 2 and names[1] in table:
            return 2.0 * _bytes_of(table[names[1]])
        return 2.0 * _bytes_of(inst.type_str)
    return _bytes_of(inst.type_str) + _operand_bytes(inst, table)


def analyze(text: str, *, pod_size: int = 256) -> dict:
    """Trip-corrected totals + per-loop-depth byte attribution.

    ``bytes_depth`` maps while-nesting depth → HBM bytes.  Depth ≥ 3 in a
    train step (µbatch × layer × attention-block scans) is the traffic a
    fused Pallas kernel keeps in VMEM — the §Perf memory-term lever.
    """
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    memo: dict[str, dict] = {}

    def walk(comp: Computation, depth: int = 0) -> dict:
        if (comp.name, depth) in memo:
            return memo[(comp.name, depth)]
        res = {"flops": 0.0, "bytes": 0.0, "bytes_depth": {},
               "coll": {}, "coll_wire": 0.0, "dcn_wire": 0.0,
               "ici_wire": 0.0, "coll_count": 0}
        memo[(comp.name, depth)] = res  # cycle guard (HLO is acyclic)
        def add_depth(d, b):
            res["bytes_depth"][d] = res["bytes_depth"].get(d, 0.0) + b

        for inst in comp.instrs:
            if inst.op == "dot":
                res["flops"] += _dot_flops(inst, comp.table)
            elif inst.op == "convolution":
                res["flops"] += _conv_flops(inst, comp.table)
            c = _collective(inst, pod_size)
            if c:
                k = c["kind"]
                rec = res["coll"].setdefault(k, {"count": 0, "bytes": 0.0,
                                                 "wire_bytes": 0.0})
                rec["count"] += 1
                rec["bytes"] += c["bytes"]
                rec["wire_bytes"] += c["wire"]
                res["coll_wire"] += c["wire"]
                res["coll_count"] += 1
                if c["dcn"]:
                    res["dcn_wire"] += c["wire"]
                else:
                    res["ici_wire"] += c["wire"]
            if inst.op not in _SKIP_BYTES_OPS:
                b = _instr_bytes(inst, comp.table)
                res["bytes"] += b
                add_depth(depth, b)
            # recurse
            mult = 1
            depth_child = depth
            children = []
            if inst.op == "while":
                mt = _TRIP_RE.search(inst.line)
                mult = int(mt.group(1)) if mt else 1
                depth_child = depth + 1
                mb = re.search(r"body=%?([\w.\-]+)", inst.line)
                if mb:
                    children = [mb.group(1)]
            elif inst.op in ("fusion", "call", "map", "reduce",
                             "reduce-window", "sort", "scatter",
                             "select-and-scatter", "all-reduce"):
                children = _CALLED_RE.findall(inst.line)
            elif inst.op == "conditional":
                mb = _BRANCHES_RE.search(inst.line)
                if mb:
                    children = [c.strip().lstrip("%")
                                for c in mb.group(1).split(",")]
            for ch in children:
                if ch in comps:
                    sub = walk(comps[ch], depth_child)
                    if inst.op == "fusion":
                        # fusion: count internal dot flops (they execute)
                        res["flops"] += mult * sub["flops"]
                        # bytes already counted at the call site
                    else:
                        res["flops"] += mult * sub["flops"]
                        res["bytes"] += mult * sub["bytes"]
                        for d, b in sub["bytes_depth"].items():
                            add_depth(d, mult * b)
                    for k, rec in sub["coll"].items():
                        dst = res["coll"].setdefault(
                            k, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
                        dst["count"] += mult * rec["count"]
                        dst["bytes"] += mult * rec["bytes"]
                        dst["wire_bytes"] += mult * rec["wire_bytes"]
                    res["coll_wire"] += mult * sub["coll_wire"]
                    res["dcn_wire"] += mult * sub["dcn_wire"]
                    res["ici_wire"] += mult * sub["ici_wire"]
                    res["coll_count"] += mult * sub["coll_count"]
        return res

    if entry is None:
        raise ValueError("no ENTRY computation found")
    out = dict(walk(entry))
    out["computations"] = len(comps)
    return out


def collective_kind_counts(text: str, *, pod_size: int = 256) -> dict:
    """Trip-corrected executed-op counts per collective kind for the
    whole module (``{"all-gather": 12, ...}``; absent kinds are 0 via
    ``.get``).  The backward re-gather and hybrid single-gather-per-layer
    pins compare these counts across lowerings: a remat cell that
    accidentally recomputes a weight gather, or a backward that is
    SUPPOSED to re-gather, both show up as an all-gather count delta."""
    res = analyze(text, pod_size=pod_size)
    return {k: int(v["count"]) for k, v in res["coll"].items()}


# ---------------------------------------------------------------------------
# structural concurrency: can the lane (DCN) hop and a node (ICI)
# collective of one pipeline step run at the same time?
# ---------------------------------------------------------------------------

def _instr_operands(inst: Instr, table: dict[str, str]) -> list[str]:
    """Operand instruction names resolvable in the same computation."""
    return [nm for nm in _operand_names(inst) if nm in table]


def _ancestor_fn(comp: Computation):
    """Memoized transitive-ancestor query over one computation's def-use
    graph.  Edges follow every operand reference, so dependence chains
    routed through tuple / get-tuple-element / bitcast plumbing are
    ancestors too (they are ordinary instructions with operands)."""
    ops_of = {i.name: _instr_operands(i, comp.table) for i in comp.instrs}
    anc_memo: dict[str, frozenset] = {}

    def ancestors(name: str) -> frozenset:
        if name in anc_memo:
            return anc_memo[name]
        out: set[str] = set()
        stack = list(ops_of.get(name, ()))
        while stack:                           # iterative: HLO chains
            cur = stack.pop()                  # can exceed Py recursion
            if cur in out:
                continue
            out.add(cur)
            if cur in anc_memo:
                out |= anc_memo[cur]
            else:
                stack.extend(ops_of.get(cur, ()))
        anc_memo[name] = frozenset(out)
        return anc_memo[name]

    return ancestors


def _independent(ancestors, a: str, b: str) -> bool:
    """True iff neither instruction is a def-use ancestor of the other."""
    return a not in ancestors(b) and b not in ancestors(a)


def collective_concurrency(text: str, *, pod_size: int = 256) -> dict:
    """Verify, per computation, that a cross-pod (DCN) collective and an
    intra-pod (ICI) collective exist with NO data dependence in either
    direction — the structural precondition for the §5 pipeline's overlap
    (XLA's scheduler cannot be forced, but absent a dependence edge it is
    free to run both at once; present one, it never can).

    Returns {"concurrent": bool, "pairs": [...], "per_computation": {...}}
    where each pair is (computation, dcn_instr, dcn_kind, ici_instr,
    ici_kind).  A scan-based pipeline puts both ops in the while-body
    computation; an unrolled bucket schedule puts them straight in the
    entry — both are covered because every computation is examined.
    """
    comps = parse_hlo(text)
    comps.pop("__entry__", None)
    pairs = []
    per_comp: dict[str, dict] = {}
    for cname, comp in comps.items():
        if comp is None:
            continue
        colls = []
        for inst in comp.instrs:
            c = _collective(inst, pod_size)
            if c:
                colls.append((inst, c))
        if not colls:
            continue
        dcn = [(i, c) for i, c in colls if c["dcn"]]
        ici = [(i, c) for i, c in colls if not c["dcn"]]
        per_comp[cname] = {"dcn": len(dcn), "ici": len(ici), "pairs": 0}
        if not dcn or not ici:
            continue
        ancestors = _ancestor_fn(comp)
        for di, dc in dcn:
            for ni, nc in ici:
                if _independent(ancestors, di.name, ni.name):
                    pairs.append((cname, di.name, dc["kind"],
                                  ni.name, nc["kind"]))
                    per_comp[cname]["pairs"] += 1
    return {"concurrent": bool(pairs), "pairs": pairs,
            "per_computation": per_comp}


# ---------------------------------------------------------------------------
# structural concurrency, collective vs COMPUTE: can the ZeRO-3 prefetch
# all-gather of layer i+1 run under layer i's dot FLOPs?
# ---------------------------------------------------------------------------

def _called_comps(line: str) -> list[str]:
    """Every computation a line references: calls=/condition=/body=/
    to_apply= AND conditional branch_computations={...}."""
    out = _CALLED_RE.findall(line)
    mb = _BRANCHES_RE.search(line)
    if mb:
        out += [c.strip().lstrip("%") for c in mb.group(1).split(",")]
    return out


def _carrier_comps(comps: dict, direct) -> set:
    """Names of computations that transitively contain an instruction for
    which ``direct(inst)`` is true — through while bodies, fusions, calls
    and conditional branches alike."""
    memo: dict[str, bool] = {}

    def has(name: str) -> bool:
        if name in memo:
            return memo[name]
        memo[name] = False                     # cycle guard (HLO is acyclic)
        comp = comps.get(name)
        if comp is None:
            return False
        for inst in comp.instrs:
            if direct(inst) or any(has(ch)
                                   for ch in _called_comps(inst.line)):
                memo[name] = True
                break
        return memo[name]

    return {n for n in comps if n != "__entry__" and has(n)}


_CALLER_OPS = ("while", "fusion", "call", "conditional", "map")


def collective_compute_concurrency(text: str, *, pod_size: int = 256,
                                   coll_kinds=None) -> dict:
    """Verify, per computation, that a collective and a FLOP-carrying
    instruction coexist with NO data dependence in either direction — the
    structural precondition for hiding a ZeRO-3 weight-prefetch
    all-gather under a layer's matmuls (multi-core cluster model: overlap
    must be provable on the graph, not inferred from CPU wall-clock,
    which cannot show the win on shared-memory host devices).

    An instruction "carries" a collective/FLOPs either directly (an
    all-gather / a dot) or by calling into a computation that transitively
    contains one (a fusion of dots; the inner while loop of the pipelined
    per-layer gather).  That nesting matters: the layer scan's body holds
    the prefetch gather as a ``while`` instruction (the AG pipeline) next
    to the current layer's dot fusions — def-use-independent, so XLA may
    overlap them.  A BLOCKING gather chains every dot behind its own
    all-gather, so no independent pair survives — the negative control.

    ``coll_kinds`` restricts which collective kinds count (default: the
    gather-shaped kind the prefetch path is built from).

    Returns {"concurrent": bool, "pairs": [...], "per_computation": {...}}
    with pairs (computation, coll_instr, coll_kind_or_op, compute_instr,
    compute_op).
    """
    if coll_kinds is None:
        coll_kinds = ("all-gather",)
    comps = parse_hlo(text)
    comps.pop("__entry__", None)

    def direct_coll(inst):
        c = _collective(inst, pod_size)
        return bool(c and c["kind"] in coll_kinds)

    def direct_flops(inst):
        return inst.op in ("dot", "convolution")

    coll_comps = _carrier_comps(comps, direct_coll)
    flop_comps = _carrier_comps(comps, direct_flops)

    def carriers(comp, direct, carrier_set):
        out = []
        for inst in comp.instrs:
            if direct(inst):
                out.append(inst)
            elif inst.op in _CALLER_OPS and any(
                    ch in carrier_set
                    for ch in _called_comps(inst.line)):
                out.append(inst)
        return out

    pairs = []
    per_comp: dict[str, dict] = {}
    for cname, comp in comps.items():
        if comp is None:
            continue
        colls = carriers(comp, direct_coll, coll_comps)
        if not colls:
            continue
        compute = carriers(comp, direct_flops, flop_comps)
        per_comp[cname] = {"colls": len(colls), "compute": len(compute),
                           "pairs": 0}
        if not compute:
            continue
        ancestors = _ancestor_fn(comp)
        for ci in colls:
            ckind = (_collective(ci, pod_size) or {}).get("kind", ci.op)
            for fi in compute:
                if fi.name == ci.name:
                    continue                   # one instr carrying both
                if _independent(ancestors, ci.name, fi.name):
                    pairs.append((cname, ci.name, ckind, fi.name, fi.op))
                    per_comp[cname]["pairs"] += 1
    return {"concurrent": bool(pairs), "pairs": pairs,
            "per_computation": per_comp}
