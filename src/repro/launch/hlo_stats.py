"""Back-compat shim: the HLO parse/accounting core moved to
``repro.analysis.footprint`` (the lanelint static-analysis subsystem
generalized it into the shared footprint layer, DESIGN.md §12).

Every name that ever lived here keeps working — benchmarks, the dryrun
reporter, the conformance grid and the structural-overlap tests all
import through this module; new code should import
``repro.analysis.footprint`` directly.
"""
from repro.analysis.footprint import (  # noqa: F401
    _COLL_KINDS,
    _DTYPE_BYTES,
    _RESULT_BYTES_OPS,
    _SKIP_BYTES_OPS,
    Computation,
    Instr,
    _ancestor_fn,
    _bytes_of,
    _called_comps,
    _carrier_comps,
    _collective,
    _dims,
    _elems_of,
    _independent,
    _instr_bytes,
    _operand_names,
    analyze,
    collective_compute_concurrency,
    collective_concurrency,
    collective_kind_counts,
    comm_footprint,
    group_info,
    parse_hlo,
    permute_edges,
    replica_groups,
)

__all__ = [
    "analyze", "collective_kind_counts", "collective_concurrency",
    "collective_compute_concurrency", "comm_footprint", "group_info",
    "parse_hlo", "replica_groups", "permute_edges",
]
