"""Training driver: end-to-end loop with checkpoint/restart + fault hooks.

Examples
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt runs/ckpt_demo
  (production: same entry point under one process per host with
   jax.distributed.initialize(); the mesh comes from launch/mesh.py)

Fault tolerance exercised here and in tests:
  * resume: picks up from the latest committed checkpoint (data pipeline
    is (seed, step)-keyed so the token stream continues exactly)
  * SIGTERM → emergency checkpoint before exit (preemption handling)
  * async checkpoint writer off the critical path
"""
from __future__ import annotations

import argparse
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import resolve, RunConfig, SHAPES
from repro.configs.base import ShapeConfig
from repro.models import init_model
from repro.optim import AdamWConfig, adamw_init
from repro.checkpoint import AsyncCheckpointer, restore_checkpoint, \
    latest_step
from repro.data import make_loader
from repro.launch.mesh import batch_axes, mesh_sizes
from repro.launch import sharding as sh
from repro.launch.steps import build_train_step


def make_mesh_auto(batch: int = 1 << 30):
    n = len(jax.devices())
    if n == 1:
        return jax.make_mesh((1, 1), ("data", "model"))
    # widest data axis that still divides the batch
    d = 1
    while d * 2 <= n and n % (d * 2) == 0 and batch % (d * 2) == 0:
        d *= 2
    m = n // d
    return jax.make_mesh((d, m), ("data", "model"))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = resolve(args.arch, smoke=args.smoke)
    mesh = make_mesh_auto(args.batch)
    ba = batch_axes(mesh)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, remat=args.remat,
                    microbatch=args.microbatch)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                          total_steps=args.steps)

    params = init_model(jax.random.PRNGKey(args.seed), cfg)
    opt_state = adamw_init(params)
    pspecs = sh.param_pspecs(params, cfg, mesh, fsdp=False)
    pshard = sh.to_shardings(pspecs, mesh)
    oshard = sh.to_shardings(sh.opt_pspecs(pspecs), mesh)
    params = jax.tree.map(jax.device_put, params, pshard)
    opt_state = jax.tree.map(jax.device_put, opt_state, oshard)

    start_step = 0
    ckpt = AsyncCheckpointer(args.ckpt) if args.ckpt else None
    if args.ckpt and latest_step(args.ckpt) is not None:
        (params, opt_state), start_step = restore_checkpoint(
            args.ckpt, (params, opt_state),
            shardings=(pshard, oshard))
        print(f"resumed from step {start_step}")

    tok_sh = NamedSharding(mesh, P(ba or None, None))
    step_fn = jax.jit(
        build_train_step(cfg, run, opt_cfg, ba),
        in_shardings=(pshard, oshard, tok_sh, tok_sh, None),
        out_shardings=(NamedSharding(mesh, P()), pshard, oshard),
        donate_argnums=(0, 1))

    loader = make_loader(cfg, args.seq, args.batch, seed=args.seed)

    # SIGTERM (preemption) → emergency checkpoint at the next step boundary
    terminate = {"now": False}
    old = signal.signal(signal.SIGTERM,
                        lambda *_: terminate.__setitem__("now", True))

    t0 = time.time()
    losses = []
    s = start_step
    try:
        for s in range(start_step, args.steps):
            toks, labels = loader.batch_at(s)
            loss, params, opt_state = step_fn(
                params, opt_state, jnp.asarray(toks), jnp.asarray(labels),
                None)
            if s % args.log_every == 0 or s == args.steps - 1:
                lv = float(loss)
                losses.append(lv)
                dt = time.time() - t0
                tps = (s - start_step + 1) * args.batch * args.seq / dt
                print(f"step {s:5d}  loss {lv:8.4f}  tok/s {tps:9.0f}",
                      flush=True)
            if ckpt and (s + 1) % args.ckpt_every == 0:
                ckpt.save(s + 1, (params, opt_state))
            if terminate["now"]:
                print("SIGTERM: emergency checkpoint")
                break
    finally:
        signal.signal(signal.SIGTERM, old)
        if ckpt:
            ckpt.save(s + 1, (params, opt_state))
            ckpt.wait()
    if len(losses) >= 2 and losses[-1] >= losses[0]:
        print(f"WARNING: loss did not decrease ({losses[0]:.3f} → "
              f"{losses[-1]:.3f})")
    else:
        print(f"loss {losses[0]:.4f} → {losses[-1]:.4f}  OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
